"""Decoder-only LM assembly: dense / MoE / RWKV6 / Mamba2-hybrid layouts.

All homogeneous layer stacks run under a single ``lax.scan`` over stacked
parameters (compile-time hygiene: one traced layer body regardless of depth —
grok's 64 MoE layers and zamba2's 81 hybrid layers compile in seconds).
Heterogeneous attention patterns (gemma3 5:1 local:global) are expressed as a
per-layer ``window`` vector consumed inside the scan, and zamba2's shared
attention block fires every ``attn_every`` layers via ``lax.cond`` with
weights closed over (shared = same params every application, per the paper's
description of Zamba2).

Public surface:
    lm_shapes(cfg)                          parameter ShapeDtypeStruct tree
    init_lm(cfg, key)                       materialized params
    forward(params, tokens, cfg, ...)       logits (+ aux loss), full-sequence
    init_cache / cache_shapes               decode cache pytrees
    decode_step(params, token, cache, cfg)  one-token serve step
    prefill_chunk_step(params, toks, ...)   C-token prompt slab into the cache
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import (Params, embed, embed_shapes, materialize,
                                 mlp, mlp_shapes, rms_norm, rms_norm_shapes,
                                 sds, unembed)

ShardFn = Callable[[jax.Array, str], jax.Array]
_id_shard: ShardFn = lambda x, name: x


def _stack(tree: Params, n: int) -> Params:
    return jax.tree.map(lambda s: sds((n,) + tuple(s.shape), s.dtype), tree)


# ---------------------------------------------------------------------------
# Parameter shapes
# ---------------------------------------------------------------------------


def _layer_shapes(cfg: ModelConfig) -> Params:
    dt = cfg.param_dtype
    if cfg.layout == "dense":
        return {"norm_attn": rms_norm_shapes(cfg.d_model, dt),
                "attn": attn.attn_shapes(cfg),
                "norm_mlp": rms_norm_shapes(cfg.d_model, dt),
                "mlp": mlp_shapes(cfg.d_model, cfg.d_ff, dt)}
    if cfg.layout == "moe":
        return {"norm_attn": rms_norm_shapes(cfg.d_model, dt),
                "attn": attn.attn_shapes(cfg),
                "norm_mlp": rms_norm_shapes(cfg.d_model, dt),
                "moe": moe_lib.moe_shapes(cfg)}
    if cfg.layout == "rwkv":
        return {"ln1": rms_norm_shapes(cfg.d_model, dt),
                "ln2": rms_norm_shapes(cfg.d_model, dt),
                "rwkv": rwkv_lib.rwkv_shapes(cfg)}
    if cfg.layout == "mamba_hybrid":
        return {"norm": rms_norm_shapes(cfg.d_model, dt),
                "mamba": ssm_lib.mamba_shapes(cfg)}
    raise ValueError(cfg.layout)


def lm_shapes(cfg: ModelConfig) -> Params:
    dt = cfg.param_dtype
    shapes: Params = {
        "tok": embed_shapes(cfg.vocab_size, cfg.d_model, dt, cfg.tie_embeddings),
        "norm_f": rms_norm_shapes(cfg.d_model, dt),
        "layers": _stack(_layer_shapes(cfg), cfg.n_layers),
    }
    if cfg.layout == "mamba_hybrid":
        shapes["shared_attn"] = {
            "norm_attn": rms_norm_shapes(cfg.d_model, dt),
            "attn": attn.attn_shapes(cfg),
            "norm_mlp": rms_norm_shapes(cfg.d_model, dt),
            "mlp": mlp_shapes(cfg.d_model, cfg.d_ff, dt)}
    return shapes


def init_lm(cfg: ModelConfig, key: jax.Array) -> Params:
    return materialize(key, lm_shapes(cfg))


# ---------------------------------------------------------------------------
# Layer bodies (shared between prefill scan and decode scan)
# ---------------------------------------------------------------------------


def _dense_block(layer: Params, x, positions, window, cfg, shard):
    h, _ = attn.attention_prefill(layer["attn"], rms_norm(x, layer["norm_attn"],
                                                          cfg.norm_eps),
                                  positions, window, cfg, shard)
    x = x + h
    x = x + mlp(layer["mlp"], rms_norm(x, layer["norm_mlp"], cfg.norm_eps),
                cfg.jnp_dtype())
    return shard(x, "act_btd")


def _moe_block(layer: Params, x, positions, window, cfg, shard):
    h, _ = attn.attention_prefill(layer["attn"], rms_norm(x, layer["norm_attn"],
                                                          cfg.norm_eps),
                                  positions, window, cfg, shard)
    x = x + h
    m, aux = moe_lib.moe_block(layer["moe"],
                               rms_norm(x, layer["norm_mlp"], cfg.norm_eps),
                               cfg, use_pallas=cfg.use_pallas)
    return shard(x + m, "act_btd"), aux


# ---------------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ---------------------------------------------------------------------------


class ForwardOut(NamedTuple):
    logits: jax.Array
    aux_loss: jax.Array


def forward_hidden(params: Params, tokens: jax.Array, cfg: ModelConfig,
                   shard: ShardFn = _id_shard,
                   frontend_embeddings: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """tokens: (B, S_text).  For vlm/audio decoder-only archs,
    ``frontend_embeddings`` (B, n_front, d) is prepended (stub frontend).

    Returns (final-normed hidden states (B, S_total, d), aux_loss) — the LM
    head is applied by the caller (training chunks it; serving takes the
    last position only)."""
    dtype = cfg.jnp_dtype()
    x = embed(params["tok"], tokens, dtype)
    if frontend_embeddings is not None:
        x = jnp.concatenate([frontend_embeddings.astype(dtype), x], axis=1)
    x = shard(x, "act_btd")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    windows = jnp.asarray(cfg.layer_windows(s), jnp.int32)
    aux0 = jnp.zeros((), jnp.float32)

    if cfg.layout in ("dense", "moe"):
        def body(x, xs):
            layer, window = xs
            if cfg.layout == "dense":
                return _dense_block(layer, x, positions, window, cfg, shard), aux0
            x, aux = _moe_block(layer, x, positions, window, cfg, shard)
            return x, aux
        if cfg.remat:
            body = jax.checkpoint(body)
        x, auxes = jax.lax.scan(lambda c, xs: body(c, xs), x,
                                (params["layers"], windows))
        aux = jnp.sum(auxes)

    elif cfg.layout == "rwkv":
        states = rwkv_lib.init_rwkv_state(cfg, b)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), states)

        def body(x, xs):
            layer, st = xs
            h, st = rwkv_lib.rwkv_time_mix(layer["rwkv"],
                                           rms_norm(x, layer["ln1"], cfg.norm_eps),
                                           st, cfg, shard=shard)
            x = x + h
            h, st = rwkv_lib.rwkv_channel_mix(layer["rwkv"],
                                              rms_norm(x, layer["ln2"], cfg.norm_eps),
                                              st, cfg)
            return shard(x + h, "act_btd"), aux0
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, (params["layers"], stacked))
        aux = aux0

    elif cfg.layout == "mamba_hybrid":
        shared = params["shared_attn"]
        every = cfg.attn_every

        def body(x, xs):
            layer, idx = xs
            h, _ = ssm_lib.mamba_prefill(layer["mamba"],
                                         rms_norm(x, layer["norm"], cfg.norm_eps),
                                         cfg)
            x = x + h

            def with_attn(x):
                return _dense_block(shared, x, positions,
                                    jnp.int32(s), cfg, shard)
            x = jax.lax.cond((idx + 1) % every == 0, with_attn, lambda x: x, x)
            return shard(x, "act_btd"), aux0
        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, (params["layers"],
                                      jnp.arange(cfg.n_layers)))
        aux = aux0
    else:
        raise ValueError(cfg.layout)

    return rms_norm(x, params["norm_f"], cfg.norm_eps), aux


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            shard: ShardFn = _id_shard,
            frontend_embeddings: Optional[jax.Array] = None) -> ForwardOut:
    """Full-logits forward (tests / small models — training uses the
    chunked-CE path over ``forward_hidden`` instead)."""
    x, aux = forward_hidden(params, tokens, cfg, shard, frontend_embeddings)
    logits = unembed(params["tok"], x, cfg.jnp_dtype())
    return ForwardOut(shard(logits, "act_btv"), aux)


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """ShapeDtypeStruct tree for the serve-step cache (dry-run input specs).

    Sliding-window / local:global stacks hold RING buffers of size W for
    their local layers instead of full-depth KV — gemma3-27b decode_32k is
    2.1 TB of KV with uniform caches and 0.4 TB with rings."""
    L = cfg.n_layers
    if cfg.layout in ("dense", "moe"):
        windowed = (cfg.layout == "dense"
                    and cfg.attn_pattern in ("swa", "local_global")
                    and max_len > cfg.window)
        if windowed:
            windows = cfg.layer_windows(max_len)
            n_local = sum(1 for w in windows if w < max_len)
            n_global = L - n_local
            w = min(cfg.window, max_len)
            kd = (batch, cfg.n_kv_heads, cfg.head_dim)
            shapes = {
                "k_local": sds((n_local, batch, w) + kd[1:], cfg.dtype),
                "v_local": sds((n_local, batch, w) + kd[1:], cfg.dtype),
                "length": sds((batch,), "int32"),
            }
            if n_global:
                shapes["k_global"] = sds((n_global, batch, max_len) + kd[1:],
                                         cfg.dtype)
                shapes["v_global"] = sds((n_global, batch, max_len) + kd[1:],
                                         cfg.dtype)
            return shapes
        kv = sds((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.dtype)
        return {"k": kv, "v": kv, "length": sds((batch,), "int32")}
    if cfg.layout == "rwkv":
        h, kd = rwkv_lib.rwkv_dims(cfg)
        return {"shift_tm": sds((L, batch, cfg.d_model), "float32"),
                "shift_cm": sds((L, batch, cfg.d_model), "float32"),
                "wkv": sds((L, batch, h, kd, kd), "float32"),
                "length": sds((batch,), "int32")}
    if cfg.layout == "mamba_hybrid":
        d_inner, h, n = ssm_lib.mamba_dims(cfg)
        conv_dim = d_inner + 2 * n
        n_sites = cfg.n_layers // cfg.attn_every
        return {"conv": sds((L, batch, cfg.ssm_conv - 1, conv_dim), cfg.dtype),
                "ssm": sds((L, batch, h, ssm_lib.MAMBA_HEAD_DIM, n), "float32"),
                "attn_k": sds((n_sites, batch, max_len, cfg.n_kv_heads,
                               cfg.head_dim), cfg.dtype),
                "attn_v": sds((n_sites, batch, max_len, cfg.n_kv_heads,
                               cfg.head_dim), cfg.dtype),
                "length": sds((batch,), "int32")}
    raise ValueError(cfg.layout)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# Chunked serving prefill (dense / moe, full-depth caches)
# ---------------------------------------------------------------------------


def prefill_chunk_step(params: Params, tokens: jax.Array, cache: Params,
                       cfg: ModelConfig, n_active: jax.Array,
                       shard: ShardFn = _id_shard
                       ) -> Tuple[jax.Array, Params]:
    """Populate the decode cache with a C-token prompt slab per slot.

    tokens: (B, C) int32 prompt tokens; slot b's slab lands at cache
    positions cache["length"][b] .. +n_active[b]-1.  ``n_active``: (B,)
    int32 — how many of the C positions are real tokens for each slot
    (0 = slot idle this step; positions past n_active are padding whose
    cache writes are masked out and whose logits are garbage).

    Returns (logits (B, C, V), new cache with per-slot lengths advanced by
    n_active).  With C == 1 and n_active == 1 this computes exactly what
    ``decode_step`` computes — the serving engine exploits that to run
    mixed ticks where decode slots ride along in slot 0 of the slab.

    Only full-depth KV layouts chunk (dense/moe with a ``k`` cache);
    ring-buffer (windowed) caches and recurrent layouts fall back to the
    one-token path — see ``api.supports_chunked_prefill``.
    """
    if cfg.layout not in ("dense", "moe") or "k" not in cache:
        raise ValueError(
            f"chunked prefill unsupported for layout={cfg.layout!r} / "
            f"cache keys {sorted(cache)} (ring-buffer caches and recurrent "
            f"state need per-token gating; use the one-token decode path)")
    dtype = cfg.jnp_dtype()
    b, c = tokens.shape
    lengths = cache["length"]
    active = (jnp.arange(c, dtype=jnp.int32)[None, :]
              < n_active[:, None])                           # (B, C)
    x = shard(embed(params["tok"], tokens, dtype), "act_btd")
    s_max = cache["k"].shape[2]
    windows = jnp.asarray(cfg.layer_windows(s_max), jnp.int32)

    def body(x, xs):
        layer, k_c, v_c, window = xs
        h = rms_norm(x, layer["norm_attn"], cfg.norm_eps)
        h, (k_c, v_c) = attn.attention_prefill_chunk(
            layer["attn"], h, k_c, v_c, window, lengths, active, cfg, shard)
        x = x + h
        h = rms_norm(x, layer["norm_mlp"], cfg.norm_eps)
        if cfg.layout == "dense":
            x = x + mlp(layer["mlp"], h, dtype)
        else:
            # padding rows flow through MoE dispatch but cannot evict real
            # tokens: ``active`` is a prefix of the slab and the capacity
            # sort is stable, so real tokens always rank first within an
            # expert (invariant documented at moe._dispatch_group); their
            # outputs land on padding rows the caller discards.
            m, _ = moe_lib.moe_block(layer["moe"], h, cfg,
                                     use_pallas=cfg.use_pallas)
            x = x + m
        return shard(x, "act_btd"), (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], windows))
    new_cache = {"k": k_new, "v": v_new, "length": lengths + n_active}
    x = rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = unembed(params["tok"], x, dtype)
    return shard(logits, "act_btv"), new_cache


# ---------------------------------------------------------------------------
# Single-token decode (serve_step body)
# ---------------------------------------------------------------------------


def _layer_slice(tree: Params, l: int) -> Params:
    return jax.tree.map(lambda a: a[l], tree)


def _dense_decode_local(layer, x, kr, vr, length, cfg, shard, dtype):
    h = rms_norm(x, layer["norm_attn"], cfg.norm_eps)
    h, (kr, vr) = attn.attention_decode_ring(layer["attn"], h, kr, vr,
                                             length, cfg, shard)
    x = x + h
    x = x + mlp(layer["mlp"], rms_norm(x, layer["norm_mlp"], cfg.norm_eps),
                dtype)
    return shard(x, "dec_btd"), kr, vr


def _dense_decode_global(layer, x, k_c, v_c, length, s_max, cfg, shard,
                         dtype):
    h = rms_norm(x, layer["norm_attn"], cfg.norm_eps)
    h, (k_c, v_c) = attn.attention_decode(layer["attn"], h, k_c, v_c,
                                          jnp.int32(s_max), length, cfg,
                                          shard)
    x = x + h
    x = x + mlp(layer["mlp"], rms_norm(x, layer["norm_mlp"], cfg.norm_eps),
                dtype)
    return shard(x, "dec_btd"), k_c, v_c


def _decode_windowed(params: Params, x: jax.Array, cache: Params,
                     cfg: ModelConfig, shard: ShardFn):
    """Decode through a windowed (ring-buffer) cache.

    swa: every layer attends through a W-slot ring.  local_global: the
    5:1 pattern is scanned as uniform groups of (local_per_global rings +
    one full-depth global layer); trailing local layers get their own scan.
    """
    dtype = cfg.jnp_dtype()
    length = cache["length"]
    L = cfg.n_layers

    def local_scan(x, layers_tree, kl, vl):
        def body(x, xs):
            layer, kr, vr = xs
            x, kr, vr = _dense_decode_local(layer, x, kr, vr, length, cfg,
                                            shard, dtype)
            return x, (kr, vr)
        return jax.lax.scan(body, x, (layers_tree, kl, vl))

    if "k_global" not in cache:          # pure sliding-window (danube)
        x, (kl, vl) = local_scan(x, params["layers"], cache["k_local"],
                                 cache["v_local"])
        return x, {"k_local": kl, "v_local": vl, "length": length + 1}

    p = cfg.local_per_global + 1
    g = L // p
    n_loc_grouped = g * (p - 1)
    grouped = jax.tree.map(
        lambda a: a[: g * p].reshape(g, p, *a.shape[1:]), params["layers"])
    local_params = jax.tree.map(lambda a: a[:, : p - 1], grouped)
    global_params = jax.tree.map(lambda a: a[:, p - 1], grouped)
    kl = cache["k_local"]
    vl = cache["v_local"]
    kl_g = kl[:n_loc_grouped].reshape(g, p - 1, *kl.shape[1:])
    vl_g = vl[:n_loc_grouped].reshape(g, p - 1, *vl.shape[1:])
    s_max = cache["k_global"].shape[2]

    def group_body(x, xs):
        lp, gp, kl_i, vl_i, kg_i, vg_i = xs
        x, (kl_i, vl_i) = local_scan(x, lp, kl_i, vl_i)
        x, kg_i, vg_i = _dense_decode_global(gp, x, kg_i, vg_i, length,
                                             s_max, cfg, shard, dtype)
        return x, (kl_i, vl_i, kg_i, vg_i)

    x, (kl_new, vl_new, kg_new, vg_new) = jax.lax.scan(
        group_body, x, (local_params, global_params, kl_g, vl_g,
                        cache["k_global"], cache["v_global"]))
    kl_new = kl_new.reshape(n_loc_grouped, *kl.shape[1:])
    vl_new = vl_new.reshape(n_loc_grouped, *vl.shape[1:])

    if L % p:                            # trailing local layers
        tail_params = jax.tree.map(lambda a: a[g * p:], params["layers"])
        x, (kl_t, vl_t) = local_scan(x, tail_params, kl[n_loc_grouped:],
                                     vl[n_loc_grouped:])
        kl_new = jnp.concatenate([kl_new, kl_t])
        vl_new = jnp.concatenate([vl_new, vl_t])
    return x, {"k_local": kl_new, "v_local": vl_new, "k_global": kg_new,
               "v_global": vg_new, "length": length + 1}


def decode_step(params: Params, token: jax.Array, cache: Params,
                cfg: ModelConfig, shard: ShardFn = _id_shard
                ) -> Tuple[jax.Array, Params]:
    """token: (B, 1) int32.  Returns (logits (B, 1, V), new cache).

    Layers run under ``lax.scan`` with the per-layer cache as xs/ys — the
    jit-level cache donation lets XLA alias the ys output buffer with the
    input cache so the append is in place.  (An unrolled ``.at[l].set``
    variant was measured to COPY the full cache per layer — 64×4 GiB of
    HBM traffic for grok decode_32k — because straight-line DUS on a buffer
    with later reads defeats XLA's in-place analysis; see EXPERIMENTS §Perf.)
    """
    dtype = cfg.jnp_dtype()
    x = shard(embed(params["tok"], token, dtype), "dec_btd")
    length = cache["length"]

    if cfg.layout in ("dense", "moe") and "k_local" in cache:
        x, new_cache = _decode_windowed(params, x, cache, cfg, shard)

    elif cfg.layout in ("dense", "moe"):
        s_max = cache["k"].shape[2]
        windows = jnp.asarray(cfg.layer_windows(s_max), jnp.int32)

        def body(x, xs):
            layer, k_c, v_c, window = xs
            h = rms_norm(x, layer["norm_attn"], cfg.norm_eps)
            h, (k_c, v_c) = attn.attention_decode(layer["attn"], h, k_c, v_c,
                                                  window, length, cfg, shard)
            x = x + h
            h = rms_norm(x, layer["norm_mlp"], cfg.norm_eps)
            if cfg.layout == "dense":
                x = x + mlp(layer["mlp"], h, dtype)
            else:
                m, _ = moe_lib.moe_block(layer["moe"], h, cfg,
                                         use_pallas=cfg.use_pallas)
                x = x + m
            return shard(x, "dec_btd"), (k_c, v_c)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"], windows))
        new_cache = {"k": k_new, "v": v_new, "length": length + 1}

    elif cfg.layout == "rwkv":
        def body(x, xs):
            layer, s_tm, s_cm, wkv = xs
            st = rwkv_lib.RwkvLayerState(s_tm, s_cm, wkv)
            h, st = rwkv_lib.rwkv_time_mix(layer["rwkv"],
                                           rms_norm(x, layer["ln1"], cfg.norm_eps),
                                           st, cfg, decode=True)
            x = x + h
            h, st = rwkv_lib.rwkv_channel_mix(layer["rwkv"],
                                              rms_norm(x, layer["ln2"], cfg.norm_eps),
                                              st, cfg)
            return x + h, (st.shift_tm, st.shift_cm, st.wkv)

        x, (s_tm, s_cm, wkv) = jax.lax.scan(
            body, x, (params["layers"], cache["shift_tm"], cache["shift_cm"],
                      cache["wkv"]))
        new_cache = {"shift_tm": s_tm, "shift_cm": s_cm, "wkv": wkv,
                     "length": length + 1}

    elif cfg.layout == "mamba_hybrid":
        shared = params["shared_attn"]
        every = cfg.attn_every
        s_max = cache["attn_k"].shape[2]
        n_sites = cache["attn_k"].shape[0]
        L = cfg.n_layers

        # mamba sub-stack between attention sites runs under a scan; the
        # (few, large) shared-attention sites are unrolled so their KV xs/ys
        # slicing stays per-site.
        def mamba_span(x, lo, hi):
            span = lambda t: jax.tree.map(lambda a: a[lo:hi], t)

            def body(x, xs):
                layer, conv_s, ssm_s = xs
                h = rms_norm(x, layer["norm"], cfg.norm_eps)
                h, (conv_s, ssm_s) = ssm_lib.mamba_decode(layer["mamba"], h,
                                                          conv_s, ssm_s, cfg)
                return x + h, (conv_s, ssm_s)

            return jax.lax.scan(body, x, (span(params["layers"]),
                                          cache["conv"][lo:hi],
                                          cache["ssm"][lo:hi]))

        conv_parts, ssm_parts, k_parts, v_parts = [], [], [], []
        lo = 0
        for site in range(n_sites):
            hi = (site + 1) * every
            x, (conv_s, ssm_s) = mamba_span(x, lo, hi)
            conv_parts.append(conv_s)
            ssm_parts.append(ssm_s)
            h = rms_norm(x, shared["norm_attn"], cfg.norm_eps)
            h, (k_c, v_c) = attn.attention_decode(
                shared["attn"], h, cache["attn_k"][site],
                cache["attn_v"][site], jnp.int32(s_max), length, cfg, shard)
            x = x + h
            x = x + mlp(shared["mlp"], rms_norm(x, shared["norm_mlp"],
                                                cfg.norm_eps), dtype)
            k_parts.append(k_c[None])
            v_parts.append(v_c[None])
            lo = hi
        if lo < L:                          # trailing mamba-only layers
            x, (conv_s, ssm_s) = mamba_span(x, lo, L)
            conv_parts.append(conv_s)
            ssm_parts.append(ssm_s)
        new_cache = {"conv": jnp.concatenate(conv_parts),
                     "ssm": jnp.concatenate(ssm_parts),
                     "attn_k": jnp.concatenate(k_parts),
                     "attn_v": jnp.concatenate(v_parts),
                     "length": length + 1}
    else:
        raise ValueError(cfg.layout)

    x = rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = unembed(params["tok"], x, dtype)
    return shard(logits, "dec_btv"), new_cache
