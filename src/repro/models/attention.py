"""GQA attention: chunked flash-reference prefill, chunked serving prefill
against a decode cache, and single-token decode.

One code path serves full, sliding-window, and local:global attention — the
per-layer ``window`` scalar parameterizes the mask (window == seq_len ⇒ full
causal attention), which is what lets heterogeneous stacks (gemma3 5:1
local:global) run under a single ``lax.scan`` over stacked layer params.

The prefill path is a *flash-structured reference*: query blocks × streamed
KV blocks with online softmax, so activation memory stays O(block²) instead
of O(seq²) — this is both the memory-safe jnp path used by the dry-run and
the numerical oracle for the Pallas kernel in ``kernels/flash_attention``.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, apply_rope, sds

ShardFn = Callable[[jax.Array, str], jax.Array]
_id_shard: ShardFn = lambda x, name: x

NEG_INF = -2.3819763e38  # most-negative bf16-representable; avoids nan softmax


def attn_shapes(cfg: ModelConfig, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    dt = cfg.param_dtype
    hq = cfg.compute_heads   # llava: 56 padded to 64 for the 16-wide TP axis
    return {
        "wq": sds((d, hq, cfg.head_dim), dt),
        "wk": sds((d, cfg.n_kv_heads, cfg.head_dim), dt),
        "wv": sds((d, cfg.n_kv_heads, cfg.head_dim), dt),
        "wo": sds((hq, cfg.head_dim, d), dt),
    }


def project_qkv(params: Params, x: jax.Array, positions: jax.Array,
                cfg: ModelConfig, rope: bool = True):
    dt = cfg.jnp_dtype()
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Prefill: chunked flash-reference with online softmax.
# ---------------------------------------------------------------------------


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, window,
                  chunk: int, causal: bool = True,
                  q_offset: int = 0) -> jax.Array:
    """q: (B, Sq, Hq, hd); k,v: (B, Sk, Hk, hd); window: scalar (traced ok).

    Returns (B, Sq, Hq, hd).  Blocks over both Sq and Sk; online softmax in
    fp32.  ``window`` counts how many past positions (incl. self) are visible.
    """
    b, sq, hq, hd = q.shape
    sk, hk = k.shape[1], k.shape[2]
    group = hq // hk
    scale = hd ** -0.5
    n_q = max(sq // chunk, 1)
    if sq % n_q:
        n_q = 1
    q_chunk = sq // n_q
    n_k = max(sk // chunk, 1)
    if sk % n_k:
        n_k = 1
    k_chunk = sk // n_k

    # inputs stay in storage dtype; contractions accumulate fp32 (MXU-native)
    qb = q.reshape(b, n_q, q_chunk, hk, group, hd)
    kb = k.reshape(b, n_k, k_chunk, hk, hd)
    vb = v.reshape(b, n_k, k_chunk, hk, hd)
    win = jnp.asarray(window, jnp.int32)

    def q_block(qi, q_tile):
        # q_tile: (b, q_chunk, hk, group, hd)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inputs):
            acc, m, l = carry
            ki, k_tile, v_tile = inputs
            k_pos = ki * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_tile, k_tile,
                           preferred_element_type=jnp.float32) * scale
            mask = k_pos[None, :] <= q_pos[:, None] if causal else jnp.ones(
                (q_chunk, k_chunk), bool)
            mask &= k_pos[None, :] > q_pos[:, None] - win
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, v_tile)
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((b, hk, group, q_chunk, hd), jnp.float32)
        m0 = jnp.full((b, hk, group, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hk, group, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.arange(n_k), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.transpose(out, (0, 3, 1, 2, 4))  # (b, q_chunk, hk, group, hd)

    out = jax.lax.map(lambda args: q_block(*args),
                      (jnp.arange(n_q), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hq, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode: one new token against a full KV cache.
# ---------------------------------------------------------------------------


def lengths_vec(cache_len, b: int) -> jax.Array:
    """Cache lengths as (B,): scalar lengths broadcast (dry-run serve_step),
    per-slot vectors pass through (continuous-batching engine)."""
    cl = jnp.asarray(cache_len, jnp.int32)
    if cl.ndim == 0:
        cl = jnp.broadcast_to(cl, (b,))
    return cl


def decode_attend(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                  window, cache_len) -> jax.Array:
    """q: (B, 1, Hq, hd); caches: (B, S, Hk, hd).  ``cache_len`` is a scalar
    or per-slot (B,) vector; slot b attends to positions
    [cache_len_b - window, cache_len_b).

    K/V stay in their storage dtype — the contractions accumulate in fp32
    via ``preferred_element_type`` instead of materializing fp32 copies of
    the cache (an eager ``.astype`` here cost ~0.35 GB/chip/layer of temp
    and doubled decode HBM traffic)."""
    b, _, hq, hd = q.shape
    s, hk = k_cache.shape[1], k_cache.shape[2]
    group = hq // hk
    scale = hd ** -0.5
    q4 = q.reshape(b, hk, group, hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", q4, k_cache,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s)
    cl = lengths_vec(cache_len, b)[:, None]             # (B, 1)
    # slot b's querying token sits at position cl_b - 1; a window of w
    # covers positions [cl_b - w, cl_b)
    valid = (pos[None] < cl) & (pos[None] >= cl - window)   # (B, S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def decode_attend_ring(q: jax.Array, k_ring: jax.Array, v_ring: jax.Array,
                       lengths: jax.Array) -> jax.Array:
    """Ring-buffer decode attention: the ring *is* the window, so the only
    mask is warm-up validity (entry i live iff i < min(len+1, W)); softmax
    is permutation-invariant so ring order needs no unscrambling (RoPE was
    applied at write time)."""
    b, _, hq, hd = q.shape
    w, hk = k_ring.shape[1], k_ring.shape[2]
    group = hq // hk
    scale = hd ** -0.5
    q4 = q.reshape(b, hk, group, hd)
    scores = jnp.einsum("bhgd,bshd->bhgs", q4, k_ring,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(w)[None] < jnp.minimum(lengths + 1, w)[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_ring,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def attention_decode_ring(params: Params, x: jax.Array, k_ring: jax.Array,
                          v_ring: jax.Array, cache_len, cfg: ModelConfig,
                          shard: ShardFn = _id_shard):
    """Sliding-window decode against a ring buffer of size W — the memory
    layout that makes gemma3's 52 local layers hold 1,024 KV entries instead
    of 32k (uniform caches put gemma3-27b decode_32k at 35 GiB/chip; rings
    bring it under 10)."""
    dt = cfg.jnp_dtype()
    lengths = lengths_vec(cache_len, x.shape[0])
    positions = lengths[:, None]
    q, k_new, v_new = project_qkv(params, x, positions, cfg)
    w = k_ring.shape[1]
    slot = lengths % w
    sel = (jnp.arange(w)[None] == slot[:, None])[:, :, None, None]
    k_ring = jnp.where(sel, k_new.astype(k_ring.dtype), k_ring)
    v_ring = jnp.where(sel, v_new.astype(v_ring.dtype), v_ring)
    out = decode_attend_ring(q, k_ring, v_ring, lengths)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return shard(out, "act_btd"), (k_ring, v_ring)


# ---------------------------------------------------------------------------
# Chunked prefill against a decode cache (serving).
# ---------------------------------------------------------------------------


def chunk_attend(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 window, positions: jax.Array) -> jax.Array:
    """Multi-query generalization of ``decode_attend``: a slab of C new
    tokens attends into a full-depth cache.

    q: (B, C, Hq, hd); caches: (B, S, Hk, hd); positions: (B, C) absolute
    position of each query token (tokens), so slot b's query c attends to
    cache positions (positions[b,c] - window, positions[b,c]] — exactly the
    visibility ``decode_attend`` gives a lone token at cache length
    positions[b,c].  Within a chunk, earlier chunk tokens are visible to
    later ones because their K/V were scattered into the cache *before*
    this attend (see ``attention_prefill_chunk``).

    Scores accumulate fp32; K/V stay in storage dtype (same rationale as
    ``decode_attend``).  A row whose mask is empty (inactive padding slot)
    degrades to a uniform softmax over NEG_INF scores — finite garbage the
    caller discards, never NaN.
    """
    b, c, hq, hd = q.shape
    s, hk = k_cache.shape[1], k_cache.shape[2]
    group = hq // hk
    scale = hd ** -0.5
    q5 = q.reshape(b, c, hk, group, hd)
    scores = jnp.einsum("bchgd,bshd->bhgcs", q5, k_cache,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s, dtype=jnp.int32)
    win = jnp.asarray(window, jnp.int32)
    valid = ((pos[None, None] <= positions[:, :, None])
             & (pos[None, None] > positions[:, :, None] - win))   # (B, C, S)
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgcs,bshd->bchgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, c, hq, hd).astype(q.dtype)


def attention_prefill_chunk(params: Params, x: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, window, lengths: jax.Array,
                            active: jax.Array, cfg: ModelConfig,
                            shard: ShardFn = _id_shard, rope: bool = True,
                            cross: bool = False):
    """One attention layer over a C-token prompt slab at per-slot offsets.

    x: (B, C, d) slab activations; ``lengths``: (B,) tokens already in the
    cache per slot (the slab lands at positions lengths..lengths+C-1);
    ``active``: (B, C) bool — position c is a real token iff
    c < n_active[b].  Inactive (padding) positions write nothing into the
    cache and their outputs are discarded by the caller.

    Self-attention (``cross=False``) scatters the slab's K/V into the cache
    at per-slot offsets first (a one-hot einsum — the chunk counterpart of
    the masked-``where`` append in ``attention_decode``, equally gather-free
    under SPMD), then attends write-then-read so intra-chunk causality comes
    from the position mask alone.  Cross-attention reads the static
    encoder-side cache and writes nothing.

    Returns (out (B, C, d), (k_cache, v_cache)).
    """
    dt = cfg.jnp_dtype()
    b, c, _ = x.shape
    offs = jnp.arange(c, dtype=jnp.int32)
    positions = lengths[:, None] + offs[None, :]            # (B, C)
    q, k_new, v_new = project_qkv(params, x, positions, cfg, rope=rope)
    if cross:
        n_f = k_cache.shape[1]
        # every encoder position visible to every query token
        pos_all = jnp.broadcast_to(jnp.int32(n_f - 1), positions.shape)
        out = chunk_attend(q, k_cache, v_cache, jnp.int32(n_f), pos_all)
    else:
        s = k_cache.shape[1]
        onehot = ((jnp.arange(s, dtype=jnp.int32)[None, None]
                   == positions[:, :, None])
                  & active[:, :, None])                      # (B, C, S)
        w = onehot.astype(jnp.float32)
        k_scat = jnp.einsum("bcs,bchd->bshd", w, k_new.astype(jnp.float32))
        v_scat = jnp.einsum("bcs,bchd->bshd", w, v_new.astype(jnp.float32))
        touched = onehot.any(axis=1)[:, :, None, None]       # (B, S, 1, 1)
        k_cache = jnp.where(touched, k_scat.astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(touched, v_scat.astype(v_cache.dtype), v_cache)
        out = chunk_attend(q, k_cache, v_cache, window, positions)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return shard(out, "act_btd"), (k_cache, v_cache)


def splice_kv(k_cache: jax.Array, v_cache: jax.Array, slot: int,
              k_block: jax.Array, v_block: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Write a reused prompt-prefix KV block into one slot's cache rows.

    k_cache/v_cache: (L, B, S, Hk, hd) stacked per-layer caches;
    k_block/v_block: (L, P, Hk, hd) host-captured KV for prompt positions
    [0, P).  The admission-time counterpart of the chunk path's
    scatter-at-offset write (``attention_prefill_chunk``): the block lands
    at positions 0..P-1 of slot ``slot`` and every other slot's rows are
    untouched, so a splice mid-serving never perturbs neighbours.  Runs
    eagerly on the host path (slot admission), where ``slot``/``P`` are
    Python ints — no jit retrace pressure.
    """
    k_block = jnp.asarray(k_block).astype(k_cache.dtype)
    v_block = jnp.asarray(v_block).astype(v_cache.dtype)
    p = k_block.shape[1]
    k_cache = k_cache.at[:, slot, :p].set(k_block)
    v_cache = v_cache.at[:, slot, :p].set(v_block)
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# Full attention block (projections + attend + output)
# ---------------------------------------------------------------------------


def attention_prefill(params: Params, x: jax.Array, positions: jax.Array,
                      window, cfg: ModelConfig, shard: ShardFn = _id_shard,
                      rope: bool = True,
                      kv_override: Optional[Tuple[jax.Array, jax.Array]] = None):
    """Returns (out (B,S,d), (k, v)) — k/v returned for cache population."""
    dt = cfg.jnp_dtype()
    q, k, v = project_qkv(params, x, positions, cfg, rope=rope)
    if kv_override is not None:
        k, v = kv_override  # cross-attention: encoder-provided KV
    q = shard(q, "act_bshd")
    k = shard(k, "act_bskd")
    v = shard(v, "act_bskd")
    if cfg.use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, window=window, chunk=cfg.attn_chunk,
                                     causal=kv_override is None)
    else:
        out = flash_prefill(q, k, v, window, cfg.attn_chunk,
                            causal=kv_override is None)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return shard(out, "act_btd"), (k, v)


def attention_decode(params: Params, x: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, window, cache_len,
                     cfg: ModelConfig, shard: ShardFn = _id_shard,
                     rope: bool = True, cross: bool = False):
    """x: (B, 1, d). Returns (out, (k_cache, v_cache)) with the new KV
    appended at ``cache_len`` (self-attention) or caches untouched (cross)."""
    dt = cfg.jnp_dtype()
    lengths = lengths_vec(cache_len, x.shape[0])        # (B,)
    positions = lengths[:, None]
    q, k_new, v_new = project_qkv(params, x, positions, cfg, rope=rope)
    if not cross:
        if cfg.kv_update == "where" or jnp.ndim(cache_len) > 0:
            # masked elementwise append: the only gather-free update when the
            # cache sequence dim is sharded (kv_heads don't divide the model
            # axis), and the only form that supports per-slot lengths
            # (continuous batching) — dynamic_update_slice on a sharded dim
            # makes SPMD materialize the full unsharded cache.
            sel = (jnp.arange(k_cache.shape[1])[None] ==
                   lengths[:, None])[:, :, None, None]
            k_cache = jnp.where(sel, k_new.astype(k_cache.dtype), k_cache)
            v_cache = jnp.where(sel, v_new.astype(v_cache.dtype), v_cache)
        else:
            # in-place append (cache S-dim local on every device)
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                k_cache, k_new.astype(k_cache.dtype), cache_len, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                v_cache, v_new.astype(v_cache.dtype), cache_len, axis=1)
        attend_len = lengths + 1
    else:
        attend_len = k_cache.shape[1]
    if (cfg.use_pallas and k_cache.shape[1] >= 2048
            and jnp.ndim(cache_len) == 0 and not cross):
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention(q, k_cache, v_cache, window=window,
                                      cache_len=cache_len + 1)
    else:
        out = decode_attend(q, k_cache, v_cache, window, attend_len)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return shard(out, "act_btd"), (k_cache, v_cache)
