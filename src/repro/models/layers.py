"""Primitive layers (pure-functional, pytree params): norms, RoPE, MLP, embed.

Parameter handling is shapes-first: every module exposes ``*_shapes(cfg)``
returning a pytree of ``jax.ShapeDtypeStruct`` that mirrors its forward code.
``materialize`` turns a shape tree into real initialized params; the dry-run
passes the shape tree itself (no allocation), which is what lets us lower
314B-parameter models on a CPU host.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat

Params = Dict[str, Any]


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def _init_leaf(key, path: str, s: jax.ShapeDtypeStruct) -> jax.Array:
    name = path.split("/")[-1]
    if name.startswith(("norm", "scale", "ln")):
        return jnp.ones(s.shape, s.dtype)
    if name.startswith(("bias", "dt_bias")):
        return jnp.zeros(s.shape, s.dtype)
    if name.startswith("a_log"):  # mamba A init: log of [1, 16)
        u = jax.random.uniform(key, s.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(s.dtype)
    if name.startswith("decay"):  # rwkv decay speed init
        return jax.random.uniform(key, s.shape, jnp.float32, -8.0, -4.0).astype(s.dtype)
    if name.startswith("embed"):
        return (jax.random.normal(key, s.shape, jnp.float32) * 0.02).astype(s.dtype)
    fan_in = s.shape[-2] if len(s.shape) >= 2 else max(s.shape[-1], 1)
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)


def materialize(key: jax.Array, shape_tree: Params) -> Params:
    """Initialize a params pytree from its ShapeDtypeStruct tree."""
    leaves, treedef = compat.tree_flatten_with_path(shape_tree)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, (path, s) in zip(keys, leaves):
        out.append(_init_leaf(k, compat.path_str(path), s))
    return jax.tree.unflatten(jax.tree.structure(shape_tree), out)


def param_count(tree: Params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def param_bytes(tree: Params) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def rms_norm_shapes(d: int, dtype) -> jax.ShapeDtypeStruct:
    return sds((d,), dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)                    # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs    # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                             # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_shapes(d_model: int, d_ff: int, dtype) -> Params:
    return {"wi_gate": sds((d_model, d_ff), dtype),
            "wi_up": sds((d_model, d_ff), dtype),
            "wo": sds((d_ff, d_model), dtype)}


def mlp(params: Params, x: jax.Array, compute_dtype=None) -> jax.Array:
    dt = compute_dtype or x.dtype
    g = jnp.einsum("...d,df->...f", x, params["wi_gate"].astype(dt))
    u = jnp.einsum("...d,df->...f", x, params["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_shapes(vocab: int, d_model: int, dtype, tie: bool) -> Params:
    out = {"embed": sds((vocab, d_model), dtype)}
    if not tie:
        out["unembed"] = sds((d_model, vocab), dtype)
    return out


def embed(params: Params, tokens: jax.Array, compute_dtype) -> jax.Array:
    return params["embed"].astype(compute_dtype)[tokens]


def unembed(params: Params, x: jax.Array, compute_dtype) -> jax.Array:
    if "unembed" in params:
        w = params["unembed"].astype(compute_dtype)
    else:
        w = params["embed"].astype(compute_dtype).T
    return jnp.einsum("...d,dv->...v", x, w)
