"""Encoder-decoder (whisper-style) assembly.

The audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed mel-frame embeddings (B, n_frames, d_model); the conv feature
extractor the real Whisper uses is out of scope (modality frontends are
explicitly stubbed, only the transformer backbone is exercised).

Encoder: bidirectional full-attention blocks over frames.
Decoder: causal self-attention + cross-attention to encoder output + MLP.
Decode caches: per-layer self KV (grows) + cross KV (static, precomputed
from the encoder output once per request).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.layers import (Params, embed, embed_shapes, materialize,
                                 mlp, mlp_shapes, rms_norm, rms_norm_shapes,
                                 sds, unembed)
from repro.models.lm import ShardFn, _id_shard, _stack, ForwardOut


def _enc_layer_shapes(cfg: ModelConfig) -> Params:
    dt = cfg.param_dtype
    return {"norm_attn": rms_norm_shapes(cfg.d_model, dt),
            "attn": attn.attn_shapes(cfg),
            "norm_mlp": rms_norm_shapes(cfg.d_model, dt),
            "mlp": mlp_shapes(cfg.d_model, cfg.d_ff, dt)}


def _dec_layer_shapes(cfg: ModelConfig) -> Params:
    dt = cfg.param_dtype
    return {"norm_self": rms_norm_shapes(cfg.d_model, dt),
            "self_attn": attn.attn_shapes(cfg),
            "norm_cross": rms_norm_shapes(cfg.d_model, dt),
            "cross_attn": attn.attn_shapes(cfg),
            "norm_mlp": rms_norm_shapes(cfg.d_model, dt),
            "mlp": mlp_shapes(cfg.d_model, cfg.d_ff, dt)}


def encdec_shapes(cfg: ModelConfig) -> Params:
    dt = cfg.param_dtype
    return {
        "tok": embed_shapes(cfg.vocab_size, cfg.d_model, dt, cfg.tie_embeddings),
        "enc_layers": _stack(_enc_layer_shapes(cfg), cfg.n_encoder_layers),
        "dec_layers": _stack(_dec_layer_shapes(cfg), cfg.n_layers),
        "norm_enc": rms_norm_shapes(cfg.d_model, dt),
        "norm_dec": rms_norm_shapes(cfg.d_model, dt),
    }


def init_encdec(cfg: ModelConfig, key: jax.Array) -> Params:
    return materialize(key, encdec_shapes(cfg))


def _cross_attention(layer_params: Params, x: jax.Array, enc_kv, cfg,
                     shard: ShardFn):
    """Prefill-style cross attention: q from x, kv precomputed from encoder."""
    dt = cfg.jnp_dtype()
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    q, _, _ = attn.project_qkv(layer_params, x, positions, cfg, rope=False)
    k, v = enc_kv
    out = attn.flash_prefill(q, k, v, window=k.shape[1], chunk=cfg.attn_chunk,
                             causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, layer_params["wo"].astype(dt))


def encode(params: Params, frames: jax.Array, cfg: ModelConfig,
           shard: ShardFn = _id_shard) -> jax.Array:
    """frames: (B, n_frames, d_model) stub embeddings -> encoder output."""
    x = shard(frames.astype(cfg.jnp_dtype()), "act_btd")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(x, layer):
        h = rms_norm(x, layer["norm_attn"], cfg.norm_eps)
        q, k, v = attn.project_qkv(layer["attn"], h, positions, cfg)
        o = attn.flash_prefill(q, k, v, window=s, chunk=cfg.attn_chunk,
                               causal=False)
        o = jnp.einsum("bshk,hkd->bsd", o, layer["attn"]["wo"].astype(x.dtype))
        x = x + o
        x = x + mlp(layer["mlp"], rms_norm(x, layer["norm_mlp"], cfg.norm_eps),
                    cfg.jnp_dtype())
        return shard(x, "act_btd"), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["norm_enc"], cfg.norm_eps)


def cross_kv(params: Params, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute per-decoder-layer cross K/V: (L, B, n_frames, hk, hd)."""
    dt = cfg.jnp_dtype()

    def per_layer(layer):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, layer["cross_attn"]["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, layer["cross_attn"]["wv"].astype(dt))
        return k, v

    return jax.vmap(per_layer)(params["dec_layers"])


def forward_hidden(params: Params, frames: jax.Array, tokens: jax.Array,
                   cfg: ModelConfig, shard: ShardFn = _id_shard
                   ) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced forward up to the final decoder norm: (B, S, d)."""
    dtype = cfg.jnp_dtype()
    enc_out = encode(params, frames, cfg, shard)
    x = shard(embed(params["tok"], tokens, dtype), "act_btd")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    ck, cv = cross_kv(params, enc_out, cfg)

    def body_cross(x, xs):
        layer, k_cross, v_cross = xs
        h = rms_norm(x, layer["norm_self"], cfg.norm_eps)
        h, _ = attn.attention_prefill(layer["self_attn"], h, positions,
                                      jnp.int32(s), cfg, shard)
        x = x + h
        h = rms_norm(x, layer["norm_cross"], cfg.norm_eps)
        x = x + _cross_attention(layer["cross_attn"], h, (k_cross, v_cross),
                                 cfg, shard)
        x = x + mlp(layer["mlp"], rms_norm(x, layer["norm_mlp"], cfg.norm_eps),
                    dtype)
        return shard(x, "act_btd"), None

    fn = body_cross
    if cfg.remat:
        fn = jax.checkpoint(fn)
    x, _ = jax.lax.scan(fn, x, (params["dec_layers"], ck, cv))
    x = rms_norm(x, params["norm_dec"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def forward(params: Params, frames: jax.Array, tokens: jax.Array,
            cfg: ModelConfig, shard: ShardFn = _id_shard) -> ForwardOut:
    """Full teacher-forced forward: (B, n_frames, d) + (B, S) -> logits."""
    x, aux = forward_hidden(params, frames, tokens, cfg, shard)
    return ForwardOut(unembed(params["tok"], x, cfg.jnp_dtype()), aux)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    L = cfg.n_layers
    kv = sds((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), cfg.dtype)
    n_f = cfg.n_frontend_tokens
    cross = sds((L, batch, n_f, cfg.n_kv_heads, cfg.head_dim), cfg.dtype)
    return {"k": kv, "v": kv, "cross_k": cross, "cross_v": cross,
            "length": sds((batch,), "int32")}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, max_len))


def prefill_chunk_step(params: Params, tokens: jax.Array, cache: Params,
                       cfg: ModelConfig, n_active: jax.Array,
                       shard: ShardFn = _id_shard):
    """Decoder-side chunked prefill: a C-token slab of prompt tokens per
    slot into the self-attention cache, cross-attending the (static,
    precomputed) encoder-side ``cross_k``/``cross_v``.

    Same contract as ``lm.prefill_chunk_step``: tokens (B, C), n_active
    (B,) gates per-slot activity, returns (logits (B, C, V), new cache)
    with lengths advanced by n_active.  Cross K/V are read-only — the
    engine precomputes them once per request (or leaves them zero for the
    stub frontend), exactly as in ``decode_step``.
    """
    dtype = cfg.jnp_dtype()
    b, c = tokens.shape
    lengths = cache["length"]
    active = (jnp.arange(c, dtype=jnp.int32)[None, :]
              < n_active[:, None])
    x = shard(embed(params["tok"], tokens, dtype), "act_btd")
    s_max = cache["k"].shape[2]

    def body(x, xs):
        layer, k_c, v_c, ck, cv = xs
        h = rms_norm(x, layer["norm_self"], cfg.norm_eps)
        h, (k_c, v_c) = attn.attention_prefill_chunk(
            layer["self_attn"], h, k_c, v_c, jnp.int32(s_max), lengths,
            active, cfg, shard)
        x = x + h
        h = rms_norm(x, layer["norm_cross"], cfg.norm_eps)
        h, _ = attn.attention_prefill_chunk(
            layer["cross_attn"], h, ck, cv, jnp.int32(ck.shape[1]), lengths,
            active, cfg, shard, rope=False, cross=True)
        x = x + h
        x = x + mlp(layer["mlp"], rms_norm(x, layer["norm_mlp"], cfg.norm_eps),
                    dtype)
        return shard(x, "act_btd"), (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = rms_norm(x, params["norm_dec"], cfg.norm_eps)
    logits = unembed(params["tok"], x, dtype)
    new_cache = dict(cache, k=k_new, v=v_new, length=lengths + n_active)
    return shard(logits, "act_btv"), new_cache


def decode_step(params: Params, token: jax.Array, cache: Params,
                cfg: ModelConfig, shard: ShardFn = _id_shard):
    """Scan over layers with cache xs/ys — see lm.decode_step."""
    dtype = cfg.jnp_dtype()
    x = shard(embed(params["tok"], token, dtype), "dec_btd")
    length = cache["length"]
    s_max = cache["k"].shape[2]

    def body(x, xs):
        layer, k_c, v_c, ck, cv = xs
        h = rms_norm(x, layer["norm_self"], cfg.norm_eps)
        h, (k_c, v_c) = attn.attention_decode(layer["self_attn"], h, k_c, v_c,
                                              jnp.int32(s_max), length, cfg,
                                              shard)
        x = x + h
        h = rms_norm(x, layer["norm_cross"], cfg.norm_eps)
        h, _ = attn.attention_decode(layer["cross_attn"], h, ck, cv,
                                     jnp.int32(ck.shape[1]), length, cfg,
                                     shard, rope=False, cross=True)
        x = x + h
        x = x + mlp(layer["mlp"], rms_norm(x, layer["norm_mlp"], cfg.norm_eps),
                    dtype)
        return shard(x, "dec_btd"), (k_c, v_c)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = rms_norm(x, params["norm_dec"], cfg.norm_eps)
    logits = unembed(params["tok"], x, dtype)
    new_cache = dict(cache, k=k_new, v=v_new, length=length + 1)
    return shard(logits, "dec_btv"), new_cache
