"""Mamba2 (SSD) block — chunked selective-state-space scan.

Prefill uses the chunked SSD formulation (intra-chunk quadratic form +
inter-chunk state recurrence), so activation memory is O(S·d + S/L·state)
rather than O(S·state) per step — mandatory for 4k×256 training batches and
the 500k long-context shape.  Decode is the exact single-step recurrence.

Scalar-A-per-head variant (as in Mamba2), n_groups=1 (B, C shared across
heads).  This is the jnp reference; ``kernels/mamba2`` holds the Pallas TPU
kernel for the same math.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, sds

MAMBA_HEAD_DIM = 64


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // MAMBA_HEAD_DIM
    return d_inner, n_heads, cfg.ssm_state


def mamba_shapes(cfg: ModelConfig) -> Params:
    dt = cfg.param_dtype
    d = cfg.d_model
    d_inner, h, n = mamba_dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        # in_proj → [z (d_inner), x (d_inner), B (n), C (n), dt (h)]
        "in_proj": sds((d, 2 * d_inner + 2 * n + h), dt),
        "conv_w": sds((cfg.ssm_conv, conv_dim), dt),
        "conv_bias": sds((conv_dim,), dt),
        "a_log": sds((h,), "float32"),
        "d_skip": sds((h,), "float32"),
        "dt_bias": sds((h,), "float32"),
        "norm_gate": sds((d_inner,), dt),
        "out_proj": sds((d_inner, d), dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, bias: jax.Array,
                 state: Optional[jax.Array] = None):
    """x: (B, S, C); w: (K, C) depthwise causal conv.  Returns (y, new_state)
    where state carries the trailing K-1 inputs for decode."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(y + bias[None, None]), new_state


def _split_proj(params: Params, u: jax.Array, cfg: ModelConfig):
    d_inner, h, n = mamba_dims(cfg)
    dt_ = cfg.jnp_dtype()
    proj = jnp.einsum("bsd,de->bse", u, params["in_proj"].astype(dt_))
    z, xbc_dt = jnp.split(proj, [d_inner], axis=-1)
    xbc, dt_raw = jnp.split(xbc_dt, [d_inner + 2 * n], axis=-1)
    return z, xbc, dt_raw


def ssd_chunked(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
                A: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x: (b, s, h, p)   per-head inputs
    dt: (b, s, h)     softplus'd step sizes
    B, C: (b, s, n)   shared input/output projections
    A: (h,)           negative per-head decay rate
    Returns (y (b,s,h,p), h_final (b,h,p,n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    L = min(chunk, s)
    if s % L:
        L = s  # fall back to single chunk for awkward lengths
    nc = s // L
    # storage-dtype until inside the chunk body: eager fp32 casts of the full
    # (B, S, ...) tensors would hold 2× sequence-length temps alive
    xc = x.reshape(b, nc, L, h, p)
    dtc = dt.reshape(b, nc, L, h)
    Bc = B.reshape(b, nc, L, n)
    Cc = C.reshape(b, nc, L, n)
    A = A.astype(jnp.float32)

    def chunk_step(h_in, inputs):
        xb, dtb, Bb, Cb = inputs            # (b,L,h,p), (b,L,h), (b,L,n), (b,L,n)
        xb = xb.astype(jnp.float32)
        dtb = dtb.astype(jnp.float32)
        Bb = Bb.astype(jnp.float32)
        Cb = Cb.astype(jnp.float32)
        dA = dtb * A[None, None]            # (b,L,h) log-decay per step (<=0)
        cum = jnp.cumsum(dA, axis=1)        # inclusive cumulative log decay
        # intra-chunk: M[b,h,t,s] = C_t·B_s · exp(cum_t - cum_s) · dt_s, s<=t
        G = jnp.einsum("btn,bsn->bts", Cb, Bb)
        diff = cum[:, :, None, :] - cum[:, None, :, :]   # (b,t,s,h)
        mask = jnp.tril(jnp.ones((L, L), bool))
        # double-where: masked (s>t) entries have diff>0 whose exp overflows
        # — harmless in the forward select but inf·0=NaN in the backward
        safe = jnp.where(mask[None, :, :, None], diff, 0.0)
        M = jnp.where(mask[None, :, :, None], jnp.exp(safe), 0.0)
        M = M * G[..., None] * dtb[:, None, :, :]        # (b,t,s,h)
        y_intra = jnp.einsum("btsh,bshp->bthp", M, xb)
        # inter-chunk: y_inter[t] = (C_t · h_in) * exp(cum_t)
        y_inter = jnp.einsum("btn,bhpn->bthp", Cb, h_in) * jnp.exp(cum)[..., None]
        # state update: h_out = h_in*exp(cum_L) + Σ_s exp(cum_L-cum_s)·dt_s·x_s⊗B_s
        w_last = jnp.exp(cum[:, -1])                     # (b,h)
        scale = jnp.exp(cum[:, -1:, :] - cum) * dtb      # (b,L,h)
        h_out = (h_in * w_last[:, :, None, None] +
                 jnp.einsum("blh,blhp,bln->bhpn", scale, xb, Bb))
        return h_out, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    h_fin, ys = jax.lax.scan(chunk_step, h0,
                             (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
                              jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)
    return y.astype(x.dtype), h_fin


def mamba_prefill(params: Params, u: jax.Array, cfg: ModelConfig,
                  chunk: int = 256):
    """u: (B, S, d) -> (y (B, S, d), (conv_state, ssm_state))."""
    d_inner, h, n = mamba_dims(cfg)
    dt_ = cfg.jnp_dtype()
    z, xbc, dt_raw = _split_proj(params, u, cfg)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"].astype(dt_),
                                   params["conv_bias"].astype(dt_))
    xin, B, C = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None])
    A = -jnp.exp(params["a_log"])
    xh = xin.reshape(*xin.shape[:2], h, MAMBA_HEAD_DIM)
    if cfg.use_pallas:
        from repro.kernels.mamba2 import ops as ssd_ops
        y, ssm_state = ssd_ops.ssd(xh, dt, B, C, A, chunk=chunk)
    else:
        y, ssm_state = ssd_chunked(xh, dt, B, C, A, chunk)
    y = y + xh.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(*u.shape[:2], d_inner).astype(dt_)
    y = y * jax.nn.silu(z)                               # gated
    y = y * params["norm_gate"].astype(dt_)[None, None]
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_)), (
        conv_state, ssm_state)


def mamba_decode(params: Params, u: jax.Array, conv_state: jax.Array,
                 ssm_state: jax.Array, cfg: ModelConfig):
    """Single-token recurrence.  u: (B, 1, d); states from prefill/previous."""
    d_inner, h, n = mamba_dims(cfg)
    dt_ = cfg.jnp_dtype()
    z, xbc, dt_raw = _split_proj(params, u, cfg)
    xbc, conv_state = _causal_conv(xbc, params["conv_w"].astype(dt_),
                                   params["conv_bias"].astype(dt_),
                                   state=conv_state)
    xin, B, C = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"][None, None])
    A = -jnp.exp(params["a_log"])
    xh = xin.reshape(xin.shape[0], 1, h, MAMBA_HEAD_DIM).astype(jnp.float32)
    # h' = h·exp(dt·A) + dt·x⊗B ;  y = C·h' + D·x
    decay = jnp.exp(dt[:, 0, :, None, None] * A[None, :, None, None])
    upd = jnp.einsum("bhp,bn->bhpn", xh[:, 0] * dt[:, 0, :, None], B[:, 0])
    ssm_state = ssm_state * decay + upd
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), ssm_state)
    y = y + xh[:, 0] * params["d_skip"][None, :, None]
    y = y.reshape(u.shape[0], 1, d_inner).astype(dt_)
    y = y * jax.nn.silu(z) * params["norm_gate"].astype(dt_)[None, None]
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_)), (
        conv_state, ssm_state)
