"""Sharding policy: parameter PartitionSpecs + activation constraints.

Parallelism plan (DESIGN §5) over mesh axes ("pod", "data", "model"):

  train:  batch over ("pod","data"); TP over "model" (heads / d_ff / vocab);
          FSDP weight+optimizer storage over "data" (per-layer all-gather
          inside the layer scan); MoE = expert-TP (expert hidden over
          "model"), no all-to-all.
  serve:  batch over "data" when divisible; KV cache sequence over "model"
          (and over ("data","model") when batch=1, e.g. long_500k); weights
          over "model" (+ "data" for MoE expert hidden — grok's 618GB of
          experts must spread over all 256 chips).

Every rule degrades gracefully: if a dim isn't divisible by its assigned
axis, that dim falls back to replication (``_fit``) — this is how kv_heads=8
archs and llava's 56 heads stay lowerable on a 16-wide model axis.  llava
additionally flips attention to sequence-sharding (cfg.attn_shard =
"sequence") so attention compute still spreads 16-way.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

AxisName = Any  # str | tuple[str, ...] | None


def _axis_size(mesh: Mesh, axis: AxisName) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def _fit(mesh: Mesh, shape: Tuple[int, ...], wanted: Tuple[AxisName, ...]) -> P:
    """Drop axes whose size doesn't divide the corresponding dim."""
    spec = []
    for dim, axis in zip(shape, wanted):
        spec.append(axis if axis is not None and dim % _axis_size(mesh, axis) == 0
                    else None)
    return P(*spec)


class ShardingPolicy:
    """Produces param specs and a shard_fn for one (cfg, mesh, mode)."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, mode: str = "train"):
        if mode not in ("train", "serve"):
            raise ValueError(mode)
        self.cfg = cfg
        self.mesh = mesh
        self.mode = mode
        self.multi_pod = "pod" in mesh.axis_names
        self.batch_axes: AxisName = (("pod", "data") if self.multi_pod else "data")
        # FSDP storage axis for weights (train only)
        self.fsdp: Optional[str] = "data" if mode == "train" else None

    # -- parameters -----------------------------------------------------------

    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        m, fsdp = self.mesh, self.fsdp
        name = path.split("/")[-1]
        nd = len(shape)
        if nd <= 1:
            return P(None) if nd else P()
        # embeddings: vocab over model, d over data (fsdp)
        if name in ("embed",):
            return _fit(m, shape, ("model", fsdp))
        if name in ("unembed",):
            return _fit(m, shape, (fsdp, "model"))
        # attention projections (d, H, hd) / (H, hd, d) — path-scoped to avoid
        # colliding with rwkv's 2-D wk/wv and MoE's 3-D wo
        in_attn = any(k in path for k in ("attn/", "self_attn/", "cross_attn/"))
        if in_attn and name in ("wq", "wk", "wv"):
            return _fit(m, shape, (fsdp, "model", None))
        if in_attn and name == "wo":
            return _fit(m, shape, ("model", None, fsdp))
        # MoE experts (E, d, f) / (E, f, d): 2-D (d × f) sharding over
        # (data × model).  Sharding f over ("data","model") jointly was
        # measured to make SPMD all-gather the *batch-sharded token buckets*
        # across data instead (60 GiB/chip for grok prefill_32k) — d×f keeps
        # tokens local and turns the conflict into a per-layer weight gather.
        if nd == 3 and name in ("wi_gate", "wi_up"):
            if self.mode == "serve":
                return _fit(m, shape, (None, "data", "model"))
            return _fit(m, shape, (None, fsdp, "model"))
        if nd == 3 and name == "wo":
            if self.mode == "serve":
                return _fit(m, shape, (None, "model", "data"))
            return _fit(m, shape, (None, "model", fsdp))
        # dense MLP (d, f) / (f, d)
        if name in ("wi_gate", "wi_up", "ck"):
            return _fit(m, shape, (fsdp, "model"))
        if name in ("wo", "cv"):
            return _fit(m, shape, ("model", fsdp))
        if name == "router":
            return _fit(m, shape, (fsdp, None))
        # mamba projections
        if name == "in_proj":
            return _fit(m, shape, (fsdp, "model"))
        if name == "out_proj":
            return _fit(m, shape, ("model", fsdp))
        if name == "conv_w":
            return _fit(m, shape, (None, "model"))
        # rwkv square projections (d, d) and channel mix handled above
        if name in ("wr", "wk", "wv", "wg", "wo_tm", "cr"):
            return _fit(m, shape, (fsdp, "model"))
        if name in ("lora_a_decay",):
            return _fit(m, shape, (fsdp, None))
        if name in ("lora_b_decay",):
            return _fit(m, shape, (None, None))
        return P(*([None] * nd))

    def param_specs(self, shape_tree) -> Any:
        def spec_of(path, leaf):
            pname = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                             for p in path)
            shape = tuple(leaf.shape)
            # stacked layer params carry a leading n_layers dim — never sharded
            if "layers" in pname and len(shape) >= 1:
                inner = self.param_spec(pname, shape[1:])
                return P(None, *inner)
            return self.param_spec(pname, shape)
        return jax.tree_util.tree_map_with_path(spec_of, shape_tree)

    def param_shardings(self, shape_tree) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.param_specs(shape_tree))

    # -- activations ----------------------------------------------------------

    def _heads_divisible(self, h: int) -> bool:
        return h % _axis_size(self.mesh, "model") == 0

    def act_spec(self, name: str, shape: Tuple[int, ...]) -> P:
        cfg, m = self.cfg, self.mesh
        batch = self.batch_axes
        if name == "act_btd":          # (B, S, d)
            if cfg.seq_shard_train and len(shape) > 1 and shape[1] > 1:
                # sequence parallelism: residual stream S-sharded between
                # blocks (train AND long-prefill); attention/MLP transitions
                # reshard via all-to-all.  Decode (S=1) is unaffected.
                return _fit(m, shape, (batch, "model", None))
            return _fit(m, shape, (batch, None, None))
        if name == "act_btv":          # logits (B, S, V)
            return _fit(m, shape, (batch, None, "model"))
        if name == "act_bshd":         # q/out (B, S, H, hd)
            if cfg.attn_shard == "sequence" or not self._heads_divisible(shape[2]):
                return _fit(m, shape, (batch, "model", None, None))
            return _fit(m, shape, (batch, None, "model", None))
        if name == "act_bskd":         # k/v (B, S, Hk, hd)
            if self._heads_divisible(shape[2]) and cfg.attn_shard != "sequence":
                return _fit(m, shape, (batch, None, "model", None))
            return _fit(m, shape, (batch, None, None, None))
        if name == "dec_btd":          # decode activations (B, 1, d)
            return _fit(m, shape, (batch, None, None))
        if name == "dec_btv":          # decode logits (B, 1, V)
            return _fit(m, shape, (batch, None, "model"))
        return P(*([None] * len(shape)))

    def shard_fn(self):
        def fn(x, name):
            spec = self.act_spec(name, tuple(x.shape))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, spec))
        return fn

    # -- decode caches ----------------------------------------------------------

    def cache_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        m = self.mesh
        batch = self.batch_axes
        name = path.split("/")[-1]
        if name in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v",
                    "k_local", "v_local", "k_global", "v_global"):
            # (L|sites, B, S, Hk, hd): batch over data when divisible; heads
            # over model when they divide (keeps the decode append a local
            # dynamic_update_slice); otherwise sequence over model (append
            # becomes a masked where — see attention.attention_decode).
            # batch=1 long-context: spread the sequence over everything.
            b, s, hk = shape[1], shape[2], shape[3]
            if b % _axis_size(m, batch) == 0 and b >= _axis_size(m, batch):
                if hk % _axis_size(m, "model") == 0:
                    return _fit(m, shape, (None, batch, None, "model", None))
                return _fit(m, shape, (None, batch, "model", None, None))
            return _fit(m, shape, (None, None, ("data", "model"), None, None))
        if name == "ssm":              # (L, B, H, P, N)
            return _fit(m, shape, (None, batch, "model", None, None))
        if name == "conv":             # (L, B, K-1, conv_dim)
            return _fit(m, shape, (None, batch, None, "model"))
        if name == "wkv":              # (L, B, H, K, V)
            return _fit(m, shape, (None, batch, "model", None, None))
        if name in ("shift_tm", "shift_cm"):
            return _fit(m, shape, (None, batch, None))
        if name == "length":           # per-slot lengths (B,)
            return _fit(m, shape, (batch,))
        return P(*([None] * len(shape)))

    def kv_update_mode(self, batch: int, n_kv_heads: int) -> str:
        """'dus' when the cache S-dim stays device-local (batch+heads shard),
        else 'where' (masked elementwise append over the sharded S-dim)."""
        bsz = _axis_size(self.mesh, self.batch_axes)
        batch_ok = batch % bsz == 0 and batch >= bsz
        heads_ok = n_kv_heads % _axis_size(self.mesh, "model") == 0
        return "dus" if (batch_ok and heads_ok) else "where"

    def cache_specs(self, cache_tree) -> Any:
        def spec_of(path, leaf):
            pname = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                             for p in path)
            return self.cache_spec(pname, tuple(leaf.shape))
        return jax.tree_util.tree_map_with_path(spec_of, cache_tree)

    def cache_shardings(self, cache_tree) -> Any:
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.cache_specs(cache_tree))

    # -- batch inputs -----------------------------------------------------------

    def batch_spec(self, name: str, shape: Tuple[int, ...]) -> P:
        batch = self.batch_axes
        if name in ("tokens", "labels", "loss_mask"):
            return _fit(self.mesh, shape, (batch, None))
        if name in ("frames", "frontend_embeddings"):
            return _fit(self.mesh, shape, (batch, None, None))
        if name == "token":            # decode input (B, 1)
            return _fit(self.mesh, shape, (batch, None))
        return P(*([None] * len(shape)))
