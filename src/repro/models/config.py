"""Unified model configuration covering every assigned architecture family.

One ``ModelConfig`` describes dense GQA transformers (full / sliding-window /
local:global interleaved attention), MoE (routed top-k + shared experts),
RWKV6, Mamba2 hybrids, and encoder-decoder (whisper) — so the GreenServ pool,
the dry-run, and the smoke tests all speak one config language.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp

FULL_WINDOW = -1  # sentinel: attention window covering the whole sequence


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    layout: str                       # dense | moe | rwkv | mamba_hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads

    # --- attention pattern ---
    attn_pattern: str = "full"        # full | swa | local_global
    window: int = 4096                # sliding window size for swa/local layers
    local_per_global: int = 5         # local:global interleave ratio

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    n_shared_experts: int = 0
    capacity_factor: float = 1.25

    # --- SSM / hybrid ---
    ssm_state: int = 0                # Mamba2 state dim
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 6               # hybrid: shared attn block after every N ssm layers

    # --- encoder-decoder / modality frontend ---
    n_encoder_layers: int = 0
    frontend: str = "none"            # none | audio | vision
    n_frontend_tokens: int = 0        # stub embedding count (audio frames / patches)

    # --- misc ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    max_seq_len: int = 131072
    tie_embeddings: bool = False
    dtype: str = "bfloat16"           # compute dtype
    param_dtype: str = "float32"      # storage dtype
    moment_dtype: str = "float32"     # Adam moment storage (bf16 for 314B grok)
    grad_accum_dtype: str = "float32" # microbatch grad accumulator (bf16 grok)
    seq_shard_train: bool = False     # sequence-parallel residual stream in
                                      # training (Korthikanti-style SP; grok)
    remat: bool = True
    attn_chunk: int = 512             # query-block size for chunked flash-ref attention

    # --- sharding policy knobs (see models/sharding.py) ---
    attn_shard: str = "heads"         # heads | sequence (when heads don't divide)
    pad_heads_to: int = 0             # round query heads up to the sharding
                                      # grid (llava: 56 -> 64 on a 16-wide
                                      # model axis); 0 = no padding
    kv_update: str = "dus"            # dus | where — decode-cache write strategy:
                                      # "where" (masked elementwise) is the only
                                      # gather-free form when S is sharded
    use_pallas: bool = False          # TPU kernels; False = pure-jnp reference path

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.layout in ("dense", "moe", "encdec") and self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError(f"{self.name}: n_heads must be a multiple of n_kv_heads")
        if self.layout == "moe" and (self.n_experts <= 0 or self.top_k <= 0):
            raise ValueError(f"{self.name}: moe layout needs n_experts/top_k")

    # -- derived ---------------------------------------------------------------

    @property
    def compute_heads(self) -> int:
        """Query heads actually computed/stored (TPU alignment padding)."""
        return self.pad_heads_to or self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def layer_windows(self, seq_len: int) -> Tuple[int, ...]:
        """Per-layer attention window; unifies full/swa/local:global in one code
        path (window == seq_len ⇒ full attention)."""
        out = []
        for l in range(self.n_layers):
            if self.attn_pattern == "full":
                out.append(seq_len)
            elif self.attn_pattern == "swa":
                out.append(min(self.window, seq_len))
            elif self.attn_pattern == "local_global":
                # pattern unit: `local_per_global` local layers then 1 global
                is_global = (l % (self.local_per_global + 1)) == self.local_per_global
                out.append(seq_len if is_global else min(self.window, seq_len))
            else:
                raise ValueError(self.attn_pattern)
        return tuple(out)

    @property
    def sub_quadratic(self) -> bool:
        """Whether long_500k is runnable (DESIGN §3 skip policy)."""
        if self.layout in ("rwkv", "mamba_hybrid"):
            return True
        return self.attn_pattern in ("swa", "local_global")

    # -- parameter counting (for MODEL_FLOPS = 6·N·D roofline ratio) -----------

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        embed = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.layout in ("dense", "moe", "encdec"):
            attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            per_layer += attn + 2 * d  # + norms
        if self.layout == "dense" or self.layout == "encdec":
            per_layer += 3 * d * f     # SwiGLU
        elif self.layout == "moe":
            per_layer += self.n_experts * 3 * d * self.moe_d_ff
            per_layer += self.n_shared_experts * 3 * d * self.moe_d_ff
            per_layer += d * self.n_experts  # router
        elif self.layout == "rwkv":
            di = self.ssm_expand * d  # rwkv: d_ff channel-mix + time-mix proj
            per_layer += 4 * d * d + d * self.d_ff * 2 + 8 * d
        elif self.layout == "mamba_hybrid":
            di = self.ssm_expand * d
            per_layer += d * (2 * di + 2 * self.n_heads * 0)  # in_proj (x,z)
            per_layer += 2 * d * di + di * d + di * self.ssm_conv  # in/out/conv
            per_layer += di * 2  # dt, A params (per-head-ish, negligible)
        n = embed + self.n_layers * per_layer
        if self.layout == "mamba_hybrid":
            # one shared full attention block + its ffn
            n += 4 * d * self.n_heads * self.head_dim + 3 * d * f
        if self.layout == "encdec":
            # encoder stack + cross attention in decoder
            enc = self.n_encoder_layers * (4 * d * d + 3 * d * f + 2 * d)
            cross = self.n_layers * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d)
            n += enc + cross
        return int(n)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared only)."""
        if self.layout != "moe":
            return self.param_count()
        d = self.d_model
        dense_part = self.param_count() - self.n_layers * (
            (self.n_experts - self.top_k) * 3 * d * self.moe_d_ff)
        return int(dense_part)

    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def jnp_param_dtype(self):
        return jnp.dtype(self.param_dtype)


def scaled_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    base = dict(
        n_layers=max(2, min(4, cfg.n_layers // 16)),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        max_seq_len=512,
        window=64,
        attn_chunk=64,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 16) if cfg.n_frontend_tokens else 0,
        remat=False,
    )
    if cfg.layout == "moe":
        base.update(n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
                    moe_d_ff=64, n_shared_experts=min(cfg.n_shared_experts, 2))
    if cfg.layout in ("mamba_hybrid",):
        base.update(ssm_state=16, attn_every=2, n_layers=5)
    if cfg.layout == "encdec":
        base.update(n_encoder_layers=2)
    if cfg.layout == "rwkv":
        base.update(n_heads=4, head_dim=32, d_model=128)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
