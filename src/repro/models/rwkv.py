"""RWKV6 ("Finch") block — data-dependent decay linear attention.

Time-mix with per-channel data-dependent decay w_t = exp(-exp(base + lora(x)))
and bonus u for the current token; chunked WKV scan for prefill (chunk=32,
fp32 — the exp(±cum) factorization is safe at that chunk length) and exact
O(1)-state decode.  Channel-mix is the squared-ReLU RWKV FFN.

State per layer: (shift_tm (B,d), shift_cm (B,d), wkv (B,H,K,V)).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, sds

RWKV_HEAD_DIM = 64
LORA_RANK = 32


class RwkvLayerState(NamedTuple):
    shift_tm: jax.Array   # (B, d) last token seen by time-mix
    shift_cm: jax.Array   # (B, d) last token seen by channel-mix
    wkv: jax.Array        # (B, H, K, V) linear-attention state


def rwkv_dims(cfg: ModelConfig) -> Tuple[int, int]:
    n_heads = cfg.d_model // RWKV_HEAD_DIM
    return n_heads, RWKV_HEAD_DIM


def rwkv_shapes(cfg: ModelConfig) -> Params:
    dt = cfg.param_dtype
    d, f = cfg.d_model, cfg.d_ff
    return {
        # time-mix interpolation vectors (μ) for r,k,v,w,g
        "mu_r": sds((d,), dt), "mu_k": sds((d,), dt), "mu_v": sds((d,), dt),
        "mu_w": sds((d,), dt), "mu_g": sds((d,), dt),
        "wr": sds((d, d), dt), "wk": sds((d, d), dt), "wv": sds((d, d), dt),
        "wg": sds((d, d), dt), "wo_tm": sds((d, d), dt),
        "decay_base": sds((d,), "float32"),
        "lora_a_decay": sds((d, LORA_RANK), dt),
        "lora_b_decay": sds((LORA_RANK, d), dt),
        "bonus_u": sds((d,), "float32"),
        "ln_x": sds((d,), dt),
        # channel-mix
        "mu_ck": sds((d,), dt), "mu_cr": sds((d,), dt),
        "ck": sds((d, f), dt), "cv": sds((f, d), dt), "cr": sds((d, d), dt),
    }


def _token_shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """Previous-token stream: [last, x_0, ..., x_{S-2}]."""
    return jnp.concatenate([last[:, None].astype(x.dtype), x[:, :-1]], axis=1)


def _mix(x, xx, mu):
    return x + (xx - x) * mu[None, None]


def _decay(params: Params, xw: jax.Array, dt) -> jax.Array:
    lora = jnp.tanh(xw @ params["lora_a_decay"].astype(dt)) @ params[
        "lora_b_decay"].astype(dt)
    logw = -jnp.exp(params["decay_base"][None, None] + lora.astype(jnp.float32))
    return logw  # (B, S, d) log decay, <= 0


def wkv_chunked(r, k, v, logw, u, chunk: int = 128,
                s0: Optional[jax.Array] = None):
    """chunk=128 (§Perf iteration 3): the scan body's small cross-shard
    gathers are charged per trip — 4× fewer trips cut rwkv6 train collective
    traffic ~4×.  The exp(±cumsum) factorization stays in f32 range because
    per-step |log w| ≤ exp(-3) by the decay parameterization."""
    """Chunked WKV. r,k,v,logw: (B,S,H,K); u: (H,K). Returns (y, s_fin)."""
    b, s, h, kd = r.shape
    L = min(chunk, s)
    if s % L:
        L = s
    nc = s // L
    shp = (b, nc, L, h, kd)
    # keep storage dtype outside the scan; cast per-chunk inside the body
    rc, kc, vc, wc = (a.reshape(shp) for a in (r, k, v, logw))

    tri_lower = jnp.tril(jnp.ones((L, L), bool), k=-1)   # strictly lower: s < t

    def chunk_step(S_in, inputs):
        rb, kb, vb, wb = inputs                          # (b,L,h,k)
        rb, kb, vb, wb = (a.astype(jnp.float32) for a in (rb, kb, vb, wb))
        cum = jnp.cumsum(wb, axis=1)                     # inclusive Σ log w
        cum_prev = cum - wb                              # Σ_{j<t} log w_j
        # inter-chunk: y_inter[t] = Σ_k r[t,k]·exp(cum_prev[t,k])·S_in[k,v]
        r_dec = rb * jnp.exp(cum_prev)
        y_inter = jnp.einsum("blhk,bhkv->blhv", r_dec, S_in)
        # intra-chunk (s<t): exp(cum_prev_t - cum_s) = exp(cum_prev_t)·exp(-cum_s)
        a = rb * jnp.exp(cum_prev)                       # (b,t,h,k)
        b_ = kb * jnp.exp(-cum)                          # (b,s,h,k)
        att = jnp.einsum("bthk,bshk->bhts", a, b_)
        att = jnp.where(tri_lower[None, None], att, 0.0)
        y_intra = jnp.einsum("bhts,bshv->bthv", att, vb)
        # diagonal (current token) with bonus u: (Σ_k r_k·u_k·k_k) · v
        y_diag = jnp.einsum("blhk,blhk,blhv->blhv", rb * u[None, None], kb, vb)
        # state update: S_out = S_in·exp(cum_L) + Σ_s exp(cum_L - cum_s)·k_s⊗v_s
        k_dec = kb * jnp.exp(cum[:, -1:, :, :] - cum)
        S_out = S_in * jnp.exp(cum[:, -1])[..., None] + jnp.einsum(
            "bshk,bshv->bhkv", k_dec, vb)
        return S_out, y_inter + y_intra + y_diag

    if s0 is None:
        s0 = jnp.zeros((b, h, kd, kd), jnp.float32)
    S_fin, ys = jax.lax.scan(chunk_step, s0,
                             tuple(jnp.moveaxis(a, 1, 0) for a in (rc, kc, vc, wc)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, kd)
    return y.astype(r.dtype), S_fin


def wkv_decode(r, k, v, logw, u, S_in):
    """Single step. r,k,v,logw: (B,1,H,K); S_in: (B,H,K,V)."""
    rf, kf, vf = (a[:, 0].astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(logw[:, 0].astype(jnp.float32))
    # y = r·(S_in + u⊙k ⊗ v)
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    y = jnp.einsum("bhk,bhkv->bhv", rf, S_in + u[None, :, :, None] * kv)
    S_out = S_in * w[..., None] + kv
    return y[:, None].astype(r.dtype), S_out


def _tm_project(params: Params, x: jax.Array, shift: jax.Array,
                cfg: ModelConfig):
    h, kd = rwkv_dims(cfg)
    dt = cfg.jnp_dtype()
    xx = _token_shift(x, shift) if x.shape[1] > 1 else shift[:, None].astype(x.dtype)
    r = _mix(x, xx, params["mu_r"].astype(dt)) @ params["wr"].astype(dt)
    k = _mix(x, xx, params["mu_k"].astype(dt)) @ params["wk"].astype(dt)
    v = _mix(x, xx, params["mu_v"].astype(dt)) @ params["wv"].astype(dt)
    g = _mix(x, xx, params["mu_g"].astype(dt)) @ params["wg"].astype(dt)
    xw = _mix(x, xx, params["mu_w"].astype(dt))
    logw = _decay(params, xw, dt)
    b, s, d = x.shape
    split = lambda a: a.reshape(b, s, h, kd)
    u = params["bonus_u"].reshape(h, kd)
    return split(r), split(k), split(v), split(logw.astype(jnp.float32)), g, u


def rwkv_time_mix(params: Params, x: jax.Array, state: RwkvLayerState,
                  cfg: ModelConfig, decode: bool = False, shard=None):
    b, s, d = x.shape
    dt = cfg.jnp_dtype()
    r, k, v, logw, g, u = _tm_project(params, x, state.shift_tm, cfg)
    if shard is not None:
        # keep the WKV scan head-local: without these constraints SPMD
        # re-gathers full (B,S,H,K) activations every layer (§Perf log:
        # 9.7 GB/chip/layer of all-gather on rwkv6 train_4k)
        r, k, v, logw = (shard(a, "act_bshd") for a in (r, k, v, logw))
    if decode:
        y, wkv = wkv_decode(r, k, v, logw, u, state.wkv)
    elif cfg.use_pallas:
        from repro.kernels.rwkv6 import ops as wkv_ops
        y, wkv = wkv_ops.wkv(r, k, v, logw, u, s0=state.wkv)
    else:
        y, wkv = wkv_chunked(r, k, v, logw, u, s0=state.wkv)
    y = y.reshape(b, s, d)
    # per-head group norm (ln_x) then gate
    y32 = y.astype(jnp.float32).reshape(b, s, -1, RWKV_HEAD_DIM)
    mean = jnp.mean(y32, axis=-1, keepdims=True)
    var = jnp.var(y32, axis=-1, keepdims=True)
    y = ((y32 - mean) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, d).astype(dt)
    y = y * params["ln_x"].astype(dt)[None, None]
    y = y * jax.nn.silu(g)
    out = y @ params["wo_tm"].astype(dt)
    new_state = state._replace(shift_tm=x[:, -1].astype(state.shift_tm.dtype),
                               wkv=wkv)
    return out, new_state


def rwkv_channel_mix(params: Params, x: jax.Array, state: RwkvLayerState,
                     cfg: ModelConfig):
    dt = cfg.jnp_dtype()
    xx = (_token_shift(x, state.shift_cm) if x.shape[1] > 1
          else state.shift_cm[:, None].astype(x.dtype))
    k = _mix(x, xx, params["mu_ck"].astype(dt)) @ params["ck"].astype(dt)
    kv = jnp.square(jax.nn.relu(k)) @ params["cv"].astype(dt)
    r = jax.nn.sigmoid(_mix(x, xx, params["mu_cr"].astype(dt)) @ params["cr"].astype(dt))
    new_state = state._replace(shift_cm=x[:, -1].astype(state.shift_cm.dtype))
    return r * kv, new_state


def init_rwkv_state(cfg: ModelConfig, batch: int) -> RwkvLayerState:
    h, kd = rwkv_dims(cfg)
    return RwkvLayerState(
        shift_tm=jnp.zeros((batch, cfg.d_model), jnp.float32),
        shift_cm=jnp.zeros((batch, cfg.d_model), jnp.float32),
        wkv=jnp.zeros((batch, h, kd, kd), jnp.float32))
