"""Unified model API over every architecture family.

One surface for the training loop, the serving engine, and the dry-run:

    param_shapes(cfg)                     ShapeDtypeStruct param tree
    init_params(cfg, key)                 materialized params
    forward(params, batch, cfg, shard)    full-sequence logits
    loss_fn(params, batch, cfg, shard)    chunked-CE loss (+ MoE aux)
    prefill(params, batch, cfg, shard)    last-position logits + (no cache)
    cache_shapes / init_cache             decode cache pytrees
    serve_step(params, token, cache, cfg) one-token decode
    prefill_chunk(params, toks, cache, …) C-token prompt slab into the cache
    splice_prefix(cache, slot, k, v)      reused prompt-prefix KV into a slot
    supports_chunked_prefill(cfg)         which layouts take the chunked path

``batch`` is a dict: tokens/labels (+ frames for enc-dec audio,
frontend_embeddings for vlm).  Dispatch on ``cfg.layout``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.config import ModelConfig
from repro.models.layers import Params, rms_norm, unembed
from repro.models.lm import ForwardOut, ShardFn, _id_shard

Batch = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_shapes(cfg: ModelConfig) -> Params:
    if cfg.layout == "encdec":
        return encdec.encdec_shapes(cfg)
    return lm.lm_shapes(cfg)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    if cfg.layout == "encdec":
        return encdec.init_encdec(cfg, key)
    return lm.init_lm(cfg, key)


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def forward(params: Params, batch: Batch, cfg: ModelConfig,
            shard: ShardFn = _id_shard) -> ForwardOut:
    if cfg.layout == "encdec":
        return encdec.forward(params, batch["frames"], batch["tokens"], cfg,
                              shard)
    return lm.forward(params, batch["tokens"], cfg, shard,
                      frontend_embeddings=batch.get("frontend_embeddings"))


def chunked_cross_entropy(x: jax.Array, params: Params, labels: jax.Array,
                          cfg: ModelConfig, chunk: int = 512) -> jax.Array:
    """Cross-entropy from *final hidden states* with sequence chunking.

    Materializing (B, S, V) fp32 logits for a 262k vocab at 4k×256 is ~4 TB;
    scanning over S-chunks caps the live logits at (B, chunk, V_shard).
    x: (B, S, d) final normed hiddens; labels: (B, S) targets.
    """
    b, s, _ = x.shape
    n = max(s // chunk, 1)
    if s % n:
        n = 1
    c = s // n
    xc = jnp.moveaxis(x.reshape(b, n, c, -1), 1, 0)       # (n, B, c, d)
    yc = jnp.moveaxis(labels.reshape(b, n, c), 1, 0)      # (n, B, c)

    def step(tot, xs):
        xb, yb = xs
        logits = unembed(params["tok"], xb, cfg.jnp_dtype()).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (xc, yc))
    return total / (b * s)


def loss_fn(params: Params, batch: Batch, cfg: ModelConfig,
            shard: ShardFn = _id_shard, aux_weight: float = 0.01,
            loss_chunk: int = 512) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token CE (labels aligned with tokens) + MoE aux loss."""
    labels = batch["labels"]
    if cfg.layout == "encdec":
        hidden, aux = encdec.forward_hidden(params, batch["frames"],
                                            batch["tokens"], cfg, shard)
    else:
        hidden, aux = lm.forward_hidden(
            params, batch["tokens"], cfg, shard,
            frontend_embeddings=batch.get("frontend_embeddings"))
    st = labels.shape[1]
    hidden_text = hidden[:, -st:]              # drop frontend positions
    ce = chunked_cross_entropy(hidden_text, params, labels, cfg,
                               chunk=loss_chunk)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(params: Params, batch: Batch, cfg: ModelConfig,
            shard: ShardFn = _id_shard) -> jax.Array:
    """Returns next-token logits for the *last* position only (B, V) —
    serving never materializes the full (B, S, V) logits tensor.

    This one-shot form recomputes from scratch and fills no cache; the
    serving engine uses ``prefill_chunk`` below, which populates the
    decode cache slab-by-slab at per-slot offsets."""
    if cfg.layout == "encdec":
        hidden, _ = encdec.forward_hidden(params, batch["frames"],
                                          batch["tokens"], cfg, shard)
    else:
        hidden, _ = lm.forward_hidden(
            params, batch["tokens"], cfg, shard,
            frontend_embeddings=batch.get("frontend_embeddings"))
    return unembed(params["tok"], hidden[:, -1:], cfg.jnp_dtype())[:, 0]


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    if cfg.layout == "encdec":
        return encdec.cache_shapes(cfg, batch, max_len)
    return lm.cache_shapes(cfg, batch, max_len)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    if cfg.layout == "encdec":
        return encdec.init_cache(cfg, batch, max_len)
    return lm.init_cache(cfg, batch, max_len)


def serve_step(params: Params, token: jax.Array, cache: Params,
               cfg: ModelConfig, shard: ShardFn = _id_shard
               ) -> Tuple[jax.Array, Params]:
    """One new token against a seq_len-deep cache: (logits (B,1,V), cache)."""
    if cfg.layout == "encdec":
        return encdec.decode_step(params, token, cache, cfg, shard)
    return lm.decode_step(params, token, cache, cfg, shard)


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Whether ``prefill_chunk`` exists for this architecture family.

    True for the attention-cached layouts (dense / moe / encdec) whose
    decode cache is a full-depth positional KV store — a prompt slab can
    be scattered in at arbitrary per-slot offsets.  Recurrent layouts
    (rwkv, mamba_hybrid) carry per-token state whose batched chunking
    needs per-token activity gating inside the scan, and ring-buffer
    (windowed) caches would overwrite in-window keys mid-chunk; both keep
    the one-token path (the engine additionally checks the materialized
    cache for a ``k`` entry to exclude ring layouts).
    """
    return cfg.layout in ("dense", "moe", "encdec")


def splice_prefix(cache: Params, slot: int, k_block, v_block) -> Params:
    """Splice a reused prompt-prefix KV block into one decode slot.

    ``k_block``/``v_block``: (L, P, Hk, hd) KV captured from a completed
    prompt whose first P tokens match this slot's prompt.  Valid exactly
    where chunked prefill is (full-depth positional ``k``/``v`` caches —
    dense/moe/encdec; the enc-dec splice covers the decoder self-KV only,
    cross-KV is per-request).  The slot's length is set to P, so the
    engine's next chunk step continues prefill at offset P — the cached
    prefix is never recomputed.
    """
    if "k" not in cache:
        raise ValueError(
            f"prefix splice needs a full-depth positional KV cache; got "
            f"cache keys {sorted(cache)} (ring-buffer and recurrent "
            f"layouts recompute their prompts)")
    from repro.models import attention as attn
    k_c, v_c = attn.splice_kv(cache["k"], cache["v"], slot, k_block, v_block)
    length = cache["length"].at[slot].set(k_block.shape[1])
    return dict(cache, k=k_c, v=v_c, length=length)


def prefill_chunk(params: Params, tokens: jax.Array, cache: Params,
                  cfg: ModelConfig, n_active: jax.Array,
                  shard: ShardFn = _id_shard) -> Tuple[jax.Array, Params]:
    """Populate the decode cache with a (B, C) slab of prompt tokens at
    per-slot offsets ``cache["length"]``; ``n_active`` (B,) gates how many
    of the C positions are real per slot (0 = idle slot this step).

    Returns (logits (B, C, V), new cache).  The logits at position
    n_active[b]-1 are the next-token logits slot b would have produced by
    feeding the same tokens one at a time through ``serve_step`` — the
    chunked/one-shot equivalence asserted by tests/test_prefill_chunk.py.
    """
    if cfg.layout == "encdec":
        return encdec.prefill_chunk_step(params, tokens, cache, cfg,
                                         n_active, shard)
    return lm.prefill_chunk_step(params, tokens, cache, cfg, n_active, shard)
