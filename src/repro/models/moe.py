"""Mixture-of-Experts: sort-based capacity-bounded dispatch + shared experts.

Dispatch strategy (TPU-native, DESIGN §5): tokens are *sorted by assigned
expert* and regrouped into an (E, C, d) tensor, experts run as one batched
einsum, and results are scattered back.  FLOPs scale with top_k (not with
n_experts), so the roofline MODEL_FLOPS/HLO_FLOPs ratio stays honest — a
one-hot-einsum MoE would inflate compiled FLOPs by E/top_k.

Sharding: experts are small for the assigned archs (grok: 8, qwen2-moe: 60),
so we use expert-tensor-parallelism — every device holds all experts with the
expert hidden dim sharded over the "model" axis, and tokens stay local to
their "data" shard.  This avoids all-to-all entirely; the only collective is
the same psum a dense TP MLP needs.  (An all-to-all EP variant is evaluated
in EXPERIMENTS §Perf.)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import Params, mlp, mlp_shapes, sds


def moe_shapes(cfg: ModelConfig) -> Params:
    dt = cfg.param_dtype
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    shapes = {
        "router": sds((d, e), dt),
        "wi_gate": sds((e, d, f), dt),
        "wi_up": sds((e, d, f), dt),
        "wo": sds((e, f, d), dt),
    }
    if cfg.n_shared_experts:
        shapes["shared"] = mlp_shapes(d, f * cfg.n_shared_experts, dt)
    return shapes


def top_k_gating(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Returns (weights (T,k) softmaxed over the chosen k, indices (T,k))."""
    gates, idx = jax.lax.top_k(logits, k)
    weights = jax.nn.softmax(gates, axis=-1)
    return weights, idx


def expert_capacity(n_tokens: int, n_experts: int, top_k: int,
                    capacity_factor: float) -> int:
    c = int(n_tokens * top_k * capacity_factor / n_experts)
    return max(((c + 7) // 8) * 8, 8)  # pad to 8 for TPU lane alignment


def _dispatch_group(params: Params, xf: jax.Array, cfg: ModelConfig,
                    use_pallas: bool) -> Tuple[jax.Array, jax.Array]:
    """One dispatch group.  xf: (t, d) -> (out (t, d), aux_loss scalar).

    Sort-based dispatch: route, sort by expert, bucket into (E, C, d) with
    capacity C; overflow tokens are dropped (standard capacity-bounded MoE;
    capacity_factor gives slack).
    """
    t, d = xf.shape
    dt = cfg.jnp_dtype()
    e, k = cfg.n_experts, cfg.top_k

    logits = jnp.einsum("td,de->te", xf, params["router"].astype(dt)).astype(jnp.float32)
    if use_pallas:
        from repro.kernels.moe_gating import ops as gate_ops
        weights, idx = gate_ops.topk_gating(logits, k)
    else:
        weights, idx = top_k_gating(logits, k)

    # load-balancing auxiliary loss (Switch-style): E * Σ_e f_e · p_e
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=0)
    one_hot_top1 = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux_loss = e * jnp.sum(me * ce)

    c = expert_capacity(t, e, k, cfg.capacity_factor)

    # --- sort-based dispatch -------------------------------------------------
    # The serving chunked-prefill path feeds slabs whose *trailing* rows may
    # be padding (lm.prefill_chunk_step masks them out afterwards).  Two
    # properties keep padding from ever evicting a real token here: the
    # argsort below is STABLE (jnp default), so within an expert group
    # earlier slab positions — the real tokens, always a prefix — rank
    # first; and expert_capacity lane-pads to >= 8 >= t for slabs of <= 8,
    # so capacity cannot bind at the default chunk size at all.  Guarded by
    # tests/test_prefill_chunk.py::test_moe_mixed_tick_padding_is_harmless.
    flat_expert = idx.reshape(-1)                      # (t*k,)
    flat_token = jnp.repeat(jnp.arange(t), k)          # source token per slot
    flat_weight = weights.reshape(-1)
    order = jnp.argsort(flat_expert)                   # group by expert
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_weight = flat_weight[order]
    # rank within expert group = position - first position of that expert
    expert_start = jnp.searchsorted(sorted_expert, jnp.arange(e))
    rank = jnp.arange(t * k) - expert_start[sorted_expert]
    keep = rank < c
    slot = sorted_expert * c + rank                    # flat (e*c) bucket slot
    slot = jnp.where(keep, slot, e * c)                # overflow -> scratch row

    gathered = xf[sorted_token]                                      # (t*k, d)
    buckets = jnp.zeros((e * c + 1, d), dt).at[slot].set(
        jnp.where(keep[:, None], gathered, 0))
    buckets = buckets[:-1].reshape(e, c, d)

    # --- batched expert FFN ---------------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buckets, params["wi_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", buckets, params["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(dt))

    # --- combine (scatter back with gate weights) -----------------------------
    flat_out = expert_out.reshape(e * c, d)
    contrib = flat_out[jnp.minimum(slot, e * c - 1)] * (
        sorted_weight * keep).astype(dt)[:, None]
    out = jnp.zeros((t, d), dt).at[sorted_token].add(contrib)
    return out, aux_loss


def moe_block(params: Params, x: jax.Array, cfg: ModelConfig,
              use_pallas: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Dispatch is *grouped per batch row* for S > 1 (prefill/train): capacity is
    computed within each sequence and the sort/scatter stays local to the
    row, so SPMD partitioning along the (sharded) batch dim never needs a
    global argsort/all-gather.  Decode (S == 1) routes all rows as one group
    — the token count is tiny there.
    """
    b, s, d = x.shape
    if s > 1:
        out, aux = jax.vmap(
            lambda xg: _dispatch_group(params, xg, cfg, use_pallas))(
                x.reshape(b, s, d))
        aux_loss = jnp.mean(aux)
    else:
        out, aux_loss = _dispatch_group(params, x.reshape(b * s, d), cfg,
                                        use_pallas)
        out = out.reshape(b, s, d)

    if cfg.n_shared_experts:
        out = out + mlp(params["shared"], x.reshape(b, s, d), cfg.jnp_dtype())
    return out.reshape(b, s, d), aux_loss
