"""Fused top-k softmax gating kernel (Pallas TPU).

One pass over a (bt, E) tile of router logits held in VMEM: k iterative
argmax sweeps (k ≤ 4 for every assigned arch — grok top-2, qwen2-moe top-4)
select the experts, then the selected gates are softmaxed in-register.  This
fuses what XLA otherwise lowers as top_k sort + gather + softmax — three
HBM round-trips over the (T, E) logits — into one.

E stays un-tiled (60 experts max — a single lane tile); T is the grid axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro import compat

NEG_INF = -1e30


def _gate_kernel(x_ref, w_ref, i_ref, *, k: int, bt: int, e: int):
    x = x_ref[...].astype(jnp.float32)                  # (bt, E)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, e), 1)
    gates = []
    idxs = []
    for _ in range(k):                                  # k argmax sweeps
        m = jnp.max(x, axis=-1, keepdims=True)          # (bt, 1)
        hit = x == m
        # tie-break to the lowest expert index, as lax.top_k does
        first = jnp.min(jnp.where(hit, cols, e), axis=-1, keepdims=True)
        gates.append(m)
        idxs.append(first)
        x = jnp.where(cols == first, NEG_INF, x)
    g = jnp.concatenate(gates, axis=-1)                 # (bt, k)
    ix = jnp.concatenate(idxs, axis=-1)
    p = jnp.exp(g - g[:, :1])                           # max is first
    w_ref[...] = (p / jnp.sum(p, axis=-1, keepdims=True)).astype(w_ref.dtype)
    i_ref[...] = ix.astype(jnp.int32)


def topk_gating_fwd(logits: jax.Array, k: int, bt: int,
                    interpret: bool):
    t, e = logits.shape
    kernel = functools.partial(_gate_kernel, k=k, bt=bt, e=e)
    return pl.pallas_call(
        kernel,
        grid=(t // bt,),
        in_specs=[pl.BlockSpec((bt, e), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bt, k), lambda i: (i, 0)),
                   pl.BlockSpec((bt, k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((t, k), jnp.float32),
                   jax.ShapeDtypeStruct((t, k), jnp.int32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(logits)
