"""jit'd public wrapper for the fused top-k gating kernel."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.moe_gating.kernel import topk_gating_fwd


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b:
        b -= 1
    return b


def topk_gating(logits: jax.Array, k: int, block_t: int = 256,
                interpret: Optional[bool] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """logits: (T, E) → (weights (T, k) f32, indices (T, k) i32)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bt = _pick_block(logits.shape[0], block_t)
    w, i = topk_gating_fwd(logits.astype(jnp.float32), k, bt, interpret)
    return w, i
