from repro.kernels.moe_gating import ops, ref
from repro.kernels.moe_gating.ops import topk_gating

__all__ = ["ops", "ref", "topk_gating"]
