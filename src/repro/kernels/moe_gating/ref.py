"""Pure-jnp oracle for the fused top-k gating kernel."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk_gating_ref(logits: jnp.ndarray, k: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """logits: (T, E) f32 → (weights (T, k) softmaxed over the top-k,
    indices (T, k) int32), descending by logit."""
    gates, idx = jax.lax.top_k(logits, k)
    return jax.nn.softmax(gates, axis=-1), idx.astype(jnp.int32)
