"""jit'd public wrapper for the RWKV6 WKV kernel."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv_fwd


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b:
        b -= 1
    return b


def wkv(r: jax.Array, k: jax.Array, v: jax.Array, logw: jax.Array,
        u: jax.Array, s0: Optional[jax.Array] = None, chunk: int = 32,
        interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV scan: returns (y (B,S,H,K), final state (B,H,K,K) f32)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, h, kd = r.shape
    if s0 is None:
        s0 = jnp.zeros((b, h, kd, kd), jnp.float32)
    c = _pick_block(s, chunk)
    return wkv_fwd(r, k, v, logw, u, s0, chunk=c, interpret=interpret)
