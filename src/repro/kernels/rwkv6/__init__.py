from repro.kernels.rwkv6 import ops, ref
from repro.kernels.rwkv6.ops import wkv

__all__ = ["ops", "ref", "wkv"]
