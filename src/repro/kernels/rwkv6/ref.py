"""Pure-jnp oracle for the RWKV6 WKV kernel: exact sequential recurrence."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def wkv_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
            logw: jnp.ndarray, u: jnp.ndarray,
            s0: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-by-token WKV recurrence (the definitional form).

    r,k,v,logw: (B, S, H, K); u: (H, K); s0: (B, H, K, K) or None.
        y_t = r_t · (S + u ⊙ k_t ⊗ v_t);  S ← S·exp(logw_t)[:,None] + k_t ⊗ v_t
    """
    b, s, h, kd = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, logw))
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((b, h, kd, kd), jnp.float32)

    def step(S, xs):
        rt, kt, vt, wt = xs            # (B, H, K)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + uf[None, :, :, None] * kv)
        S = S * jnp.exp(wt)[..., None] + kv
        return S, y

    S_fin, ys = jax.lax.scan(
        step, s0, tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf)))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), S_fin
