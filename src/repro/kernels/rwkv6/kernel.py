"""Chunked RWKV6 WKV scan kernel (Pallas TPU).

Grid = (batch, heads, n_chunks); the chunk axis is sequential and the
(K, V) linear-attention state lives in f32 VMEM scratch.  Inside a chunk
the recurrence is factored into three MXU matmuls (inter-chunk, intra-chunk
lower-triangular, diagonal-bonus) using the exp(±cumsum log w)
factorization — safe at chunk length 32–64 because per-step |log w| is
bounded by the decay parameterization.

This adapts the CUDA wkv6 kernel's warp-per-head layout to the TPU: one
grid cell per (batch, head), chunk loop in-core, state never leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                s_ref, *, chunk: int, kd: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0, :, :].astype(jnp.float32)

    rb = r_ref[0, :, 0, :].astype(jnp.float32)          # (L, K)
    kb = k_ref[0, :, 0, :].astype(jnp.float32)
    vb = v_ref[0, :, 0, :].astype(jnp.float32)
    wb = w_ref[0, :, 0, :].astype(jnp.float32)          # log-decay ≤ 0
    u = u_ref[0, :].astype(jnp.float32)                 # (K,)
    S = s_ref[...]                                      # (K, V=K)

    cum = jnp.cumsum(wb, axis=0)                        # inclusive Σ log w
    cum_prev = cum - wb
    r_dec = rb * jnp.exp(cum_prev)
    # inter-chunk: y_t += (r_t ⊙ exp(cum_prev_t)) @ S
    y_inter = jax.lax.dot_general(r_dec, S, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # intra-chunk (s < t): att[t,s] = Σ_k r_dec[t,k] · k[s,k]·exp(-cum[s,k])
    k_dec = kb * jnp.exp(-cum)
    att = jax.lax.dot_general(r_dec, k_dec, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (L, L)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(cols < rows, att, 0.0)
    y_intra = jax.lax.dot_general(att, vb, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # diagonal with bonus u: y_t += (Σ_k r_t·u·k_t) · v_t
    coeff = jnp.sum(rb * u[None, :] * kb, axis=-1, keepdims=True)
    y = y_inter + y_intra + coeff * vb
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state: S ← S·exp(cum_L)[:,None] + Σ_s (k_s·exp(cum_L - cum_s)) ⊗ v_s
    k_carry = kb * jnp.exp(cum[-1:, :] - cum)
    S = S * jnp.exp(cum[-1])[:, None] + jax.lax.dot_general(
        k_carry, vb, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    s_ref[...] = S

    @pl.when(ci == nc - 1)
    def _finish():
        sout_ref[0, 0, :, :] = S


def wkv_fwd(r, k, v, logw, u, s0, chunk: int, interpret: bool):
    """r,k,v,logw: (B, S, H, K); u: (H, K); s0: (B, H, K, K) f32."""
    b, s, h, kd = r.shape
    grid = (b, h, s // chunk)
    seq_spec = pl.BlockSpec((1, chunk, 1, kd), lambda bb, hh, ci: (bb, ci, hh, 0))
    kernel = functools.partial(_wkv_kernel, chunk=chunk, kd=kd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, kd), lambda bb, hh, ci: (hh, 0)),
                  pl.BlockSpec((1, 1, kd, kd), lambda bb, hh, ci: (bb, hh, 0, 0))],
        out_specs=[seq_spec,
                   pl.BlockSpec((1, 1, kd, kd), lambda bb, hh, ci: (bb, hh, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct(r.shape, r.dtype),
                   jax.ShapeDtypeStruct((b, h, kd, kd), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((kd, kd), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, logw, u, s0)
