"""Pure-jnp oracle for the flash-attention prefill kernel.

Unblocked O(S²) attention with GQA, causal masking, and a sliding window —
the numerical ground truth every kernel variant is asserted against.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -2.3819763e38


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  window: int, causal: bool = True,
                  q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Sq, Hq, hd); k, v: (B, Sk, Hk, hd).  ``window`` counts visible
    past positions including self; window >= Sk ⇒ full attention."""
    b, sq, hq, hd = q.shape
    sk, hk = k.shape[1], k.shape[2]
    group = hq // hk
    qf = q.astype(jnp.float32) * (hd ** -0.5)
    qf = qf.reshape(b, sq, hk, group, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, sq, hq, hd).astype(q.dtype)
