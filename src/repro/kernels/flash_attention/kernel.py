"""Flash-attention prefill kernel (Pallas TPU).

Grid = (batch, q_heads, q_blocks, kv_blocks); the kv dimension is the
innermost sequential ("arbitrary") axis so the online-softmax running state
(acc, m, l) lives in VMEM scratch across kv iterations.  GQA is handled in
the k/v index maps (kv head = q head // group), the causal + sliding-window
mask is computed from broadcasted iotas, and ``window`` arrives as a dynamic
SMEM scalar so gemma3's per-layer local/global windows work under one
compiled kernel.

Fully-masked kv blocks are skipped with ``pl.when`` (their DMAs still run —
grid pruning with a *dynamic* window isn't expressible; noted in §Perf).

VMEM working set per grid step: q/k/v/o tiles (bq+2·bk+bq)·hd·2B plus
(bq·hd + 2·bq·128) f32 scratch — e.g. bq=bk=512, hd=128: ~1.1 MB, well
under the ~16 MB v5e VMEM budget, with MXU-aligned (≥128) matmul dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -2.3819763e38
LANES = 128   # TPU lane width: running stats are stored (bq, LANES)


def _fa_kernel(win_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
               *, bq: int, bk: int, causal: bool, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    win = win_ref[0]
    q_first = qi * bq                   # first q position of this block
    k_first = ki * bk
    # block visibility: any (q, k) pair with k <= q (causal) and k > q - win
    visible = (k_first + bk - 1) > (q_first - win)
    if causal:
        visible &= k_first <= (q_first + bq - 1)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        q_pos = q_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos > q_pos - win
        if causal:
            mask &= k_pos <= q_pos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                                   # (bq, 1)
        l_prev = l_ref[:, :1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)                          # (bq, 1)
        l_ref[...] = jnp.broadcast_to(l_prev * corr +
                                      jnp.sum(p, axis=-1, keepdims=True),
                                      l_ref.shape)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        window: jax.Array, bq: int, bk: int,
                        causal: bool, interpret: bool) -> jax.Array:
    """q: (B, Sq, Hq, hd); k, v: (B, Sk, Hk, hd); window: i32[1] (dynamic)."""
    b, sq, hq, hd = q.shape
    sk, hk = k.shape[1], k.shape[2]
    group = hq // hk
    grid = (b, hq, sq // bq, sk // bk)

    kernel = functools.partial(_fa_kernel, bq=bq, bk=bk, causal=causal,
                               scale=hd ** -0.5)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bq, 1, hd), lambda bb, h, qi, ki: (bb, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bb, h, qi, ki: (bb, ki, h // group, 0)),
            pl.BlockSpec((1, bk, 1, hd),
                         lambda bb, h, qi, ki: (bb, ki, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd),
                               lambda bb, h, qi, ki: (bb, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
            pltpu.VMEM((bq, LANES), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(window, q, k, v)
