"""jit'd public wrapper for the flash-attention prefill kernel."""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (block shapes must tile)."""
    b = min(n, target)
    while n % b:
        b -= 1
    return b


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    window: Union[int, jax.Array], chunk: int = 512,
                    causal: bool = True,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Drop-in for models.attention.flash_prefill (Pallas TPU path).

    q: (B, Sq, Hq, hd); k, v: (B, Sk, Hk, hd); ``window`` may be a traced
    scalar (per-layer local/global windows under one compiled kernel).
    """
    if interpret is None:
        interpret = _interpret_default()
    sq, sk = q.shape[1], k.shape[1]
    bq = _pick_block(sq, chunk)
    bk = _pick_block(sk, chunk)
    win = jnp.asarray(window, jnp.int32).reshape(1)
    return flash_attention_fwd(q, k, v, win, bq=bq, bk=bk, causal=causal,
                               interpret=interpret)
