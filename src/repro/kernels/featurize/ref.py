"""Pure-jnp oracle for the hashed-embedding featurization kernel."""
from __future__ import annotations

import jax.numpy as jnp


def hashed_embed_ref(ids: jnp.ndarray, weights: jnp.ndarray,
                     proj: jnp.ndarray) -> jnp.ndarray:
    """ids/weights: (Q, L) with id −1 = padding; proj: (H, D) → (Q, D)
    unit embeddings: normalize(log1p(scatter_add(weights by id)) @ proj)."""
    q, _ = ids.shape
    h = proj.shape[0]
    w = jnp.where(ids >= 0, weights.astype(jnp.float32), 0.0)
    idx = jnp.clip(ids, 0, h - 1)
    counts = jnp.zeros((q, h), jnp.float32)
    counts = counts.at[jnp.arange(q)[:, None], idx].add(w)
    v = jnp.log1p(counts) @ proj.astype(jnp.float32)
    norm = jnp.linalg.norm(v, axis=-1, keepdims=True)
    return jnp.where(norm > 0.0, v / jnp.maximum(norm, 1e-30), v)
