"""Batched hashed-embedding featurization kernel (Pallas TPU) — the host
encoder of ``core/embedding.py`` as a fused device op.

The router's sentence encoder is a hashed bag-of-features map: blake2-hashed
token/trigram/bigram ids with tf weights, scatter-accumulated into a
``hash_dim`` count vector, sublinear-tf'd with log1p, projected through a
fixed Gaussian matrix, and L2-normalized.  The hashing itself is string work
and stays on host (one vectorized pass building padded ``(Q, L)`` id/weight
tensors); everything after the ids — the scatter, the tf transform, the
projection, the normalization — is dense arithmetic and runs here as one
VMEM pass per Q-block:

    counts[q, h] = Σ_l weights[q, l] · [ids[q, l] == h]      (scatter)
    emb[q]       = normalize(log1p(counts[q]) @ proj)        (tf + project)

The scatter is expressed as a chunked one-hot contraction (compare a
``(bq, lb)`` id tile against the bucket iota and contract the ``lb`` axis)
so it vectorizes on the VPU instead of serializing into per-element stores;
padding rows use id −1, which matches no bucket.  ``hash_dim`` (2048) and
``dim`` (384) are lane-aligned, and ``proj`` (3 MB fp32) stays resident in
VMEM across the whole grid.

log1p(0) = 0, so applying the tf transform unconditionally is exactly the
host encoder's "skip log1p when the text produced no features" branch — an
all-padding row yields the same all-zero embedding on both paths.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro import compat


def _featurize_kernel(ids_ref, w_ref, proj_ref, o_ref, *, hash_dim: int,
                      lb: int):
    ids = ids_ref[...]                                   # (bq, L) int32
    w = w_ref[...].astype(jnp.float32)                   # (bq, L)
    bq, seq_l = ids.shape
    buckets = jax.lax.broadcasted_iota(jnp.int32, (1, 1, hash_dim), 2)
    counts = jnp.zeros((bq, hash_dim), jnp.float32)
    for l0 in range(0, seq_l, lb):                       # static chunk loop
        onehot = (ids[:, l0:l0 + lb, None] == buckets).astype(jnp.float32)
        counts = counts + jnp.einsum("ql,qlh->qh", w[:, l0:l0 + lb], onehot)
    tf = jnp.log1p(counts)
    v = jax.lax.dot(tf, proj_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)  # (bq, dim)
    norm = jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True))
    o_ref[...] = jnp.where(norm > 0.0, v / jnp.maximum(norm, 1e-30),
                           v).astype(o_ref.dtype)


def hashed_embed_fwd(ids, weights, proj, bq: int, lb: int, interpret: bool):
    q, _ = ids.shape
    hash_dim, dim = proj.shape
    kernel = functools.partial(_featurize_kernel, hash_dim=hash_dim, lb=lb)
    return pl.pallas_call(
        kernel,
        grid=(q // bq,),
        in_specs=[
            pl.BlockSpec((bq, ids.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bq, ids.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((hash_dim, dim), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bq, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q, dim), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(ids, weights, proj)
