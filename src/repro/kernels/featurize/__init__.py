from repro.kernels.featurize import ops, ref
from repro.kernels.featurize.ops import hashed_embed

__all__ = ["ops", "ref", "hashed_embed"]
