"""jit'd public wrapper for the hashed-embedding featurization kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.featurize.kernel import hashed_embed_fwd


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b:
        b -= 1
    return b


def pad_pow2(n: int, floor: int = 1) -> int:
    """Next power of two ≥ n (≥ floor) — callers pad batch shapes to this
    so the jit cache holds log2-many program variants, not one per shape."""
    return max(floor, 1 << max(n - 1, 0).bit_length())


# static blocks/interpret: one compiled program per (L, blocks) combination —
# Q and L are both padded to powers of two below, so the jit cache stays
# bounded at log2-many variants instead of one per serving batch shape
@functools.partial(jax.jit, static_argnames=("bq", "lb", "interpret"))
def _embed_jit(ids, weights, proj, bq: int, lb: int, interpret: bool):
    return hashed_embed_fwd(ids, weights, proj, bq=bq, lb=lb,
                            interpret=interpret)


def hashed_embed(ids: jax.Array, weights: jax.Array, proj: jax.Array,
                 block_q: int = 8, block_l: int = 64,
                 interpret: Optional[bool] = None) -> jax.Array:
    """ids/weights: (Q, L), id −1 = padding; proj: (hash_dim, dim) →
    (Q, dim) unit embeddings.  Pads Q and L to powers of two (L floored at
    128 for lane alignment) so serving batches of arbitrary shape reuse a
    handful of compiled programs."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q, seq_l = ids.shape
    q_pad = pad_pow2(q)
    l_pad = pad_pow2(seq_l, floor=128)
    if (q_pad, l_pad) != (q, seq_l):
        ids = jnp.pad(ids, ((0, q_pad - q), (0, l_pad - seq_l)),
                      constant_values=-1)
        weights = jnp.pad(weights, ((0, q_pad - q), (0, l_pad - seq_l)))
    bq = _pick_block(q_pad, block_q)
    lb = _pick_block(l_pad, block_l)
    out = _embed_jit(ids.astype(jnp.int32), weights.astype(jnp.float32),
                     proj.astype(jnp.float32), bq=bq, lb=lb,
                     interpret=bool(interpret))
    return out[:q]
