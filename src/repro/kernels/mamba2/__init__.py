from repro.kernels.mamba2 import ops, ref
from repro.kernels.mamba2.ops import ssd

__all__ = ["ops", "ref", "ssd"]
