"""Pure-jnp oracle for the Mamba2 SSD kernel: exact sequential recurrence."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, B: jnp.ndarray, C: jnp.ndarray,
            A: jnp.ndarray, h0: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token-by-token SSD recurrence.

    x: (b, s, h, p); dt: (b, s, h); B, C: (b, s, n); A: (h,) negative.
        h ← h·exp(dt_t·A) + dt_t·x_t ⊗ B_t ;  y_t = C_t · h
    Returns (y (b, s, h, p), h_final (b, h, p, n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(state, xs):
        xt, dtt, Bt, Ct = xs           # (b,h,p), (b,h), (b,n), (b,n)
        decay = jnp.exp(dtt * Af[None])[:, :, None, None]
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], Bt)
        state = state * decay + upd
        y = jnp.einsum("bn,bhpn->bhp", Ct, state)
        return state, y

    h_fin, ys = jax.lax.scan(
        step, h0, (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
                   jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_fin
