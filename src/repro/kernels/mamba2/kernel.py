"""Chunked Mamba2 SSD kernel (Pallas TPU).

Grid = (batch, heads, n_chunks); chunk axis sequential, per-head (P, N)
state in f32 VMEM scratch.  The chunk body is the SSD block decomposition
(Dao & Gu 2024): an intra-chunk lower-triangular matmul, an inter-chunk
state read, and a rank-L state update — three MXU contractions per chunk.

Adaptation from the paper's GPU layout: instead of a warpgroup per (chunk,
head) with shared-memory staging, one grid cell owns a head's whole scan and
the state never leaves VMEM; B/C tiles (shared across heads, n_groups=1) are
re-fetched per head — they are (L, N=64..128) tiles, cheap next to x.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, h0_ref, y_ref, hout_ref,
                h_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0, 0, :, :].astype(jnp.float32)

    xb = x_ref[0, :, 0, :].astype(jnp.float32)       # (L, P)
    dtb = dt_ref[0, :, 0].astype(jnp.float32)        # (L,)
    Bb = b_ref[0, :, :].astype(jnp.float32)          # (L, N)
    Cb = c_ref[0, :, :].astype(jnp.float32)          # (L, N)
    A = a_ref[0, 0]                                  # scalar (negative)
    h = h_ref[...]                                   # (P, N)

    dA = dtb * A                                     # (L,) log-decay ≤ 0
    cum = jnp.cumsum(dA)                             # inclusive
    # intra-chunk: M[t,s] = (C_t·B_s) · exp(cum_t − cum_s) · dt_s,  s ≤ t
    G = jax.lax.dot_general(Cb, Bb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, L)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    M = jnp.where(cols <= rows, G * decay * dtb[None, :], 0.0)
    y_intra = jax.lax.dot_general(M, xb, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk: y_t += exp(cum_t) · C_t · h
    Ch = jax.lax.dot_general(Cb, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, P)
    y = y_intra + Ch * jnp.exp(cum)[:, None]
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: h ← h·exp(cum_L) + Σ_s exp(cum_L−cum_s)·dt_s · x_s ⊗ B_s
    scale = jnp.exp(cum[-1] - cum) * dtb             # (L,)
    h = h * jnp.exp(cum[-1]) + jax.lax.dot_general(
        xb * scale[:, None], Bb, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)          # (P, N)
    h_ref[...] = h

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0, 0, :, :] = h


def ssd_fwd(x, dt, B, C, A, h0, chunk: int, interpret: bool):
    """x: (b, s, h, p); dt: (b, s, h); B, C: (b, s, n); A: (h,);
    h0: (b, h, p, n) f32."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    grid = (b, h, s // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, ci: (bb, ci, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, hh, ci: (bb, ci, hh)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ci: (bb, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ci: (bb, ci, 0)),
            pl.BlockSpec((1, 1), lambda bb, hh, ci: (hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bb, hh, ci: (bb, hh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, ci: (bb, ci, hh, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bb, hh, ci: (bb, hh, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(x.shape, x.dtype),
                   jax.ShapeDtypeStruct((b, h, p, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, B, C, A.reshape(-1, 1), h0)
