"""jit'd public wrapper for the Mamba2 SSD kernel."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.mamba2.kernel import ssd_fwd


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b:
        b -= 1
    return b


def ssd(x: jax.Array, dt: jax.Array, B: jax.Array, C: jax.Array,
        A: jax.Array, h0: Optional[jax.Array] = None, chunk: int = 64,
        interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan: returns (y (b,s,h,p), h_final (b,h,p,n) f32)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, s, h, p = x.shape
    n = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    c = _pick_block(s, chunk)
    return ssd_fwd(x, dt, B, C, A.astype(jnp.float32), h0, chunk=c,
                   interpret=interpret)
