"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -2.3819763e38


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, window: int,
                         cache_len: int) -> jnp.ndarray:
    """q: (B, 1, Hq, hd); caches: (B, S, Hk, hd).  Attends to positions
    [max(0, cache_len - window), cache_len)."""
    b, _, hq, hd = q.shape
    s, hk = k_cache.shape[1], k_cache.shape[2]
    group = hq // hk
    qf = q.reshape(b, hk, group, hd).astype(jnp.float32) * (hd ** -0.5)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
    pos = jnp.arange(s)
    valid = (pos < cache_len) & (pos >= cache_len - window)
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, hd).astype(q.dtype)
