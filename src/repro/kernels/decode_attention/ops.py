"""jit'd public wrapper for the flash-decode kernel."""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import flash_decode_fwd


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b:
        b -= 1
    return b


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     window: Union[int, jax.Array],
                     cache_len: Union[int, jax.Array], block_k: int = 1024,
                     interpret: Optional[bool] = None) -> jax.Array:
    """Drop-in for models.attention.decode_attend (Pallas TPU path)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bk = _pick_block(k_cache.shape[1], block_k)
    scalars = jnp.stack([jnp.asarray(cache_len, jnp.int32),
                         jnp.asarray(window, jnp.int32)])
    return flash_decode_fwd(q, k_cache, v_cache, scalars, bk=bk,
                            interpret=interpret)
