"""Flash-decode kernel (Pallas TPU): one query token vs. a long KV cache.

Grid = (batch, kv_heads, kv_blocks); the kv-block axis is sequential so the
online-softmax state (acc (G, hd), m, l) lives in VMEM scratch.  All ``G``
query heads of a kv head are processed together — the (G, bk) score matrix
keeps the MXU busy even at decode (G=6 for grok's 48q/8kv).

``cache_len`` and ``window`` are dynamic SMEM scalars; kv blocks entirely
outside [cache_len - window, cache_len) are skipped via ``pl.when`` — for a
32k cache at cache_len=1k, 31/32 blocks do no compute.

VMEM per step: 2·bk·hd·2B (k+v tiles) + G·hd·4B ≈ 0.5 MB at bk=1024, hd=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -2.3819763e38
LANES = 128


def _fd_kernel(scalars_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
               *, bk: int, group: int, scale: float):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    cache_len = scalars_ref[0]
    window = scalars_ref[1]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_first = ki * bk
    lo = jnp.maximum(cache_len - window, 0)
    visible = (k_first < cache_len) & (k_first + bk > lo)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale      # (G, hd)
        k = k_ref[0, :, 0, :]                                  # (bk, hd)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bk)
        k_pos = k_first + jax.lax.broadcasted_iota(jnp.int32, (group, bk), 1)
        mask = (k_pos < cache_len) & (k_pos >= cache_len - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, :1]
        l_prev = l_ref[:, :1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            l_prev * corr + jnp.sum(p, axis=-1, keepdims=True), l_ref.shape)
        pv = jax.lax.dot_general(p, v.astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


def flash_decode_fwd(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     scalars: jax.Array, bk: int,
                     interpret: bool) -> jax.Array:
    """q: (B, 1, Hq, hd) reshaped to (B, Hk, G, hd) outside; caches
    (B, S, Hk, hd); scalars = [cache_len, window] i32."""
    b, _, hq, hd = q.shape
    s, hk = k_cache.shape[1], k_cache.shape[2]
    group = hq // hk
    q4 = q.reshape(b, hk, group, hd)
    grid = (b, hk, s // bk)

    kernel = functools.partial(_fd_kernel, bk=bk, group=group,
                               scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, hd), lambda bb, h, ki: (bb, h, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bb, h, ki: (bb, ki, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda bb, h, ki: (bb, ki, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda bb, h, ki: (bb, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hk, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, hd), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
            pltpu.VMEM((group, LANES), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(scalars, q4, k_cache, v_cache)
    return out.reshape(b, 1, hq, hd)
