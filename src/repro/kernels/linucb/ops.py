"""jit'd public wrapper for the LinUCB scoring kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.linucb.kernel import linucb_scores_fwd


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b:
        b -= 1
    return b


def linucb_scores(a_inv: jax.Array, theta: jax.Array, x: jax.Array,
                  alpha: float, block_m: int = 16, block_q: int = 128,
                  interpret: Optional[bool] = None) -> jax.Array:
    """a_inv: (M, d, d); theta: (M, d); x: (d,) or (Q, d) → (M,) or (Q, M)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    single = x.ndim == 1
    xq = x[None] if single else x
    bm = _pick_block(a_inv.shape[0], block_m)
    bq = _pick_block(xq.shape[0], block_q)
    out = linucb_scores_fwd(a_inv.astype(jnp.float32),
                            theta.astype(jnp.float32),
                            xq.astype(jnp.float32), float(alpha),
                            bm=bm, bq=bq, interpret=interpret)
    return out[0] if single else out
