"""jit'd public wrapper for the LinUCB scoring kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.linucb.kernel import linucb_scores_fwd


def _pick_block(n: int, target: int) -> int:
    b = min(n, target)
    while n % b:
        b -= 1
    return b


# static alpha/block/interpret: one compiled program per (M, d, Q, blocks)
# combination — serving batch sizes recur, so steady state is cache hits,
# not per-call retracing of the pallas_call
@functools.partial(jax.jit, static_argnames=("alpha", "bm", "bq",
                                             "interpret"))
def _scores_jit(a_inv, theta, xq, alpha: float, bm: int, bq: int,
                interpret: bool):
    return linucb_scores_fwd(a_inv, theta, xq, alpha, bm=bm, bq=bq,
                             interpret=interpret)


def linucb_scores(a_inv: jax.Array, theta: jax.Array, x: jax.Array,
                  alpha: float, block_m: int = 16, block_q: int = 128,
                  interpret: Optional[bool] = None) -> jax.Array:
    """a_inv: (M, d, d); theta: (M, d); x: (d,) or (Q, d) → (M,) or (Q, M)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    single = x.ndim == 1
    xq = x[None] if single else x
    # pad Q to the next power of two: serving batches take arbitrary sizes,
    # and compiling one program per distinct Q would thrash the jit cache —
    # padding bounds the compiled variants to log2(block cap) shapes
    q = xq.shape[0]
    q_pad = 1 << max(q - 1, 0).bit_length()
    if q_pad != q:
        xq = jnp.concatenate(
            [xq, jnp.zeros((q_pad - q, xq.shape[1]), xq.dtype)])
    bm = _pick_block(a_inv.shape[0], block_m)
    bq = _pick_block(q_pad, block_q)
    out = _scores_jit(a_inv.astype(jnp.float32),
                      theta.astype(jnp.float32),
                      xq.astype(jnp.float32), float(alpha),
                      bm=bm, bq=bq, interpret=bool(interpret))[:q]
    return out[0] if single else out
