"""Pure-jnp oracle for the batched LinUCB scoring kernel (paper Eq. 13)."""
from __future__ import annotations

import jax.numpy as jnp


def linucb_scores_ref(a_inv: jnp.ndarray, theta: jnp.ndarray,
                      x: jnp.ndarray, alpha: float) -> jnp.ndarray:
    """a_inv: (M, d, d); theta: (M, d); x: (Q, d) → scores (Q, M):
    θ_mᵀx_q + α·sqrt(x_qᵀ A_m⁻¹ x_q)."""
    mean = jnp.einsum("md,qd->qm", theta, x)
    ax = jnp.einsum("mij,qj->qmi", a_inv, x)
    var = jnp.maximum(jnp.einsum("qmi,qi->qm", ax, x), 0.0)
    return mean + alpha * jnp.sqrt(var)
