from repro.kernels.linucb import ops, ref
from repro.kernels.linucb.ops import linucb_scores

__all__ = ["ops", "ref", "linucb_scores"]
