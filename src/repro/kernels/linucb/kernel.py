"""Batched LinUCB arm-scoring kernel (Pallas TPU) — the paper's router as a
fused TPU op.

The paper's Appendix B puts routing at O(|M|·d³) per decision (a Cholesky
solve per arm).  With A⁻¹ maintained by Sherman–Morrison (see
core/bandits.py) scoring is O(|M|·d²), and this kernel fuses the whole
decision — |M| quadratic forms + means + the UCB combine — into one VMEM
pass over a (bm, d, d) tile of arm inverses, batched over a Q-block of
query contexts (serving routes *batches*, not single queries).

At the paper's scale (|M|=16, d=12) this is sub-microsecond; the kernel
exists so the router stays off the host at production batch sizes
(Q=10³ queries × M=64 arms × d=128 contexts per step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro import compat


def _linucb_kernel(ainv_ref, theta_ref, x_ref, o_ref, *, alpha: float):
    ainv = ainv_ref[...].astype(jnp.float32)     # (bm, d, d)
    theta = theta_ref[...].astype(jnp.float32)   # (bm, d)
    x = x_ref[...].astype(jnp.float32)           # (bq, d)
    # mean_qm = θ_m · x_q
    mean = jax.lax.dot_general(x, theta, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (bq, bm)
    # ax_qmi = Σ_j A⁻¹_mij x_qj  → contract j
    ax = jax.lax.dot_general(x, ainv, (((1,), (2,)), ((), ())),
                             preferred_element_type=jnp.float32)    # (bq,bm,d)
    var = jnp.einsum("qmi,qi->qm", ax, x)
    var = jnp.maximum(var, 0.0)
    o_ref[...] = (mean + alpha * jnp.sqrt(var)).astype(o_ref.dtype)


def linucb_scores_fwd(a_inv, theta, x, alpha: float, bm: int, bq: int,
                      interpret: bool):
    m, d, _ = a_inv.shape
    q = x.shape[0]
    kernel = functools.partial(_linucb_kernel, alpha=alpha)
    return pl.pallas_call(
        kernel,
        grid=(q // bq, m // bm),
        in_specs=[
            pl.BlockSpec((bm, d, d), lambda qi, mi: (mi, 0, 0)),
            pl.BlockSpec((bm, d), lambda qi, mi: (mi, 0)),
            pl.BlockSpec((bq, d), lambda qi, mi: (qi, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bm), lambda qi, mi: (qi, mi)),
        out_shape=jax.ShapeDtypeStruct((q, m), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(a_inv, theta, x)
