"""Pallas TPU kernels for the serving hot spots + the paper's router.

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd wrapper, auto interpret=True off-TPU), and ref.py
(pure-jnp oracle used by the per-kernel allclose test sweeps).
"""
from repro.kernels.decode_attention import decode_attention
from repro.kernels.featurize import hashed_embed
from repro.kernels.flash_attention import flash_attention
from repro.kernels.linucb import linucb_scores
from repro.kernels.mamba2 import ssd
from repro.kernels.moe_gating import topk_gating
from repro.kernels.rwkv6 import wkv

__all__ = ["decode_attention", "flash_attention", "hashed_embed",
           "linucb_scores", "ssd", "topk_gating", "wkv"]
