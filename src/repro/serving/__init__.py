"""Multi-model serving runtime: engines, continuous batching, routing."""
from repro.serving.engine import (BaseEngine, EngineFailure, ModelEngine,
                                  SimEngine)
from repro.serving.faults import FaultInjector, FaultSpec, fault_storm
from repro.serving.reliability import BreakerConfig, CircuitBreaker
from repro.serving.request import Request, RequestState, Response
from repro.serving.scheduler import LivelockError, PoolServer

__all__ = ["BaseEngine", "EngineFailure", "ModelEngine", "SimEngine",
           "Request", "RequestState", "Response", "PoolServer",
           "LivelockError", "BreakerConfig", "CircuitBreaker",
           "FaultInjector", "FaultSpec", "fault_storm"]
