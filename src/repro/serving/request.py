"""Serving request/response types and per-request lifecycle state."""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import List, Optional

from repro.core.types import Query


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"      # prompt tokens streaming into the cache
    DECODE = "decode"        # generating
    DONE = "done"
    FAILED = "failed"
    CANCELLED = "cancelled"  # hedged duplicate that lost the race


@dataclasses.dataclass
class Request:
    query: Query
    prompt_tokens: List[int]
    max_new_tokens: int
    eos_id: int = 0
    # lifecycle
    state: RequestState = RequestState.QUEUED
    model_name: str = ""
    slot: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    n_prompt_fed: int = 0
    submit_s: float = dataclasses.field(default_factory=time.monotonic)
    start_s: float = 0.0
    first_token_s: float = 0.0
    finish_s: float = 0.0
    hedged: bool = False
    hedge_of: Optional[int] = None   # uid of the primary request

    @property
    def uid(self) -> int:
        return self.query.uid

    @property
    def prefill_done(self) -> bool:
        return self.n_prompt_fed >= len(self.prompt_tokens)

    @property
    def done(self) -> bool:
        return self.state in (RequestState.DONE, RequestState.FAILED,
                              RequestState.CANCELLED)

    @property
    def latency_ms(self) -> float:
        if self.finish_s and self.submit_s:
            return (self.finish_s - self.submit_s) * 1e3
        return 0.0


@dataclasses.dataclass
class Response:
    uid: int
    model_name: str
    tokens: List[int]
    text: str
    latency_ms: float
    queue_ms: float
    energy_wh: float
    input_tokens: int
    output_tokens: int
    hedged_winner: bool = False
    ttft_ms: float = 0.0     # time to first generated token (0 = unknown)
