"""Serving request/response types and per-request lifecycle state."""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import List, Optional

from repro.core.types import Query


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"      # prompt tokens streaming into the cache
    MIGRATING = "migrating"  # prompt KV in transit prefill→decode engine
    DECODE = "decode"        # generating
    DONE = "done"
    FAILED = "failed"        # attempts exhausted (terminal, no response)
    CANCELLED = "cancelled"  # hedged duplicate that lost the race
    TIMED_OUT = "timed_out"  # deadline passed before completion (terminal)


@dataclasses.dataclass
class Request:
    """One routed query's engine-side lifecycle state.

    Token counts are in tokenizer tokens; every ``*_s`` field is a
    ``time.monotonic()`` timestamp in seconds (0.0 = not reached yet):
    ``submit_s`` at routing, ``start_s`` at slot admission (queue wait
    ends), ``first_token_s`` at the first *generated* token (TTFT), and
    ``finish_s`` at completion.  ``n_prompt_fed`` is the prompt cursor —
    how many prompt tokens the engine has consumed into the cache
    (advanced by 1 on the token-wise path, by up to ``prefill_chunk`` per
    chunked-prefill tick)."""

    query: Query
    prompt_tokens: List[int]
    max_new_tokens: int
    eos_id: int = 0
    # lifecycle
    state: RequestState = RequestState.QUEUED
    model_name: str = ""
    slot: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    n_prompt_fed: int = 0
    prefix_reused: int = 0   # prompt tokens spliced from the prefix-KV cache
    # --- prefill→decode disaggregation (docs/SERVING.md) ---
    # (k, v) numpy blocks captured on the prefill engine at phase boundary,
    # carried by the scheduler to the decode twin, cleared after splice
    kv_payload: Optional[tuple] = None
    kv_migrated: int = 0     # prompt-KV tokens moved between engines
    prefill_wh: float = 0.0  # metered prefill-phase Wh, stamped at migration
    # (task_label, cluster, embedding) computed once by the scheduler's
    # cache probe; reused at completion for the semantic insert
    cache_features: Optional[tuple] = None
    # pre-dispatch Wh forecast stamped at admission by the scheduler's
    # EnergyCostModel (0.0 = no cost model / never predicted); reconciled
    # against the metered energy_wh at completion
    predicted_wh: float = 0.0
    submit_s: float = dataclasses.field(default_factory=time.monotonic)
    start_s: float = 0.0
    first_token_s: float = 0.0
    finish_s: float = 0.0
    hedged: bool = False
    hedge_of: Optional[int] = None   # uid of the primary request
    # --- reliability (docs/RELIABILITY.md) ---
    # end-to-end deadline in seconds from ``submit_s`` (0.0 = none); the
    # deadline covers *all* attempts — retries never reset the clock
    deadline_s: float = 0.0
    attempts: int = 0        # failed attempts so far (0 = first try clean)
    max_retries: int = 0     # re-dispatches allowed after the first attempt

    @property
    def uid(self) -> int:
        return self.query.uid

    @property
    def prefill_done(self) -> bool:
        return self.n_prompt_fed >= len(self.prompt_tokens)

    @property
    def done(self) -> bool:
        return self.state in (RequestState.DONE, RequestState.FAILED,
                              RequestState.CANCELLED, RequestState.TIMED_OUT)

    @property
    def defunct(self) -> bool:
        """Terminal without a completion — engines must drop the request
        on sight (free its slot, never decode it, never resurrect it on
        restart).  DONE is deliberately excluded: a finished request has a
        Response and exits through the normal completion path."""
        return self.state in (RequestState.CANCELLED, RequestState.FAILED,
                              RequestState.TIMED_OUT)

    @property
    def latency_ms(self) -> float:
        """End-to-end milliseconds (submit → finish); 0.0 while unfinished."""
        if self.finish_s and self.submit_s:
            return (self.finish_s - self.submit_s) * 1e3
        return 0.0


@dataclasses.dataclass
class Response:
    """The completed-request record the scheduler hands back: latencies in
    milliseconds (``latency_ms`` end-to-end, ``queue_ms`` submission →
    admission, ``ttft_ms`` submission → first generated token), energy in
    watt-hours (``energy_wh``, both phases), token counts in tokenizer
    tokens."""

    uid: int
    model_name: str
    tokens: List[int]
    text: str
    latency_ms: float
    queue_ms: float
    energy_wh: float
    input_tokens: int
    output_tokens: int
    hedged_winner: bool = False
    ttft_ms: float = 0.0     # time to first generated token (0 = unknown)
    prefix_reused: int = 0   # prompt tokens served from the prefix-KV cache
    kv_migrated: int = 0     # prompt-KV tokens moved prefill→decode engine
    # prefill-phase share of energy_wh (migration DMA included); 0.0 for
    # engines without a phase split.  The cost model trains its per-phase
    # residual buckets from this split.
    prefill_wh: float = 0.0
