"""Per-arm circuit breakers for failure-aware routing (docs/RELIABILITY.md).

A :class:`CircuitBreaker` guards one (engine, role) arm of the pool with
the classic three-state machine over a rolling failure-rate window:

  CLOSED ──(failure rate ≥ threshold over ≥ min_samples)──▶ OPEN
  OPEN ──(``open_steps`` scheduler steps elapse)──▶ HALF_OPEN
  HALF_OPEN ──(``probe_successes`` probes succeed)──▶ CLOSED
  HALF_OPEN ──(any probe fails)──▶ OPEN (cooldown restarts)

Time is measured in *scheduler steps*, not wall seconds — the serving
benches run on a virtual clock, and a breaker keyed to wall time would
either never cool down (idle-jumps skip hours in one tick) or flap.  The
scheduler passes its step counter into every call.

``routable`` is the routing-mask predicate ``PoolServer`` aggregates into
the router's arm-health mask: OPEN arms are masked out of
``route_batch``'s argmax entirely; HALF_OPEN arms take a *probe trickle*
— at most ``probe_quota`` in-flight requests at a time — so one success
or failure decides the arm's fate before real traffic returns to it.
The mask can never make routing impossible: ``GreenServRouter`` falls
back to the unmasked feasibility row when every arm of a query is
breaker-blocked (serving must answer; see ``_feasible_matrix``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Optional

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Knobs for one arm's breaker (shared by the whole pool — the
    scheduler instantiates one breaker per (engine, role) from this).

    ``window``            rolling attempt window (successes + failures)
    ``failure_threshold`` open when failures/window ≥ this …
    ``min_samples``       … and the window holds at least this many attempts
    ``open_steps``        OPEN cooldown, in scheduler steps
    ``probe_quota``       concurrent probes allowed while HALF_OPEN
    ``probe_successes``   consecutive probe successes required to close
    """

    window: int = 16
    failure_threshold: float = 0.5
    min_samples: int = 4
    open_steps: int = 60
    probe_quota: int = 1
    probe_successes: int = 2

    def __post_init__(self):
        if not (0.0 < self.failure_threshold <= 1.0):
            raise ValueError(
                f"failure_threshold must be in (0, 1], got "
                f"{self.failure_threshold}")
        if self.window < 1 or self.min_samples < 1:
            raise ValueError("window and min_samples must be >= 1")


class CircuitBreaker:
    """One arm's failure-rate state machine (module docstring has the
    transition diagram).  ``on_transition(old, new, step)`` fires on every
    state change — the scheduler wires it to telemetry."""

    def __init__(self, config: Optional[BreakerConfig] = None,
                 on_transition: Optional[
                     Callable[[str, str, int], None]] = None):
        self.config = config or BreakerConfig()
        self.state = CLOSED
        self.on_transition = on_transition
        # rolling attempt window: True = failure
        self._window: Deque[bool] = deque(maxlen=self.config.window)
        self._opened_at = 0
        self._probe_ok = 0
        self.n_opens = 0

    def _transition(self, new: str, step: int) -> None:
        old, self.state = self.state, new
        if new == OPEN:
            self.n_opens += 1
            self._opened_at = step
        if new == HALF_OPEN:
            self._probe_ok = 0
        if new == CLOSED:
            self._window.clear()
        if self.on_transition is not None and old != new:
            self.on_transition(old, new, step)

    def failure_rate(self) -> float:
        if not self._window:
            return 0.0
        return sum(self._window) / len(self._window)

    def record_success(self, step: int) -> None:
        if self.state == HALF_OPEN:
            self._probe_ok += 1
            if self._probe_ok >= self.config.probe_successes:
                self._transition(CLOSED, step)
            return
        self._window.append(False)

    def record_failure(self, step: int) -> None:
        if self.state == HALF_OPEN:
            # the probe died: straight back to OPEN, cooldown restarts
            self._transition(OPEN, step)
            return
        if self.state == OPEN:
            return
        self._window.append(True)
        if (len(self._window) >= self.config.min_samples
                and self.failure_rate() >= self.config.failure_threshold):
            self._transition(OPEN, step)

    def routable(self, step: int, pending: int = 0) -> bool:
        """May the router send (more) work to this arm right now?
        ``pending`` is the arm's current queue+slot occupancy — HALF_OPEN
        admits probes only while it sits under ``probe_quota``.  Calling
        this advances the OPEN→HALF_OPEN cooldown transition (the breaker
        has no timer of its own)."""
        if self.state == OPEN:
            if step - self._opened_at >= self.config.open_steps:
                self._transition(HALF_OPEN, step)
            else:
                return False
        if self.state == HALF_OPEN:
            return pending < self.config.probe_quota
        return True


__all__ = ["BreakerConfig", "CircuitBreaker", "CLOSED", "OPEN", "HALF_OPEN"]
