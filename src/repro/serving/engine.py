"""Per-model serving engines with slot-based continuous batching.

Two backends behind one interface:

  * ``ModelEngine``  — a real JAX model (reduced config on CPU, full config
    on TPU) over a (max_batch,)-slot KV/state cache with *per-slot
    lengths*.  Prompts run as **chunked prefill**: each engine tick feeds
    every prefilling slot up to ``prefill_chunk`` prompt tokens through one
    jitted chunk-step (in-flight decode slots ride along with one token
    each, so continuous batching never stalls), then greedy decode runs
    one token per tick through the cheaper jitted ``serve_step`` until
    EOS/max_new_tokens.  A long prompt therefore reaches its first token
    in ~len/chunk ticks instead of len ticks.  Architectures whose state
    can't take a slab at an offset (recurrent rwkv/mamba, ring-buffer
    windowed caches) transparently fall back to one prompt token per tick
    — see ``api.supports_chunked_prefill``.
  * ``SimEngine``    — a timing/energy/accuracy model of a pool member
    (paper's 16-model pool has no public weights in this container); used
    by the paper-scale benchmarks.

Both report per-query energy (Wh) via the analytic TPU model (core.energy)
— the zeus stand-in of DESIGN §4 — and time-resolved per-step joules,
split by phase (prefill is compute-bound, decode bandwidth-bound; the
telemetry layer tags and charges the two separately).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import (CostModelParams, EnergyMonitor, JOULES_PER_WH,
                               chunk_rider_cost, decode_step_cost,
                               energy_joules, kv_migration_cost,
                               prefill_chunk_cost, prefill_cost, roofline)
from repro.core.types import ModelProfile
from repro.models import api
from repro.models.config import ModelConfig
from repro.serving.request import Request, RequestState, Response


class EngineFailure(RuntimeError):
    pass


class BaseEngine:
    """Interface shared by real and simulated engines."""

    name: str
    profile: ModelProfile
    role: str = "unified"    # "prefill" | "decode" | "unified"

    def submit(self, req: Request) -> None:
        """Enqueue one routed request (admitted into a slot on a later step)."""
        raise NotImplementedError

    def submit_many(self, reqs: List[Request]) -> None:
        """Enqueue an already-routed batch slice in arrival order."""
        for req in reqs:
            self.submit(req)

    def step(self) -> List[Response]:
        """Advance the engine one tick; returns requests finished this tick."""
        raise NotImplementedError

    @property
    def pending(self) -> int:
        """Queued + in-slot request count (the scheduler's load signal)."""
        raise NotImplementedError

    @property
    def free_capacity(self) -> int:
        """Slots the engine could admit into right now (continuous-batching
        admission signal; 0 = saturated)."""
        return max(0, 1 - self.pending)

    # -- prefill/decode disaggregation hooks ----------------------------------

    def set_role(self, role: str) -> None:
        """Specialize the engine: a ``prefill`` engine hands requests off at
        the phase boundary instead of decoding them; a ``decode`` engine
        accepts those migrations.  Engines that can't export/import KV
        (no full-depth positional cache) silently stay ``unified``."""

    def drain_migrations(self) -> List[Request]:
        """Requests that finished prefill this tick and carry a KV payload;
        the scheduler moves them to the decode twin.  Draining empties the
        outbox.  Always empty for unified/decode roles."""
        return []

    def submit_migrated(self, req: Request) -> None:
        """Enqueue a migrated request (``req.kv_payload`` holds its prompt
        KV); spliced into a slot on a later step, then decoded."""
        raise NotImplementedError(f"{self.name} cannot accept migrations")

    def modeled_time_s(self) -> float:
        """Cumulative modeled wall-clock seconds of engine compute (per-tick
        roofline ``t_step`` summed).  Virtual-clock benches diff this to
        advance time by what the hardware would actually take — it is how
        prefill/decode interference (chunk-padded mixed ticks) becomes
        visible as TBT/TTFT inflation.  0.0 for engines without a roofline
        model (SimEngine)."""
        return 0.0

    def set_prefill_chunk(self, n: int) -> None:
        """Prompt tokens consumed per prefill tick (1 = token-wise legacy
        path).  No-op for engines without a real prefill (SimEngine)."""

    def set_prefix_cache(self, cache) -> None:
        """Attach a ``repro.cache.PrefixCache`` for cross-query prompt-KV
        reuse.  No-op for engines without a materialized KV cache
        (SimEngine) — real engines override and gate on layout support."""

    # -- telemetry hooks -------------------------------------------------------

    def cumulative_joules(self) -> float:
        """Cumulative metered energy in joules; sampled per scheduler step
        by the telemetry PowerTrace to derive a watts time-series."""
        return 0.0

    def cumulative_joules_by_phase(self) -> Dict[str, float]:
        """Cumulative metered joules split by serving phase ("prefill" /
        "decode"); the values sum to ``cumulative_joules()``.  Engines
        without a phase split report everything as decode."""
        return {"prefill": 0.0, "decode": self.cumulative_joules()}

    def cumulative_joules_avoided(self) -> float:
        """Cumulative modeled joules *not* spent thanks to prefix-KV
        reuse (the prefill work a spliced prefix replaced).  Telemetry
        diffs this per step into the avoided-energy counters, exactly as
        it does the phase joules."""
        return 0.0

    def prefix_hit_count(self) -> int:
        """Admissions that spliced a cached prefix (telemetry diffs it)."""
        return 0

    # -- fault-tolerance hooks -------------------------------------------------

    def heartbeat(self) -> float:
        """Monotonic seconds timestamp of the last completed step."""
        return getattr(self, "_last_step_s", 0.0)

    def inject_failure(self) -> None:
        self._failed = True

    def restart(self) -> List[Request]:
        """Reset engine state; returns in-flight requests for re-queueing."""
        raise NotImplementedError


class ModelEngine(BaseEngine):
    """Real-model engine: continuous batching over a slotted cache.

    ``prefill_chunk`` sets how many prompt tokens each prefilling slot
    consumes per tick (1 = token-wise path; the launcher default is 8).
    The chunked path applies only to layouts with a full-depth positional
    KV cache (``api.supports_chunked_prefill`` + a ``k`` cache entry —
    ring-buffer windowed caches are excluded); everything else silently
    clamps to 1.
    """

    def __init__(self, name: str, cfg: ModelConfig, key: jax.Array,
                 max_batch: int = 4, max_len: int = 256,
                 params=None, detokenize: Optional[Callable] = None,
                 prefill_chunk: int = 1, role: str = "unified"):
        self.name = name
        self.cfg = dataclasses.replace(cfg, kv_update="where")
        self.max_batch = max_batch
        self.max_len = max_len
        self.params = params if params is not None else api.init_params(
            self.cfg, key)
        self.cache = api.init_cache(self.cfg, max_batch, max_len)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.detokenize = detokenize or (lambda toks: "")
        self._failed = False
        self._last_step_s = time.monotonic()
        self.energy = EnergyMonitor()
        # per-step metered joules by serving phase (telemetry reads these)
        self._phase_joules = {"prefill": 0.0, "decode": 0.0}
        self.cost_params = CostModelParams(
            n_params=float(cfg.param_count()),
            n_active_params=float(cfg.active_param_count()),
            d_model=cfg.d_model, n_layers=cfg.n_layers,
            kv_heads=max(cfg.n_kv_heads, 1), head_dim=cfg.head_dim)
        self.profile = ModelProfile(
            name=name, family=cfg.layout,
            params_b=cfg.param_count() / 1e9, arch_config=cfg)
        self.n_steps = 0

        def _step(params, cache, tokens):
            logits, cache = api.serve_step(params, tokens, cache, self.cfg)
            return jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32), cache

        self._jit_step = jax.jit(_step, donate_argnums=(1,))
        self._jit_chunk_step = None
        self.prefill_chunk = 1
        self.set_prefill_chunk(prefill_chunk)
        # cross-query prefix-KV reuse (repro.cache): attached by the
        # scheduler's _configure_engine; None = recompute every prompt
        self.prefix_cache = None
        self._avoided_joules = 0.0
        self._prefix_hits = 0
        # prefill/decode disaggregation (docs/SERVING.md): a "prefill"
        # engine parks phase-boundary requests here for the scheduler to
        # move to the decode twin
        self.role = "unified"
        self._migration_outbox: List[Request] = []
        self._migration_joules = 0.0
        self._modeled_time_s = 0.0
        self.set_role(role)

    def set_role(self, role: str) -> None:
        """Pin the engine to one serving phase.  The same full-depth
        positional-KV gate as chunked prefill applies: KV migration rides
        device→host ``_capture_prefix``-style copies and ``splice_prefix``,
        so recurrent (rwkv/mamba) and ring-buffer layouts silently fall
        back to ``unified`` and keep both phases local."""
        if role not in ("prefill", "decode", "unified"):
            raise ValueError(f"unknown engine role {role!r}")
        if role != "unified" and not (api.supports_chunked_prefill(self.cfg)
                                      and "k" in self.cache):
            role = "unified"
        self.role = role

    def set_prefill_chunk(self, n: int) -> None:
        """Set the prompt tokens consumed per prefill tick and (re)build
        the jitted chunk-step.  Clamped to 1 when the architecture can't
        take a slab at an offset (recurrent state, ring-buffer caches)."""
        n = max(int(n), 1)
        if not (api.supports_chunked_prefill(self.cfg) and "k" in self.cache):
            n = 1
        if n == self.prefill_chunk:
            return      # keep the warmed jit cache (hot-add re-push path)
        self.prefill_chunk = n
        if n == 1:
            self._jit_chunk_step = None
            return

        def _chunk_step(params, cache, tokens, n_active):
            logits, cache = api.prefill_chunk(params, tokens, cache,
                                              self.cfg, n_active)
            # next token per slot from its last *active* position's logits
            idx = jnp.maximum(n_active - 1, 0)
            last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)
            return jnp.argmax(last[:, 0], axis=-1).astype(jnp.int32), cache

        self._jit_chunk_step = jax.jit(_chunk_step, donate_argnums=(1,))

    def set_prefix_cache(self, cache) -> None:
        """Attach (or detach, with None) a prefix-KV cache.  Only layouts
        whose decode cache can take a spliced slab participate — the same
        full-depth positional-KV gate as chunked prefill; ring-buffer and
        recurrent layouts silently keep recomputing their prompts."""
        if cache is not None and not (api.supports_chunked_prefill(self.cfg)
                                      and "k" in self.cache):
            cache = None
        self.prefix_cache = cache

    def cumulative_joules_avoided(self) -> float:
        return self._avoided_joules

    def prefix_hit_count(self) -> int:
        return self._prefix_hits

    def _prefill_joules(self, n_tokens: int, kv_start: int = 0) -> float:
        """Modeled joules the engine would spend prefilling ``n_tokens``
        prompt tokens starting at cache offset ``kv_start`` at its current
        chunk setting (mirrors ``_meter_step``'s charging rule: slabs > 1
        token cost ``prefill_chunk_cost``, single tokens
        ``decode_step_cost``).  With kv_start=0 this is the exact work a
        spliced prefix of that length avoids; with kv_start=p it is the
        work the uncached suffix still costs."""
        C = max(self.prefill_chunk, 1)
        joules, kv = 0.0, kv_start
        end = kv_start + n_tokens
        while kv < end:
            n = min(C, end - kv)
            if n > 1:
                f, b = prefill_chunk_cost(self.cost_params, n, kv)
            else:
                f, b = decode_step_cost(self.cost_params, max(kv + n, 1))
            joules += energy_joules(roofline(f, b, 0.0, self.energy.chips))
            kv += n
        return joules

    def estimate_prefill_wh(self, n_tokens: int) -> float:
        """Expected Wh saved by an ``n_tokens`` prefix hit (router-discount
        and governor-credit units)."""
        return self._prefill_joules(n_tokens) / JOULES_PER_WH

    # -- queueing ----------------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.model_name = self.name
        self.queue.append(req)

    def submit_migrated(self, req: Request) -> None:
        """Accept a phase-boundary migration from the prefill twin.  The
        request keeps its prompt cursor (fully fed), its first generated
        token, and its stamped ``prefill_wh``; ``_admit`` splices the KV
        payload into a slot and decode continues from there."""
        self.queue.append(req)

    def drain_migrations(self) -> List[Request]:
        out, self._migration_outbox = self._migration_outbox, []
        return out

    def modeled_time_s(self) -> float:
        return self._modeled_time_s

    @property
    def pending(self) -> int:
        return (len(self.queue) + len(self._migration_outbox)
                + sum(s is not None for s in self.slots))

    @property
    def free_capacity(self) -> int:
        return max(0, self.max_batch - len(self.queue)
                   - sum(s is not None for s in self.slots))

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                if req.defunct:
                    continue
                req.slot = i
                self.slots[i] = req
                # reset the slot's cache length so it starts fresh
                self.cache["length"] = self.cache["length"].at[i].set(0)
                if req.kv_payload is not None:
                    self._splice_migration(i, req)
                    continue
                req.state = RequestState.PREFILL
                req.start_s = time.monotonic()
                if self.prefix_cache is not None:
                    self._splice_prefix(i, req)

    def _splice_migration(self, slot: int, req: Request) -> None:
        """Land a migrated request: splice its carried prompt KV into the
        slot (cache length = prompt length, exactly the state the prefill
        twin left behind) and charge the migration DMA to the prefill
        ledger — it is phase-boundary overhead, not decode work."""
        k_blk, v_blk = req.kv_payload
        self.cache = api.splice_prefix(self.cache, slot, k_blk, v_blk)
        req.kv_payload = None
        req.state = RequestState.DECODE
        f, b = kv_migration_cost(self.cost_params, req.kv_migrated)
        terms = roofline(f, b, 0.0, self.energy.chips)
        joules = energy_joules(terms)
        self._migration_joules += joules
        self._phase_joules["prefill"] += joules
        self._modeled_time_s += terms.t_step

    def _splice_prefix(self, slot: int, req: Request) -> None:
        """Reuse the longest cached KV prefix for a newly admitted prompt.

        Keeps >= 1 prompt token to feed (the final token's forward pass
        produces the first-generation logits), so the cap is
        ``len(prompt) - 1``.  The splice sets the slot length to the
        reused depth; prefill then continues from that offset, and the
        avoided prefill work is credited to the engine's avoided-joules
        ledger (telemetry turns it into ``kind="prefix"`` counters)."""
        p, k_blk, v_blk = self.prefix_cache.match(
            req.prompt_tokens, max_tokens=len(req.prompt_tokens) - 1)
        if p <= 0:
            return
        self.cache = api.splice_prefix(self.cache, slot, k_blk, v_blk)
        req.n_prompt_fed = p
        req.prefix_reused = p
        self._prefix_hits += 1
        self._avoided_joules += self._prefill_joules(p)

    # -- the continuous-batching step ---------------------------------------------

    def step(self) -> List[Response]:
        """One engine tick.  Runs the jitted chunk-step when any slot still
        has prompt tokens pending (and chunking is enabled/supported),
        otherwise the cheaper one-token decode step.  Returns the requests
        that finished this tick."""
        if self._failed:
            raise EngineFailure(f"engine {self.name} failed")
        self._admit()
        self._last_step_s = time.monotonic()
        if not any(self.slots):
            return []
        need_prefill = any(
            req is not None and not req.defunct
            and not req.prefill_done for req in self.slots)
        if self._jit_chunk_step is not None and need_prefill:
            return self._chunk_tick()
        return self._decode_tick()

    def _decode_tick(self) -> List[Response]:
        """Legacy one-token tick: every live slot feeds one token (next
        prompt token while prefilling, last generated token while
        decoding) through the jitted ``serve_step``."""
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if not req.prefill_done:
                tokens[i, 0] = req.prompt_tokens[req.n_prompt_fed]
            else:
                tokens[i, 0] = (req.generated[-1] if req.generated
                                else req.prompt_tokens[-1])
        next_tok, self.cache = self._jit_step(self.params, self.cache,
                                              jnp.asarray(tokens))
        next_tok = np.asarray(next_tok)
        self.n_steps += 1
        # token-wise prefill runs the decode kernel, so it costs a decode
        # step — but it is still prefill work, tagged as such
        self._meter_step([
            ("prefill" if not req.prefill_done else "decode", 1,
             max(req.n_prompt_fed + len(req.generated), 1))
            for req in self.slots
            if req is not None and not req.defunct])
        fed_prompt = [0 if (req is None or req.prefill_done) else 1
                      for req in self.slots]
        return self._advance_slots(next_tok, fed_prompt)

    def _chunk_tick(self) -> List[Response]:
        """Chunked-prefill tick: prefilling slots consume up to
        ``prefill_chunk`` prompt tokens, decode slots ride along with one
        token each (continuous batching never stalls), all in one jitted
        chunk-step."""
        C = self.prefill_chunk
        tokens = np.zeros((self.max_batch, C), np.int32)
        n_active = np.zeros((self.max_batch,), np.int32)
        fed_prompt = [0] * self.max_batch
        meter = []
        for i, req in enumerate(self.slots):
            if req is None or req.defunct:
                continue
            kv_start = req.n_prompt_fed + len(req.generated)
            if not req.prefill_done:
                n = min(C, len(req.prompt_tokens) - req.n_prompt_fed)
                tokens[i, :n] = req.prompt_tokens[
                    req.n_prompt_fed:req.n_prompt_fed + n]
                n_active[i] = n
                fed_prompt[i] = n
                meter.append(("prefill", n, kv_start))
            else:
                tokens[i, 0] = (req.generated[-1] if req.generated
                                else req.prompt_tokens[-1])
                n_active[i] = 1
                # decode rider in a mixed tick: its row is chunk-padded
                # through the fused kernel (see chunk_rider_cost)
                meter.append(("decode", 1, max(kv_start, 1), C))
        next_tok, self.cache = self._jit_chunk_step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(n_active))
        next_tok = np.asarray(next_tok)
        self.n_steps += 1
        self._meter_step(meter)
        return self._advance_slots(next_tok, fed_prompt)

    def _advance_slots(self, next_tok: np.ndarray,
                       fed_prompt: List[int]) -> List[Response]:
        """Shared post-step bookkeeping: advance prompt cursors, record
        TTFT at the first generated token, append decode tokens, finish
        on EOS / max_new_tokens / cache overflow.  On a ``prefill``-role
        engine, requests that survive the finish checks at the phase
        boundary are handed to the migration outbox instead of decoding
        locally."""
        finished: List[Response] = []
        now = time.monotonic()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req.defunct:
                self.slots[i] = None
                continue
            if fed_prompt[i]:
                req.n_prompt_fed += fed_prompt[i]
                if req.prefill_done:
                    req.state = RequestState.DECODE
                    req.generated.append(int(next_tok[i]))
                    req.first_token_s = now
                    # the first generated token gets the same finish checks
                    # as any decode token — an EOS-first or 1-token-budget
                    # request must not survive into decode (or migrate)
                    if self._should_finish(i, req):
                        finished.append(self._finish(i))
                    elif self.role == "prefill":
                        self._emit_migration(i, req)
                continue
            req.generated.append(int(next_tok[i]))
            if self._should_finish(i, req):
                finished.append(self._finish(i))
        return finished

    def _should_finish(self, slot: int, req: Request) -> bool:
        hit_eos = req.generated[-1] == req.eos_id
        full = len(req.generated) >= req.max_new_tokens
        overflow = int(self.cache["length"][slot]) >= self.max_len - 1
        return hit_eos or full or overflow

    def _emit_migration(self, slot: int, req: Request) -> None:
        """Phase boundary on a prefill-role engine: snapshot the prompt KV
        (device→host, the ``_capture_prefix`` transport), stamp the metered
        prefill-phase Wh on the request, and free the slot for the next
        arrival.  Prompts that overflowed the slot cache have unwritten KV
        positions — nothing trustworthy to ship — so they stay local and
        decode here (per-request unified fallback)."""
        n_p = len(req.prompt_tokens)
        if n_p > self.max_len - 1:
            return
        k = np.asarray(self.cache["k"][:, slot, :n_p])
        v = np.asarray(self.cache["v"][:, slot, :n_p])
        req.kv_payload = (k, v)
        req.kv_migrated = n_p
        req.prefill_wh = self._prefill_phase_wh(req)
        req.state = RequestState.MIGRATING
        req.slot = -1
        self._migration_outbox.append(req)
        self.slots[slot] = None

    def _prefill_phase_wh(self, req: Request) -> float:
        """The prefill share of the per-query Wh of record, stamped at
        migration time by the engine that actually did the work.  Mirrors
        ``_query_wh``'s split: cold prompts cost ``measure_query``'s
        prefill term, spliced prompts only their uncached suffix.  The
        spend is charged to this engine's monitor now — a later decode-twin
        failure re-queues the request but never un-spends these joules."""
        n_p = max(len(req.prompt_tokens), 1)
        if req.prefix_reused > 0:
            joules = self._prefill_joules(max(n_p - req.prefix_reused, 1),
                                          kv_start=req.prefix_reused)
        else:
            f, b = prefill_cost(self.cost_params, n_p)
            joules = energy_joules(roofline(f, b, 0.0, self.energy.chips))
        self.energy.total_joules += joules
        return joules / JOULES_PER_WH

    def _meter_step(self, fed) -> None:
        """Accumulate this tick's modeled energy from the analytic cost
        model, split by phase.  ``fed`` lists (phase, n_tokens, kv_len)
        per live slot — plus a 4th ``pad`` element for decode riders in
        mixed chunk ticks: prefill slabs are charged
        ``prefill_chunk_cost`` (one weight read amortized over the slab),
        plain decode tokens ``decode_step_cost``, and padded riders
        ``chunk_rider_cost`` (the fused chunk kernel computes all ``pad``
        positions of the rider's row — the interference cost
        role-specialized engines avoid).  This is the time-resolved
        counterpart of ``measure_query`` (which stays the per-query Wh
        accounting of record).  No device sync: kv lengths come from
        request progress, not the cache.

        The same per-slot terms also advance the modeled tick-time ledger:
        one roofline ``t_step`` over the tick's aggregate FLOPs/bytes,
        with the per-slot weight read collapsed to a single read — the
        batched kernel streams the weights once per tick, so per-slot
        energy charges keep the read (each slot's query really pays for
        it) while tick *time* must not multiply it."""
        tick_flops, tick_bytes = 0.0, 0.0
        w_bytes = self.cost_params.n_active_params * self.cost_params.dtype_bytes
        for entry in fed:
            phase, n_tokens, kv_len = entry[:3]
            pad = entry[3] if len(entry) > 3 else 0
            if phase == "prefill" and n_tokens > 1:
                f, b = prefill_chunk_cost(self.cost_params, n_tokens, kv_len)
            elif pad > 1:
                f, b = chunk_rider_cost(self.cost_params, pad, max(kv_len, 1))
            else:
                f, b = decode_step_cost(self.cost_params, max(kv_len, 1))
            self._phase_joules[phase] += energy_joules(
                roofline(f, b, 0.0, self.energy.chips))
            tick_flops += f
            tick_bytes += b - w_bytes
        if fed:
            tick_bytes += w_bytes
            self._modeled_time_s += roofline(
                tick_flops, max(tick_bytes, 0.0), 0.0,
                self.energy.chips).t_step

    def cumulative_joules(self) -> float:
        return self._phase_joules["prefill"] + self._phase_joules["decode"]

    def cumulative_joules_by_phase(self) -> Dict[str, float]:
        return dict(self._phase_joules)

    def _finish(self, slot: int) -> Response:
        req = self.slots[slot]
        self._capture_prefix(slot, req)
        self.slots[slot] = None
        req.state = RequestState.DONE
        req.finish_s = time.monotonic()
        out = [t for t in req.generated if t != req.eos_id]
        if req.kv_migrated and req.prefill_wh > 0:
            pre_wh, dec_wh = self._migrated_query_wh(req, len(out))
        else:
            pre_wh, dec_wh = self._query_wh(len(req.prompt_tokens),
                                            req.prefix_reused, len(out))
        ttft_ms = ((req.first_token_s - req.submit_s) * 1e3
                   if req.first_token_s else 0.0)
        return Response(
            uid=req.uid, model_name=self.name, tokens=out,
            text=self.detokenize(out), latency_ms=req.latency_ms,
            queue_ms=(req.start_s - req.submit_s) * 1e3,
            energy_wh=pre_wh + dec_wh, input_tokens=len(req.prompt_tokens),
            output_tokens=len(out), hedged_winner=req.hedged,
            ttft_ms=ttft_ms, prefix_reused=req.prefix_reused,
            kv_migrated=req.kv_migrated, prefill_wh=pre_wh)

    def _query_wh(self, n_prompt: int, reused: int,
                  n_out: int) -> tuple:
        """Per-query (prefill Wh, decode Wh) of record; the sum is what
        ``measure_query`` charges.  Cold queries keep its terms exactly.
        With a spliced prefix, the prefill term covers only the uncached
        suffix (charged at its true cache offsets) while decode is still
        charged at *full* context depth — prefix reuse avoids prefill
        work, never decode work (every decode step attends over the whole
        cache).  The bandit feedback and the governor's bucket drain both
        see this true spend; the phase split feeds the cost model's
        per-phase residuals."""
        if reused <= 0:
            f, b = prefill_cost(self.cost_params, max(n_prompt, 1))
            pre_j = energy_joules(roofline(f, b, 0.0, self.energy.chips))
        else:
            pre_j = self._prefill_joules(max(n_prompt - reused, 1),
                                         kv_start=reused)
        mid_kv = n_prompt + max(n_out, 1) // 2
        f, b = decode_step_cost(self.cost_params, mid_kv)
        dec_j = max(n_out, 0) * energy_joules(
            roofline(f, b, 0.0, self.energy.chips))
        # keep the monitor's totals coherent with measure_query's
        self.energy.total_joules += pre_j + dec_j
        self.energy.n_queries += 1
        return pre_j / JOULES_PER_WH, dec_j / JOULES_PER_WH

    def _migrated_query_wh(self, req: Request, n_out: int) -> tuple:
        """Per-query (prefill Wh, decode Wh) of record for a request that
        prefilled elsewhere: the prefill twin's stamped ``prefill_wh`` +
        the phase-boundary KV DMA on the prefill side, this engine's
        decode work at full context depth on the decode side.  Decode is
        charged here (mirroring ``_query_wh``'s mid-depth decode term);
        the prefill term was already charged to the twin's monitor at
        migration time."""
        n_prompt = len(req.prompt_tokens)
        mid_kv = n_prompt + max(n_out, 1) // 2
        f, b = decode_step_cost(self.cost_params, mid_kv)
        dec_j = max(n_out, 0) * energy_joules(
            roofline(f, b, 0.0, self.energy.chips))
        f, b = kv_migration_cost(self.cost_params, req.kv_migrated)
        mig_j = energy_joules(roofline(f, b, 0.0, self.energy.chips))
        self.energy.total_joules += dec_j + mig_j
        self.energy.n_queries += 1
        return (req.prefill_wh + mig_j / JOULES_PER_WH,
                dec_j / JOULES_PER_WH)

    def _capture_prefix(self, slot: int, req: Request) -> None:
        """Register a finished prompt's KV with the prefix cache.  The
        prompt region [0, n_prompt) of the slot cache is still intact at
        finish time (decode appends strictly after it), so the capture is
        one device→host copy; whole blocks only (tail rounding lives in
        ``PrefixCache.insert``)."""
        n_p = len(req.prompt_tokens)
        if (self.prefix_cache is None
                or n_p < self.prefix_cache.block_tokens
                or req.n_prompt_fed < n_p
                or n_p > self.max_len - 1):
            # the last guard: a prompt that overflowed the slot cache has
            # KV positions >= max_len that were never written — nothing
            # trustworthy to capture
            return
        k = np.asarray(self.cache["k"][:, slot, :n_p])
        v = np.asarray(self.cache["v"][:, slot, :n_p])
        self.prefix_cache.insert(req.prompt_tokens, k, v)

    def restart(self) -> List[Request]:
        # defunct (cancelled/timed-out/failed) requests are dropped, not
        # resurrected: resetting one to QUEUED would re-enter a terminal
        # uid into the scheduler's bookkeeping
        inflight = [r for r in ([r for r in self.slots if r is not None]
                                + self.queue + self._migration_outbox)
                    if not r.defunct]
        for r in inflight:
            r.state = RequestState.QUEUED
            r.slot = -1
            r.generated = []
            r.n_prompt_fed = 0
            r.prefix_reused = 0          # re-splices on re-admission
            r.first_token_s = 0.0
            # drop any in-transit KV: it is re-prefilled from scratch, and
            # the twin's already-charged joules stay spent (never refunded)
            r.kv_payload = None
            r.kv_migrated = 0
            r.prefill_wh = 0.0
        self.slots = [None] * self.max_batch
        self.queue = []
        self._migration_outbox = []
        self.cache = api.init_cache(self.cfg, self.max_batch, self.max_len)
        self._failed = False
        return inflight


class SimEngine(BaseEngine):
    """Pool-member simulator: latency/energy/accuracy from profiles.

    Used by the paper-scale benchmarks (16 models × 2500 queries in
    seconds).  ``outcome_fn(query, model_name) -> (accuracy, energy_wh,
    latency_ms, out_tokens)`` encapsulates the calibrated behaviour tables
    (repro.data.profiles).

    A faithful cheap twin of ``ModelEngine`` for everything the scheduler,
    cache, cost model, and telemetry observe:

      * ``concurrency`` mirrors the slot semantics: up to that many queued
        requests make progress each step, and ``free_capacity`` reports
        the unused slots so ``PoolServer.enqueue`` continuous batching
        admits at the pool's real parallelism;
      * ``clock`` injects the time source (same pattern as
        ``SemanticCache.clock``) so ``start_s``/``finish_s``/heartbeats
        live on the bench's virtual clock instead of mixing wall and
        modeled time;
      * energy is phase-split: each completion's Wh divides into prefill
        vs decode by per-token weights mirroring the calibrated tables'
        marginal costs, feeding ``cumulative_joules_by_phase`` and
        ``Response.prefill_wh`` exactly like the real engine;
      * prefix-KV reuse is modeled with the *real* ``PrefixCache`` radix
        trie (token-level matching, LRU eviction) — a hit discounts the
        avoided prefill share from the query's spend and credits the
        avoided-joules ledger, never un-spending energy;
      * ``modeled_time_s`` advances by the slowest active request's
        per-step latency share, so virtual-clock benches can diff it.
    """

    # prefill/decode per-token Wh weights for the phase split; the ratio
    # mirrors repro.data.profiles' marginal costs (MWH_PER_B_PER_IN_TOKEN
    # vs MWH_PER_B_PER_OUT_TOKEN), kept literal to avoid a data-layer dep
    PREFILL_TOKEN_WEIGHT = 0.002
    DECODE_TOKEN_WEIGHT = 0.15

    def __init__(self, profile: ModelProfile, outcome_fn,
                 steps_per_query: int = 1, concurrency: int = 1,
                 clock: Optional[Callable[[], float]] = None):
        if concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got {concurrency}")
        self.name = profile.name
        self.profile = profile
        self.outcome_fn = outcome_fn
        self.queue: List[Request] = []
        self.steps_per_query = steps_per_query
        self.concurrency = concurrency
        self.clock = clock or time.monotonic
        self._failed = False
        self._last_step_s = self.clock()
        self._progress: Dict[int, int] = {}
        # outcome drawn once at first service step (slot activation) and
        # held until completion, so latency can pace the modeled clock
        self._outcomes: Dict[int, tuple] = {}
        self._phase_joules = {"prefill": 0.0, "decode": 0.0}
        self._modeled_time_s = 0.0
        self.prefix_cache = None
        self._avoided_joules = 0.0
        self._prefix_hits = 0
        # EWMA of observed cold prefill Wh per prompt token, backing
        # estimate_prefill_wh (honest: a cold engine offers no discount)
        self._prefill_wh_per_token: Optional[float] = None

    def submit(self, req: Request) -> None:
        req.model_name = self.name
        self.queue.append(req)

    @property
    def pending(self) -> int:
        return len(self.queue)

    @property
    def free_capacity(self) -> int:
        return max(0, self.concurrency - len(self.queue))

    def set_prefix_cache(self, cache) -> None:
        """Attach a PrefixCache; the sim models a full-depth positional KV
        layout, so there is no layout gate."""
        self.prefix_cache = cache

    def cumulative_joules(self) -> float:
        return self._phase_joules["prefill"] + self._phase_joules["decode"]

    def cumulative_joules_by_phase(self) -> Dict[str, float]:
        return dict(self._phase_joules)

    def cumulative_joules_avoided(self) -> float:
        return self._avoided_joules

    def prefix_hit_count(self) -> int:
        return self._prefix_hits

    def modeled_time_s(self) -> float:
        return self._modeled_time_s

    def estimate_prefill_wh(self, n_tokens: int) -> float:
        """Expected Wh an ``n_tokens`` prefix hit saves, from the observed
        per-token prefill EWMA (0 until the first completion calibrates it
        — a cold cache honestly offers no routing discount)."""
        return (self._prefill_wh_per_token or 0.0) * max(n_tokens, 0)

    def _phase_split(self, n_in: int, n_out: int) -> float:
        """Prefill fraction of a completion's energy, by token-weighted
        marginal cost (the fixed overhead splits proportionally)."""
        pre = max(n_in, 0) * self.PREFILL_TOKEN_WEIGHT
        dec = max(n_out, 1) * self.DECODE_TOKEN_WEIGHT
        return pre / max(pre + dec, 1e-12)

    def _activate(self, req: Request) -> tuple:
        """First service step for a request: stamp start, probe the prefix
        cache (the modeled splice — ``match`` LRU-touches the chain like a
        real admission), and draw + pin the outcome."""
        if req.start_s == 0.0:
            req.start_s = self.clock()
        outcome = self._outcomes.get(req.uid)
        if outcome is None:
            if (self.prefix_cache is not None and req.prefix_reused == 0
                    and len(req.prompt_tokens) > 1):
                p, _, _ = self.prefix_cache.match(
                    req.prompt_tokens, max_tokens=len(req.prompt_tokens) - 1)
                if p > 0:
                    req.prefix_reused = p
                    self._prefix_hits += 1
            # state stays QUEUED while in service (a sim "slot" has no
            # prefill/decode sub-lifecycle); hedging semantics match the
            # seed engine: a slow head-of-queue request is still hedgeable
            outcome = self.outcome_fn(req.query, self.name)
            self._outcomes[req.uid] = outcome
        return outcome

    def _finish(self, req: Request, outcome: tuple) -> Response:
        acc, energy_wh, latency_ms, out_tokens = outcome
        n_in = len(req.prompt_tokens)
        pre_frac = self._phase_split(n_in, out_tokens)
        pre_wh_cold = energy_wh * pre_frac
        dec_wh = energy_wh - pre_wh_cold
        avoided_wh = 0.0
        if req.prefix_reused > 0 and n_in > 0:
            # the spliced share of the prompt was never prefilled: its
            # energy is avoided (credited, not un-spent) and the query's
            # Wh of record covers only the uncached suffix + decode
            avoided_wh = pre_wh_cold * min(req.prefix_reused / n_in, 1.0)
            self._avoided_joules += avoided_wh * JOULES_PER_WH
        pre_wh = pre_wh_cold - avoided_wh
        self._phase_joules["prefill"] += pre_wh * JOULES_PER_WH
        self._phase_joules["decode"] += dec_wh * JOULES_PER_WH
        if n_in > 0:
            sample = pre_wh_cold / n_in
            self._prefill_wh_per_token = (
                sample if self._prefill_wh_per_token is None
                else 0.8 * self._prefill_wh_per_token + 0.2 * sample)
        if (self.prefix_cache is not None
                and n_in >= self.prefix_cache.block_tokens):
            # register the completed prompt; the sim has no real KV, so
            # placeholder blocks stand in (matching is token-exact either
            # way, and capacity/eviction behave like the real pool)
            kv = np.zeros((1, n_in, 1, 1), np.float32)
            self.prefix_cache.insert(req.prompt_tokens, kv, kv)
        req.state = RequestState.DONE
        req.finish_s = self.clock()
        resp = Response(
            uid=req.uid, model_name=self.name, tokens=[], text="",
            latency_ms=latency_ms,
            queue_ms=(req.start_s - req.submit_s) * 1e3,
            energy_wh=pre_wh + dec_wh,
            input_tokens=n_in,
            output_tokens=out_tokens, ttft_ms=latency_ms,
            prefix_reused=req.prefix_reused, prefill_wh=pre_wh)
        resp.accuracy = acc  # type: ignore[attr-defined]
        return resp

    def step(self) -> List[Response]:
        if self._failed:
            raise EngineFailure(f"engine {self.name} failed")
        self._last_step_s = self.clock()
        out: List[Response] = []
        if not self.queue:
            return out
        keep: List[Request] = []
        active = 0
        tick_dt = 0.0
        for pos, req in enumerate(self.queue):
            if active >= self.concurrency:
                keep.extend(self.queue[pos:])
                break
            if req.defunct:
                self._progress.pop(req.uid, None)
                self._outcomes.pop(req.uid, None)
                continue                       # drop; frees its slot
            active += 1
            outcome = self._activate(req)
            # the tick takes as long as its slowest active request's
            # per-step share (slots run concurrently, like real slots)
            tick_dt = max(tick_dt,
                          outcome[2] / 1e3 / max(self.steps_per_query, 1))
            k = self._progress.get(req.uid, 0) + 1
            if k < self.steps_per_query:
                self._progress[req.uid] = k
                keep.append(req)
                continue
            self._progress.pop(req.uid, None)
            self._outcomes.pop(req.uid, None)
            out.append(self._finish(req, outcome))
        self.queue = keep
        self._modeled_time_s += tick_dt
        return out

    def restart(self) -> List[Request]:
        # like ModelEngine.restart: defunct requests are dropped, never
        # resurrected into the scheduler's bookkeeping
        inflight = [r for r in self.queue if not r.defunct]
        for r in inflight:
            r.state = RequestState.QUEUED
            r.start_s = 0.0
            r.prefix_reused = 0          # re-probes on re-admission
        self.queue = []
        self._progress.clear()
        self._outcomes.clear()
        self._failed = False
        return inflight
