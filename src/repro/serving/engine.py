"""Per-model serving engines with slot-based continuous batching.

Two backends behind one interface:

  * ``ModelEngine``  — a real JAX model (reduced config on CPU, full config
    on TPU): one jitted ``serve_step`` over a (max_batch,)-slot KV/state
    cache with *per-slot lengths*; prompt tokens stream through the same
    decode step (chunked prefill is a TODO noted in DESIGN), then greedy
    generation until EOS/max_new_tokens.  New requests are admitted into
    free slots between steps — in-flight requests are never stalled
    (continuous batching).
  * ``SimEngine``    — a timing/energy/accuracy model of a pool member
    (paper's 16-model pool has no public weights in this container); used
    by the paper-scale benchmarks.

Both report per-query energy via the analytic TPU model (core.energy) — the
zeus stand-in of DESIGN §4.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.energy import (CostModelParams, EnergyMonitor, JOULES_PER_WH,
                               decode_step_cost, energy_joules, roofline)
from repro.core.types import ModelProfile
from repro.models import api
from repro.models.config import ModelConfig
from repro.serving.request import Request, RequestState, Response


class EngineFailure(RuntimeError):
    pass


class BaseEngine:
    """Interface shared by real and simulated engines."""

    name: str
    profile: ModelProfile

    def submit(self, req: Request) -> None:
        raise NotImplementedError

    def submit_many(self, reqs: List[Request]) -> None:
        """Enqueue an already-routed batch slice in arrival order."""
        for req in reqs:
            self.submit(req)

    def step(self) -> List[Response]:
        raise NotImplementedError

    @property
    def pending(self) -> int:
        raise NotImplementedError

    # -- telemetry hooks -------------------------------------------------------

    def cumulative_joules(self) -> float:
        """Cumulative metered energy; sampled per scheduler step by the
        telemetry PowerTrace to derive a watts time-series."""
        return 0.0

    # -- fault-tolerance hooks -------------------------------------------------

    def heartbeat(self) -> float:
        return getattr(self, "_last_step_s", 0.0)

    def inject_failure(self) -> None:
        self._failed = True

    def restart(self) -> List[Request]:
        """Reset engine state; returns in-flight requests for re-queueing."""
        raise NotImplementedError


class ModelEngine(BaseEngine):
    """Real-model engine: continuous batching over a slotted cache."""

    def __init__(self, name: str, cfg: ModelConfig, key: jax.Array,
                 max_batch: int = 4, max_len: int = 256,
                 params=None, detokenize: Optional[Callable] = None):
        self.name = name
        self.cfg = dataclasses.replace(cfg, kv_update="where")
        self.max_batch = max_batch
        self.max_len = max_len
        self.params = params if params is not None else api.init_params(
            self.cfg, key)
        self.cache = api.init_cache(self.cfg, max_batch, max_len)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        self.detokenize = detokenize or (lambda toks: "")
        self._failed = False
        self._last_step_s = time.monotonic()
        self.energy = EnergyMonitor()
        self._step_joules = 0.0     # per-step metered energy (telemetry)
        self.cost_params = CostModelParams(
            n_params=float(cfg.param_count()),
            n_active_params=float(cfg.active_param_count()),
            d_model=cfg.d_model, n_layers=cfg.n_layers,
            kv_heads=max(cfg.n_kv_heads, 1), head_dim=cfg.head_dim)
        self.profile = ModelProfile(
            name=name, family=cfg.layout,
            params_b=cfg.param_count() / 1e9, arch_config=cfg)
        self.n_steps = 0

        def _step(params, cache, tokens):
            logits, cache = api.serve_step(params, tokens, cache, self.cfg)
            return jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32), cache

        self._jit_step = jax.jit(_step, donate_argnums=(1,))

    # -- queueing ----------------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.model_name = self.name
        self.queue.append(req)

    @property
    def pending(self) -> int:
        return len(self.queue) + sum(s is not None for s in self.slots)

    def _admit(self) -> None:
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                if req.state == RequestState.CANCELLED:
                    continue
                req.slot = i
                req.state = RequestState.PREFILL
                req.start_s = time.monotonic()
                self.slots[i] = req
                # reset the slot's cache length so it starts fresh
                self.cache["length"] = self.cache["length"].at[i].set(0)

    # -- the continuous-batching step ---------------------------------------------

    def step(self) -> List[Response]:
        if self._failed:
            raise EngineFailure(f"engine {self.name} failed")
        self._admit()
        self._last_step_s = time.monotonic()
        if not any(self.slots):
            return []
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if not req.prefill_done:
                tokens[i, 0] = req.prompt_tokens[req.n_prompt_fed]
            else:
                tokens[i, 0] = (req.generated[-1] if req.generated
                                else req.prompt_tokens[-1])
        next_tok, self.cache = self._jit_step(self.params, self.cache,
                                              jnp.asarray(tokens))
        next_tok = np.asarray(next_tok)
        self.n_steps += 1
        self._meter_step()

        finished: List[Response] = []
        now = time.monotonic()
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req.state == RequestState.CANCELLED:
                self.slots[i] = None
                continue
            if not req.prefill_done:
                req.n_prompt_fed += 1
                if req.prefill_done:
                    req.state = RequestState.DECODE
                    req.generated.append(int(next_tok[i]))
                    req.first_token_s = now
                continue
            req.generated.append(int(next_tok[i]))
            hit_eos = req.generated[-1] == req.eos_id
            full = len(req.generated) >= req.max_new_tokens
            overflow = int(self.cache["length"][i]) >= self.max_len - 1
            if hit_eos or full or overflow:
                finished.append(self._finish(i))
        return finished

    def _meter_step(self) -> None:
        """Accumulate this step's modeled energy from the analytic cost
        model over the active slots' host-tracked sequence lengths — the
        time-resolved counterpart of ``measure_query`` (which stays the
        per-query accounting of record).  No device sync: slot kv lengths
        are derived from request progress, not the cache."""
        joules = 0.0
        for req in self.slots:
            if req is None or req.state == RequestState.CANCELLED:
                continue
            kv_len = max(req.n_prompt_fed + len(req.generated), 1)
            f, b = decode_step_cost(self.cost_params, kv_len)
            joules += energy_joules(roofline(f, b, 0.0, self.energy.chips))
        self._step_joules += joules

    def cumulative_joules(self) -> float:
        return self._step_joules

    def _finish(self, slot: int) -> Response:
        req = self.slots[slot]
        self.slots[slot] = None
        req.state = RequestState.DONE
        req.finish_s = time.monotonic()
        out = [t for t in req.generated if t != req.eos_id]
        energy_wh = self.energy.measure_query(
            self.cost_params, len(req.prompt_tokens), len(out))
        ttft_ms = ((req.first_token_s - req.submit_s) * 1e3
                   if req.first_token_s else 0.0)
        return Response(
            uid=req.uid, model_name=self.name, tokens=out,
            text=self.detokenize(out), latency_ms=req.latency_ms,
            queue_ms=(req.start_s - req.submit_s) * 1e3,
            energy_wh=energy_wh, input_tokens=len(req.prompt_tokens),
            output_tokens=len(out), hedged_winner=req.hedged,
            ttft_ms=ttft_ms)

    def restart(self) -> List[Request]:
        inflight = [r for r in self.slots if r is not None] + self.queue
        for r in inflight:
            r.state = RequestState.QUEUED
            r.slot = -1
            r.generated = []
            r.n_prompt_fed = 0
            r.first_token_s = 0.0
        self.slots = [None] * self.max_batch
        self.queue = []
        self.cache = api.init_cache(self.cfg, self.max_batch, self.max_len)
        self._failed = False
        return inflight


class SimEngine(BaseEngine):
    """Pool-member simulator: latency/energy/accuracy from profiles.

    Used by the paper-scale benchmarks (16 models × 2500 queries in
    seconds).  ``outcome_fn(query, model_name) -> (accuracy, energy_wh,
    latency_ms, out_tokens)`` encapsulates the calibrated behaviour tables
    (repro.data.profiles).

    ``concurrency`` mirrors ``ModelEngine``'s slot semantics: up to that
    many queued requests make progress each step, so a deep queue drains
    ``k`` per step instead of strictly serially (paper-scale benches were
    previously pessimistic about queueing under load).
    """

    def __init__(self, profile: ModelProfile, outcome_fn,
                 steps_per_query: int = 1, concurrency: int = 1):
        if concurrency < 1:
            raise ValueError(f"concurrency must be >= 1, got {concurrency}")
        self.name = profile.name
        self.profile = profile
        self.outcome_fn = outcome_fn
        self.queue: List[Request] = []
        self.steps_per_query = steps_per_query
        self.concurrency = concurrency
        self._failed = False
        self._last_step_s = time.monotonic()
        self._progress: Dict[int, int] = {}
        self._joules = 0.0

    def submit(self, req: Request) -> None:
        req.model_name = self.name
        self.queue.append(req)

    @property
    def pending(self) -> int:
        return len(self.queue)

    def cumulative_joules(self) -> float:
        return self._joules

    def step(self) -> List[Response]:
        if self._failed:
            raise EngineFailure(f"engine {self.name} failed")
        self._last_step_s = time.monotonic()
        out: List[Response] = []
        if not self.queue:
            return out
        keep: List[Request] = []
        active = 0
        for pos, req in enumerate(self.queue):
            if active >= self.concurrency:
                keep.extend(self.queue[pos:])
                break
            if req.state == RequestState.CANCELLED:
                self._progress.pop(req.uid, None)
                continue                       # drop; frees its slot
            active += 1
            if req.start_s == 0.0:
                req.start_s = time.monotonic()
            k = self._progress.get(req.uid, 0) + 1
            if k < self.steps_per_query:
                self._progress[req.uid] = k
                keep.append(req)
                continue
            self._progress.pop(req.uid, None)
            acc, energy_wh, latency_ms, out_tokens = self.outcome_fn(
                req.query, self.name)
            req.state = RequestState.DONE
            req.finish_s = time.monotonic()
            self._joules += energy_wh * JOULES_PER_WH
            resp = Response(
                uid=req.uid, model_name=self.name, tokens=[], text="",
                latency_ms=latency_ms,
                queue_ms=(req.start_s - req.submit_s) * 1e3,
                energy_wh=energy_wh,
                input_tokens=len(req.prompt_tokens),
                output_tokens=out_tokens, ttft_ms=latency_ms)
            resp.accuracy = acc  # type: ignore[attr-defined]
            out.append(resp)
        self.queue = keep
        return out

    def restart(self) -> List[Request]:
        inflight = list(self.queue)
        for r in inflight:
            r.state = RequestState.QUEUED
            r.start_s = 0.0
        self.queue = []
        self._progress.clear()
        self._failed = False
        return inflight
