"""Deterministic chaos fault injection for serving engines.

``FaultInjector`` wraps any engine (SimEngine, ModelEngine, a fleet
shard's adopted engine) behind the same ``BaseEngine`` surface and
injects faults on a fixed schedule of :class:`FaultSpec` entries, all
timed against the injected ``clock`` — the same virtual clock the bench
loop advances, so a chaos run is reproducible tick-for-tick from its
seed.  The taxonomy (docs/RELIABILITY.md):

  ``crash``           one-shot: sets the inner engine's failure flag at
                      ``t_s`` — the next step raises ``EngineFailure``
                      and the scheduler's restart path takes over
  ``stall``           window: ``step()`` does nothing for ``duration_s``
                      (heartbeat freezes; modeled time still accrues a
                      small per-tick cost so virtual-clock loops keep
                      advancing instead of livelocking on a 0-dt tick)
  ``slow``            window: only every ``magnitude``-th tick reaches
                      the inner engine (a thermally-throttled / noisy
                      neighbour step-time multiplier)
  ``garbage``         window: completions come back corrupted — the
                      NaN/inf-logits failure mode.  Energy was really
                      burned; tokens/text are gone, accuracy is zero,
                      and ``resp.corrupt`` marks them for the scheduler
  ``drop_migration``  window: phase-boundary KV payloads vanish in
                      transit; the request re-prefills on this engine
                      (the same fallback as a vanished decode twin)

Deliberately NOT a ``BaseEngine`` subclass: the base class defines
``pending`` (and friends) as raising properties, which would shadow the
``__getattr__`` delegation below.  Everything not explicitly intercepted
— queues, caches, joule ledgers, heartbeats, the ``_failed`` flag —
resolves on the wrapped engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.serving.request import Request, RequestState, Response

FAULT_KINDS = ("crash", "stall", "slow", "garbage", "drop_migration")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault.  ``t_s`` is on the engine's clock (virtual
    seconds in the benches).  ``duration_s`` bounds window faults and is
    ignored by ``crash``; ``magnitude`` is the ``slow`` step multiplier
    (every m-th tick runs)."""

    t_s: float
    kind: str
    duration_s: float = 0.0
    magnitude: float = 2.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")

    def active(self, t: float) -> bool:
        return self.t_s <= t < self.t_s + self.duration_s


class FaultInjector:
    """Engine wrapper executing a :class:`FaultSpec` schedule (module
    docstring has the taxonomy).  ``stats`` counts what actually fired —
    the chaos bench asserts against it."""

    # modeled seconds a stalled/skipped tick still costs.  A stall that
    # accrued *zero* modeled time would freeze a virtual-clock loop (it
    # advances by the max per-tick modeled-time delta); a real stalled
    # accelerator still burns wall time, so the window elapses.
    STALL_TICK_S = 0.02

    def __init__(self, inner, faults: List[FaultSpec],
                 clock: Optional[Callable[[], float]] = None):
        self._inner = inner
        self.faults = sorted(faults, key=lambda f: f.t_s)
        self.clock = clock or getattr(inner, "clock", None) or time.monotonic
        self._crashed = set()      # indices of crash specs already fired
        self._tick = 0
        self._stall_time_s = 0.0
        self.stats: Dict[str, int] = {
            "crashes": 0, "stall_steps": 0, "slow_skips": 0,
            "garbage": 0, "dropped_migrations": 0}

    # -- schedule queries ------------------------------------------------------

    def _active_spec(self, t: float, kind: str) -> Optional[FaultSpec]:
        for spec in self.faults:
            if spec.kind == kind and spec.active(t):
                return spec
        return None

    def _fire_crashes(self, t: float) -> None:
        for i, spec in enumerate(self.faults):
            if spec.kind == "crash" and i not in self._crashed \
                    and t >= spec.t_s:
                self._crashed.add(i)
                self.stats["crashes"] += 1
                self._inner.inject_failure()

    # -- intercepted engine surface -------------------------------------------

    def step(self) -> List[Response]:
        t = self.clock()
        self._fire_crashes(t)      # inner.step() raises EngineFailure
        if self._active_spec(t, "stall") is not None:
            self.stats["stall_steps"] += 1
            self._stall_time_s += self.STALL_TICK_S
            return []
        slow = self._active_spec(t, "slow")
        if slow is not None:
            self._tick += 1
            if self._tick % max(int(slow.magnitude), 1):
                self.stats["slow_skips"] += 1
                self._stall_time_s += self.STALL_TICK_S
                return []
        out = self._inner.step()
        if out and self._active_spec(t, "garbage") is not None:
            for resp in out:
                # NaN/inf logits: the compute (and its energy) happened,
                # the output is unusable.  The scheduler decides whether
                # to retry (reliability on) or complete at zero accuracy.
                resp.tokens = []
                resp.text = ""
                resp.accuracy = 0.0          # type: ignore[attr-defined]
                resp.corrupt = True          # type: ignore[attr-defined]
                self.stats["garbage"] += 1
        return out

    def drain_migrations(self) -> List[Request]:
        out = self._inner.drain_migrations()
        if not out or self._active_spec(
                self.clock(), "drop_migration") is None:
            return out
        for req in out:
            # payload lost in transit — re-prefill locally, exactly the
            # scheduler's vanished-twin fallback (nothing is ever lost)
            req.kv_payload = None
            req.kv_migrated = 0
            req.prefill_wh = 0.0
            req.state = RequestState.QUEUED
            req.generated = []
            req.n_prompt_fed = 0
            req.prefix_reused = 0
            self._inner.submit(req)
            self.stats["dropped_migrations"] += 1
        return []

    def modeled_time_s(self) -> float:
        return self._inner.modeled_time_s() + self._stall_time_s

    def restart(self) -> List[Request]:
        # the schedule survives a restart: chaos doesn't stop because
        # the pool recovered (crashes already fired stay fired)
        return self._inner.restart()

    def __getattr__(self, name: str):
        return getattr(self._inner, name)


def fault_storm(span_s: float, target: str, others: List[str],
                seed: int = 0, frac_start: float = 0.35,
                frac_end: float = 0.65, n_crashes: int = 2,
                background_rate: float = 0.5) -> Dict[str, List[FaultSpec]]:
    """Seeded per-engine fault schedules for a chaos scenario: a
    concentrated crash + garbage storm on ``target`` inside the
    [frac_start, frac_end] window of the run's modeled span, plus sparse
    background stalls/slowdowns on ``others`` (~``background_rate``
    faults per engine).  Deterministic in (seed, span, names).

    Crashes are the bandit-proof damage: a garbage completion feeds the
    router a zero-accuracy observation it can learn from, but a crash on
    the legacy (reliability-off) path just replays the wiped work on the
    same engine once it restarts — the joules are burned twice and the
    router never hears about it.  ``n_crashes`` spreads that many crashes
    evenly through the window (jittered within their slots)."""
    rng = np.random.default_rng(seed)
    t0, t1 = span_s * frac_start, span_s * frac_end
    storm: List[FaultSpec] = [
        # garbage first: the arm degrades, the bandit and breaker see
        # zero-accuracy observations, then it crashes outright mid-window
        FaultSpec(t_s=t0, kind="garbage", duration_s=(t1 - t0)),
    ]
    slot = (t1 - t0) / max(n_crashes, 1)
    for i in range(max(n_crashes, 0)):
        lo = t0 + i * slot
        storm.append(FaultSpec(
            t_s=float(rng.uniform(lo, lo + slot)), kind="crash"))
    schedules: Dict[str, List[FaultSpec]] = {target: storm}
    for name in others:
        faults: List[FaultSpec] = []
        n = rng.poisson(background_rate)
        for _ in range(int(n)):
            kind = str(rng.choice(["stall", "slow"]))
            faults.append(FaultSpec(
                t_s=float(rng.uniform(0.0, span_s * 0.9)), kind=kind,
                duration_s=float(rng.uniform(0.02, span_s * 0.05)),
                magnitude=float(rng.integers(2, 5))))
        if faults:
            schedules[name] = faults
    return schedules


__all__ = ["FAULT_KINDS", "FaultSpec", "FaultInjector", "fault_storm"]
