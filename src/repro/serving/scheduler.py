"""GreenServ pool server: router → per-model engines → feedback loop.

Implements the paper's online deployment (§4.4) with the production
concerns of DESIGN §5:

  * caching: GreenCache (repro.cache) is consulted before routing — a
    semantic hit answers the query with zero engine work, and prefix-KV
    hit lengths become expected-energy discounts in the routing decision
    (only the queries that need compute are routed);
  * routing: every query goes through GreenServRouter (context → feasible →
    LinUCB), execution through the selected model's engine, and the
    measured (accuracy, energy, latency) closes the bandit loop;
  * continuous operation: engines are stepped round-robin, admitting new
    work between decode steps;
  * straggler mitigation: a request stuck behind a deep queue past its
    hedge deadline is duplicated onto the fastest feasible engine; the
    first completion wins, the loser is cancelled (hedged requests);
  * fault tolerance: engines carry heartbeats; a stalled or failed engine
    is restarted and its in-flight requests re-queued (after router
    re-routing, since the failed arm may be deprioritized);
  * model addition (§6.3.4): ``add_engine`` registers a new pool member at
    runtime — the router grows a fresh arm, zero offline calibration.
"""
from __future__ import annotations

import time
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Sequence

if TYPE_CHECKING:  # pragma: no cover - annotation only
    from repro.cache import GreenCache
    from repro.costmodel import EnergyCostModel
    from repro.telemetry.hub import Telemetry

import numpy as np

from repro.cache.semantic import SemanticEntry
from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import Feedback, ModelProfile, Query, RouterConfig
from repro.serving.engine import BaseEngine, EngineFailure
from repro.serving.request import Request, RequestState, Response


class LivelockError(TimeoutError):
    """``run_until_drained`` exhausted its step budget with live requests —
    the continuous-batching loop stopped making progress (a bug), or the
    budget is simply too small for the workload.  Subclasses TimeoutError
    so callers treating drain exhaustion as a timeout keep working."""


class PoolServer:
    """The GreenServ scheduler: routes queries, steps engines, closes the
    bandit loop.  ``hedge_after_steps`` is measured in scheduler steps
    spent QUEUED; ``heartbeat_timeout_s`` in wall-clock seconds.  Every
    pool-level serving setting — ``prefill_chunk`` (prompt tokens per
    engine prefill tick), the ``cache`` handles (GreenCache prefix-KV /
    semantic reuse, consulted before routing), telemetry pre-binding — is
    applied through one ``_configure_engine`` choke point at construction
    and again on ``add_engine``, so a server-level setting governs the
    whole pool including late joiners."""

    def __init__(self, router: GreenServRouter,
                 engines: Dict[str, BaseEngine],
                 tokenizer: Optional[Callable[[str], List[int]]] = None,
                 hedge_after_steps: Optional[int] = None,
                 heartbeat_timeout_s: float = 30.0,
                 accuracy_fn: Optional[Callable] = None,
                 telemetry: Optional["Telemetry"] = None,
                 prefill_chunk: Optional[int] = None,
                 cache: Optional["GreenCache"] = None,
                 decode_engines: Optional[Dict[str, BaseEngine]] = None,
                 cost_model: Optional["EnergyCostModel"] = None,
                 admission_planner: bool = False,
                 clock: Optional[Callable[[], float]] = None):
        names = router.pool.names
        missing = [n for n in names if n not in engines]
        if missing:
            raise ValueError(f"engines missing for pool members: {missing}")
        self.router = router
        self.engines = engines
        # prefill/decode disaggregation: primary-name → decode twin.  The
        # primary becomes the prefill-role engine; requests migrate to the
        # twin at phase boundary (docs/SERVING.md "Disaggregated serving")
        self.decode_engines: Dict[str, BaseEngine] = {}
        self.tokenizer = tokenizer or (lambda text: [1 + (ord(c) % 250)
                                                     for c in text[:32]])
        self.hedge_after_steps = hedge_after_steps
        self.heartbeat_timeout_s = heartbeat_timeout_s
        # injectable time source (same pattern as SemanticCache.clock):
        # virtual-clock benches pass the clock their SimEngines share, so
        # submit/heartbeat timestamps never mix wall and modeled time
        self.clock = clock or time.monotonic
        self.accuracy_fn = accuracy_fn
        self.telemetry = telemetry
        self.prefill_chunk = prefill_chunk
        self.cache = cache if (cache is None or cache.mode != "off") else None
        if self.cache is not None:
            # guard features must live in the router's embedding space
            self.cache.bind_context(router.context)
        # predictive energy cost model (repro.costmodel): pre-dispatch Wh
        # forecasts feeding the router tilt, the governor's in-flight
        # charge, and (when enabled) the energy-aware admission planner
        self.cost_model = cost_model
        self.admission_planner = bool(admission_planner)
        for name, eng in engines.items():
            self._configure_engine(name, eng, initial=True)
        if telemetry is not None and telemetry.governor is not None:
            telemetry.governor.attach(router)
        self.inflight: Dict[int, Request] = {}
        self.hedges: Dict[int, Request] = {}
        self.responses: Dict[int, Response] = {}
        self.wait_steps: Dict[int, int] = {}
        # continuous-batching arrivals queue: ``enqueue``d queries wait
        # here until a step() tick has free prefill capacity for them
        self.arrivals: List[Query] = []
        self.stats = {"hedges": 0, "restarts": 0, "completed": 0,
                      "cache_hits": 0, "migrations": 0, "deferred": 0}
        # feedback for completions collected during the current step(); the
        # router is updated once per step via feedback_batch
        self._fb_buffer: List[Feedback] = []
        for name, twin in (decode_engines or {}).items():
            self.attach_decode_engine(name, twin)

    # -- pool growth (paper §6.3.4) ---------------------------------------------

    def _configure_engine(self, name: str, engine: BaseEngine,
                          initial: bool = False,
                          role: Optional[str] = None) -> None:
        """Apply *every* pool-level serving setting to one engine — the
        single choke point used at construction and by ``add_engine``, so
        a late joiner can never silently miss a knob (prefill chunking,
        its prefix-KV cache handle, its phase role, telemetry
        pre-binding).  ``name`` is the telemetry display key (a decode
        twin shows up as ``<primary>#decode``); the prefix cache is keyed
        by the *model* name so twins share one cache (they share params —
        the KV blocks are interchangeable)."""
        if self.prefill_chunk is not None:
            engine.set_prefill_chunk(self.prefill_chunk)
        if self.cache is not None:
            model_name = name.split("#", 1)[0]
            engine.set_prefix_cache(self.cache.prefix_for(model_name))
        if role is not None:
            engine.set_role(role)
        if self.cost_model is not None:
            # the predictor is keyed by *model* name: a decode twin shares
            # the primary's cost model (same params, same shape terms —
            # the disaggregation surcharge is a flag set by
            # attach_decode_engine, not a separate predictor)
            self.cost_model.register_engine(name.split("#", 1)[0], engine)
        if self.telemetry is not None:
            self.telemetry.on_engine_added(name, engine, initial=initial)

    def add_engine(self, profile: ModelProfile, engine: BaseEngine,
                   decode_engine: Optional[BaseEngine] = None) -> None:
        """Zero-calibration model addition: new engine + fresh bandit arm.
        Every server-level setting (``prefill_chunk``, cache handles,
        telemetry hooks) applies to late joiners via _configure_engine.
        Pass ``decode_engine`` to register the member disaggregated from
        the start (the twin must share the primary's params)."""
        self._configure_engine(profile.name, engine)
        self.engines[profile.name] = engine
        self.router.pool.add(profile)   # fires the router's add-arm hook
        if decode_engine is not None:
            self.attach_decode_engine(profile.name, decode_engine)

    def attach_decode_engine(self, name: str, twin: BaseEngine) -> None:
        """Disaggregate pool member ``name``: the existing engine becomes
        the prefill-role engine and ``twin`` (sharing its params) takes
        the decode phase via KV migration.  Layouts without a full-depth
        positional KV cache can't export/import KV — ``set_role`` falls
        back to ``unified`` there, and the twin is not registered (the
        member keeps serving both phases on one engine)."""
        if name not in self.engines:
            raise KeyError(f"no pool member named {name!r}")
        primary = self.engines[name]
        primary.set_role("prefill")
        if primary.role != "prefill":       # unified fallback (e.g. rwkv)
            return
        self._configure_engine(f"{name}#decode", twin, role="decode")
        self.decode_engines[name] = twin
        if self.cost_model is not None:
            # the prior now charges the phase-boundary KV DMA too
            self.cost_model.set_disaggregated(name, True)

    # -- submission ---------------------------------------------------------------

    def submit(self, query: Query) -> Request:
        """Route and enqueue one query (a batch of one; tools/demos)."""
        return self.submit_batch([query])[0]

    def enqueue(self, query: Query) -> None:
        """Continuous-batching entry point: park an arrival until a
        ``step()`` tick has free prefill capacity for it.  Unlike
        ``submit``, routing is deferred to admission time — the bandit
        sees the queue state that actually exists when the query gets a
        slot, and a burst never floods engine queues beyond what the
        slots can absorb."""
        self.arrivals.append(query)

    def enqueue_many(self, queries: Sequence[Query]) -> None:
        self.arrivals.extend(queries)

    def _admit_arrivals(self) -> None:
        """Admit as many parked arrivals as the pool has free slots this
        tick (FIFO).  Capacity is summed over the routable (prefill-side)
        engines only — decode twins receive work through migration, never
        admission.  Admitted queries go through the normal batched
        ``submit_batch`` hot path (cache probe → route_batch → slices)."""
        if not self.arrivals:
            return
        free = sum(e.free_capacity for e in self.engines.values())
        if free <= 0:
            return
        batch, rest = self.arrivals[:free], self.arrivals[free:]
        if self._planner_active():
            batch, deferred = self._plan_admissions(batch)
            rest = deferred + rest
        self.arrivals = rest
        if batch:
            self.submit_batch(batch)

    def _planner_active(self) -> bool:
        """Energy-aware admission requires all three legs: the planner
        knob, a cost model to forecast with, and a governor whose budget
        headroom is the thing being planned against."""
        return (self.admission_planner and self.cost_model is not None
                and self.telemetry is not None
                and self.telemetry.governor is not None)

    def _engine_occupancy(self, name: str) -> float:
        """Fraction of the engine's slot/queue capacity in use — the cost
        model's batching-pressure feature."""
        eng = self.engines.get(name)
        if eng is None:
            return 0.0
        cap = max(int(getattr(eng, "max_batch", 0)
                      or getattr(eng, "concurrency", 0) or 1), 1)
        return min(eng.pending / cap, 1.0)

    def _plan_admissions(self, batch: List[Query]) -> tuple:
        """(admit, deferred): FIFO stop-at-first-breach over the
        governor's remaining per-tick Wh headroom (docs/ENERGY.md).  Each
        arrival is costed at its *cheapest* arm (the planner must never
        defer a query the router could still serve within budget); the
        first arrival whose forecast would breach the headroom stops the
        scan — no reordering, no cherry-picking behind the breach.  An
        idle pool always admits the head-of-line arrival regardless of
        headroom: admission pressure may slow the pool down, but it must
        never stop it (``run_until_drained`` would raise LivelockError)."""
        gov = self.telemetry.governor
        headroom = gov.admission_headroom_wh()
        names = self.router.pool.names
        costs = self.cost_model.predict_matrix(
            names, [len(self.tokenizer(q.text)) for q in batch],
            [q.max_new_tokens for q in batch],
            occupancy={n: self._engine_occupancy(n) for n in names})
        per_query = costs.min(axis=1)
        admit: List[Query] = []
        planned = 0.0
        breach_wh = 0.0
        pool_idle = not self.inflight
        for q, wh in zip(batch, per_query):
            if planned + wh > headroom:
                if not admit and pool_idle:
                    admit.append(q)      # head-of-line liveness guarantee
                    planned += float(wh)
                else:
                    breach_wh = float(wh)
                break
            admit.append(q)
            planned += float(wh)
        deferred = list(batch[len(admit):])
        if deferred:
            self.stats["deferred"] += len(deferred)
            self.telemetry.on_admission_deferred(
                len(deferred), predicted_wh=breach_wh,
                headroom_wh=max(headroom - planned, 0.0))
        return admit, deferred

    def submit_batch(self, queries: Sequence[Query]) -> List[Request]:
        """Admit a batch: cache consultation, then one ``route_batch`` call
        routes every remaining query and each engine receives its slice in
        arrival order.  This is the serving hot path — featurization and
        LinUCB scoring amortize over the batch instead of paying per-query
        dispatch.

        GreenCache runs *before* routing: a semantic hit short-circuits
        the query entirely (its Request comes back already DONE, the
        cached Response is immediately available in ``responses``), and
        per-(query, engine) prefix-KV hit lengths become expected-energy
        discounts in the router's arm scores plus an in-flight savings
        credit for the governor."""
        # routed models always come from the pool, so checking the
        # pool/engine invariant up front fails before ANY bookkeeping
        # (router pending entries included) — a half-registered batch
        # would sit in inflight forever with nothing dispatched
        missing = [n for n in self.router.pool.names
                   if n not in self.engines]
        if missing:
            raise KeyError(f"no engine for pool member(s): {missing}")
        req_by_uid: Dict[int, Request] = {}
        routable: List[Query] = []
        miss_features: List[Optional[tuple]] = []
        # one batched probe featurizes the whole admission (on the device
        # path this is a single fused kernel call); the per-query loop
        # below only does the similarity lookups
        probe = None
        if (self.cache is not None and self.cache.semantic_enabled
                and queries):
            probe = self.cache.features_batch([q.text for q in queries])
        for i, query in enumerate(queries):
            feats_in = (None if probe is None else
                        (int(probe[0][i]), int(probe[1][i]), probe[2][i]))
            hit, feats = self._try_semantic(query, feats_in)
            if hit is not None:
                req_by_uid[query.uid] = hit
            else:
                routable.append(query)
                miss_features.append(feats)
        tokens = [self.tokenizer(q.text) for q in routable]
        discounts = self._prefix_discounts(routable, tokens)
        # forward the cache probe's feature work (one batched embed +
        # classify — the device embeddings included) into routing instead
        # of re-deriving it there
        embs = labels = None
        if routable and miss_features[0] is not None:
            labels = np.asarray([f[0] for f in miss_features], np.int64)
            embs = np.stack([f[2] for f in miss_features])
        # pre-dispatch joule forecasts: a (Q, M) predicted-Wh matrix tilts
        # the routing decision per (query, arm), replacing the bandit's
        # coarse per-arm energy statistics for this decision
        costs = occ = None
        if self.cost_model is not None and routable:
            names = self.router.pool.names
            occ = {n: self._engine_occupancy(n) for n in names}
            costs = self.cost_model.predict_matrix(
                names, [len(t) for t in tokens],
                [q.max_new_tokens for q in routable], occupancy=occ)
        decisions = self.router.route_batch(
            routable, energy_discounts_wh=discounts,
            energy_costs_wh=costs, embeddings=embs, task_labels=labels)
        per_engine: Dict[str, List[Request]] = {}
        expected_savings_wh = 0.0
        predicted = [] if costs is not None else None
        for i, (query, decision) in enumerate(zip(routable, decisions)):
            req = Request(query=query, prompt_tokens=tokens[i],
                          max_new_tokens=query.max_new_tokens,
                          cache_features=miss_features[i],
                          submit_s=self.clock())
            per_engine.setdefault(decision.model_name, []).append(req)
            self.inflight[query.uid] = req
            self.wait_steps[query.uid] = 0
            req_by_uid[query.uid] = req
            if discounts is not None:
                expected_savings_wh += float(
                    discounts[i, decision.model_index])
            if costs is not None:
                # the query's forecast on the arm that won, net of its
                # predicted prefix-reuse saving (cold − discount = warm)
                wh = float(costs[i, decision.model_index])
                if discounts is not None:
                    wh = max(wh - float(discounts[i, decision.model_index]),
                             0.0)
                req.predicted_wh = wh
                self.cost_model.note_admission(
                    query.uid, decision.model_name, wh,
                    n_prompt=len(tokens[i]),
                    max_new_tokens=query.max_new_tokens,
                    occupancy=occ.get(decision.model_name, 0.0))
                predicted.append((query.uid, wh))
        for name, batch in per_engine.items():
            self.engines[name].submit_many(batch)
        if self.telemetry is not None:
            # with the cost model on, the per-uid predictions are already
            # net of prefix reuse — also crediting expected_savings_wh
            # would discount the governor's in-flight commitment twice
            self.telemetry.on_admit(
                len(routable), sum(e.pending for e in self.engines.values()),
                expected_savings_wh=(0.0 if costs is not None
                                     else expected_savings_wh),
                predicted=predicted)
        return [req_by_uid[q.uid] for q in queries]

    # -- GreenCache consultation (docs/CACHING.md) -------------------------------

    def _try_semantic(self, query: Query,
                      feats: Optional[tuple] = None) -> tuple:
        """(already-DONE Request | None, probe features | None).

        A hit synthesizes the cached completion as this query's Response
        (zero engine work, zero routing) and returns an already-DONE
        Request; the avoided energy — the cached completion's measured Wh
        — is credited via ``Telemetry.on_cache_hit("semantic", …)``.
        Cached queries never touch router state (no k-means update, no
        bandit pull): they are invisible to the learning loop, exactly
        like traffic that never arrived.  On a miss the computed
        (task, cluster, embedding) features come back so the query is
        embedded exactly once per lifecycle — routing and the
        completion-time insert both reuse them.  ``feats`` carries the
        batched admission probe's row for this query (one featurization
        pass per batch; on the device path, one fused kernel call)."""
        if self.cache is None or not self.cache.semantic_enabled:
            return None, None
        if feats is None:
            feats = self.cache.features(query.text)
        task, cluster, emb = feats
        entry = self.cache.semantic.lookup(emb, task, cluster)
        if entry is None:
            return None, feats
        resp = Response(
            uid=query.uid, model_name=entry.model_name,
            tokens=list(entry.tokens), text=entry.text_out,
            latency_ms=0.0, queue_ms=0.0, energy_wh=0.0,
            input_tokens=entry.input_tokens,
            output_tokens=entry.output_tokens, ttft_ms=0.0)
        resp.accuracy = entry.accuracy  # type: ignore[attr-defined]
        self.responses[query.uid] = resp
        self.stats["cache_hits"] += 1
        if self.telemetry is not None:
            self.telemetry.on_cache_hit("semantic", entry.energy_wh,
                                        model=entry.model_name)
        req = Request(query=query, prompt_tokens=[],
                      max_new_tokens=query.max_new_tokens,
                      state=RequestState.DONE,
                      model_name=entry.model_name)
        return req, feats

    def _prefix_discounts(self, queries: Sequence[Query],
                          tokens: Sequence[List[int]]
                          ) -> Optional[np.ndarray]:
        """(Q, n_models) expected Wh each engine's prefix cache would save
        per query — the router adds λ·ΔWh/scale to those arms' scores.
        Probes use ``peek_len`` (no LRU touch): an unrouted probe must not
        keep blocks warm.  With a cost model attached the discount is the
        calibrated predicted-suffix-minus-full (``discount_wh``) instead
        of the engine's raw analytic prefill estimate, so the tilt and
        the governor's in-flight charge come from the same forecaster."""
        if self.cache is None or not self.cache.prefix_enabled or not queries:
            return None
        names = self.router.pool.names
        disc = np.zeros((len(queries), len(names)), np.float64)
        for j, name in enumerate(names):
            eng = self.engines[name]
            pc = getattr(eng, "prefix_cache", None)
            if pc is None:
                continue
            occ = (self._engine_occupancy(name)
                   if self.cost_model is not None else 0.0)
            for i, toks in enumerate(tokens):
                p = pc.peek_len(toks, max_tokens=len(toks) - 1)
                if p > 0:
                    if self.cost_model is not None:
                        disc[i, j] = self.cost_model.discount_wh(
                            name, len(toks), queries[i].max_new_tokens,
                            p, occ)
                    else:
                        disc[i, j] = eng.estimate_prefill_wh(p)
        return disc if disc.any() else None

    # -- hedged (straggler-mitigating) dispatch ------------------------------------

    def _maybe_hedge(self) -> None:
        if self.hedge_after_steps is None:
            return
        for uid, req in list(self.inflight.items()):
            if req.done or uid in self.hedges or req.hedge_of is not None:
                continue
            if (req.state == RequestState.QUEUED
                    and self.wait_steps[uid] >= self.hedge_after_steps):
                # pick the least-loaded other engine as the hedge target
                others = [(e.pending, n) for n, e in self.engines.items()
                          if n != req.model_name]
                if not others:
                    continue
                _, target = min(others)
                hedge = Request(query=req.query,
                                prompt_tokens=list(req.prompt_tokens),
                                max_new_tokens=req.max_new_tokens,
                                hedged=True, hedge_of=uid,
                                submit_s=self.clock())
                self.engines[target].submit(hedge)
                self.hedges[uid] = hedge
                self.stats["hedges"] += 1
                if self.telemetry is not None:
                    self.telemetry.on_hedge(uid, target)

    # -- fault tolerance -------------------------------------------------------------

    def _check_engines(self) -> None:
        now = self.clock()
        for name, eng in self.engines.items():
            stalled = now - eng.heartbeat() > self.heartbeat_timeout_s
            if stalled or getattr(eng, "_failed", False):
                self._restart_engine(name)
        for name, twin in self.decode_engines.items():
            stalled = now - twin.heartbeat() > self.heartbeat_timeout_s
            if stalled or getattr(twin, "_failed", False):
                self._restart_engine(name, decode=True)

    def _restart_engine(self, name: str, decode: bool = False) -> None:
        eng = self.decode_engines[name] if decode else self.engines[name]
        inflight = eng.restart()
        self.stats["restarts"] += 1
        if self.telemetry is not None:
            self.telemetry.on_restart(f"{name}#decode" if decode else name,
                                      len(inflight))
        # flush buffered feedback first so re-routing sees the updated
        # bandit, and so no pending decision consumed by the flush is
        # overwritten by the re-route below
        self._flush_feedback()
        # displaced hedges are dropped, not resubmitted — clear their
        # bookkeeping so _maybe_hedge can protect the primary again
        for req in inflight:
            if (req.hedge_of is not None
                    and self.hedges.get(req.hedge_of) is req):
                req.state = RequestState.CANCELLED
                del self.hedges[req.hedge_of]
        # re-route the displaced batch in one shot: the bandit may now
        # prefer a different (healthy) arm.  restart() resets every held
        # request to QUEUED — including a hedge loser whose query was
        # already answered; resurrecting it would re-insert a finished uid
        # into inflight (never drains) and duplicate the work.
        primaries = [req for req in inflight
                     if req.hedge_of is None
                     and req.uid not in self.responses]
        if not primaries:
            return
        decisions = self.router.route_batch([req.query for req in primaries])
        for req, decision in zip(primaries, decisions):
            self.inflight[req.uid] = req
            self.engines[decision.model_name].submit(req)

    def _flush_feedback(self) -> None:
        if self._fb_buffer:
            fbs, self._fb_buffer = self._fb_buffer, []
            self.router.feedback_batch(fbs, strict=False)

    # -- completion -------------------------------------------------------------------

    def _complete(self, resp: Response, req: Request) -> None:
        primary_uid = req.hedge_of if req.hedge_of is not None else req.uid
        primary = self.inflight.get(primary_uid)
        if primary is None or primary_uid in self.responses:
            return                          # race already resolved
        # cancel the loser of a hedged pair
        if req.hedge_of is not None:        # hedge won
            primary.state = RequestState.CANCELLED
        elif primary_uid in self.hedges:    # primary won
            self.hedges[primary_uid].state = RequestState.CANCELLED
        hedged_pair = (req.hedge_of is not None
                       or primary_uid in self.hedges)
        accuracy = getattr(resp, "accuracy", None)
        if accuracy is None:
            accuracy = (self.accuracy_fn(primary.query, resp)
                        if self.accuracy_fn else 0.0)
        # buffered: the router is updated once per step via feedback_batch
        # (a hedge that finished on a non-routed arm is skipped at flush; a
        # hedge that won on an engine outside the pool has no arm at all)
        try:
            model_index = self.router.pool.index_of(resp.model_name)
        except KeyError:
            model_index = None
        if model_index is not None:
            self._fb_buffer.append(Feedback(
                query_uid=primary_uid, model_index=model_index,
                accuracy=float(accuracy), energy_wh=resp.energy_wh,
                latency_ms=resp.latency_ms,
                input_tokens=resp.input_tokens,
                output_tokens=resp.output_tokens))
        self.responses[primary_uid] = resp
        self.inflight.pop(primary_uid, None)
        self.hedges.pop(primary_uid, None)
        self.wait_steps.pop(primary_uid, None)
        self.stats["completed"] += 1
        if self.cache is not None and self.cache.semantic_enabled:
            # the admission-time probe features (one embed per query);
            # fall back to a fresh probe only if they were never stashed
            # (e.g. the cache was attached mid-flight)
            task, cluster, emb = (primary.cache_features
                                  or self.cache.features(primary.query.text))
            self.cache.semantic.insert(emb, SemanticEntry(
                text=primary.query.text, task_label=task, cluster=cluster,
                model_name=resp.model_name, tokens=list(resp.tokens),
                text_out=resp.text, energy_wh=resp.energy_wh,
                accuracy=float(accuracy), input_tokens=resp.input_tokens,
                output_tokens=resp.output_tokens))
        predicted_wh = None
        if self.cost_model is not None:
            # reconcile the admission-time forecast against the metered
            # Wh and fold the completion into the residual calibration
            predicted_wh = self.cost_model.observe_response(
                resp, float(accuracy))
        if self.telemetry is not None:
            self.telemetry.on_completion(resp, float(accuracy),
                                         predicted_wh=predicted_wh)
            if hedged_pair:
                # the cancelled duplicate's work never completes; charge
                # the energy budget for it (winner's cost as proxy)
                self.telemetry.on_duplicate_work(resp.energy_wh)

    # -- main loop ---------------------------------------------------------------------

    def step(self) -> List[Response]:
        """One scheduler tick: health checks, hedging, arrival admission
        into free prefill slots, one ``step()`` per engine (each engine
        tick is one jitted chunk-prefill or decode call, prefill-side
        engines first so a phase boundary migrates the same tick it is
        reached), the migration pump, one batched feedback flush, one
        telemetry/governor step.  Returns the responses completed this
        tick."""
        done: List[Response] = []
        self._check_engines()
        self._maybe_hedge()
        self._admit_arrivals()
        for name, eng in self.engines.items():
            try:
                for resp in eng.step():
                    req = self._find_request(resp.uid, name)
                    if req is not None:
                        self._complete(resp, req)
                        done.append(resp)
            except EngineFailure:
                self._restart_engine(name)
        for name, twin in self.decode_engines.items():
            try:
                for resp in twin.step():
                    req = self._find_request(resp.uid, name)
                    if req is not None:
                        self._complete(resp, req)
                        done.append(resp)
            except EngineFailure:
                self._restart_engine(name, decode=True)
        self._pump_migrations()
        self._flush_feedback()
        for uid, req in self.inflight.items():
            if req.state == RequestState.QUEUED:
                self.wait_steps[uid] = self.wait_steps.get(uid, 0) + 1
        # telemetry last: power samples see the step's energy, and the
        # governor's λ adjustment lands after this step's feedback flush
        if self.telemetry is not None:
            self.telemetry.on_step(self._all_engines())
        return done

    def _all_engines(self) -> Dict[str, BaseEngine]:
        """Telemetry view of the pool: primaries under their model name,
        decode twins under ``<name>#decode``."""
        if not self.decode_engines:
            return self.engines
        view = dict(self.engines)
        for name, twin in self.decode_engines.items():
            view[f"{name}#decode"] = twin
        return view

    def _pump_migrations(self) -> None:
        """Move phase-boundary requests from each prefill-role engine's
        outbox into its decode twin's queue.  Runs after engine stepping,
        so a prefill that completes at tick t starts decoding at t+1 —
        the one-tick handoff is the (honest) migration latency.  If the
        twin vanished mid-flight the request re-prefills on the primary
        (payload dropped); nothing is ever lost."""
        for name, eng in self.engines.items():
            if eng.role != "prefill":
                continue
            twin = self.decode_engines.get(name)
            for req in eng.drain_migrations():
                if req.state == RequestState.CANCELLED:
                    continue
                if twin is None:
                    req.kv_payload = None
                    req.kv_migrated = 0
                    req.prefill_wh = 0.0
                    req.state = RequestState.QUEUED
                    req.generated = []
                    req.n_prompt_fed = 0
                    req.prefix_reused = 0
                    eng.submit(req)
                    continue
                twin.submit_migrated(req)
                self.stats["migrations"] += 1
                if self.telemetry is not None:
                    self.telemetry.on_migration(name, req.kv_migrated)

    def _find_request(self, uid: int, engine_name: str) -> Optional[Request]:
        req = self.inflight.get(uid)
        if req is not None and req.model_name == engine_name:
            return req
        for primary_uid, hedge in self.hedges.items():
            if hedge.uid == uid and hedge.model_name == engine_name:
                return hedge
        return req

    def run_until_drained(self, max_steps: int = 100_000) -> None:
        """Step until nothing is in flight *and* no arrival is parked.
        Raises ``LivelockError`` (a ``TimeoutError``) if the step budget
        runs out with live work — a silent return here would mask a
        scheduler livelock, which the continuous loop must never hide."""
        for _ in range(max_steps):
            if not self.inflight and not self.arrivals:
                return
            self.step()
        if not self.inflight and not self.arrivals:
            return      # the budget's last step drained the pool
        raise LivelockError(
            f"{len(self.inflight)} request(s) still in flight and "
            f"{len(self.arrivals)} arrival(s) still parked after "
            f"{max_steps} steps")
