"""GreenServ pool server: router → per-model engines → feedback loop.

Implements the paper's online deployment (§4.4) with the production
concerns of DESIGN §5:

  * caching: GreenCache (repro.cache) is consulted before routing — a
    semantic hit answers the query with zero engine work, and prefix-KV
    hit lengths become expected-energy discounts in the routing decision
    (only the queries that need compute are routed);
  * routing: every query goes through GreenServRouter (context → feasible →
    LinUCB), execution through the selected model's engine, and the
    measured (accuracy, energy, latency) closes the bandit loop;
  * continuous operation: engines are stepped round-robin, admitting new
    work between decode steps;
  * straggler mitigation: a request stuck behind a deep queue past its
    hedge deadline is duplicated onto the fastest feasible engine; the
    first completion wins, the loser is cancelled (hedged requests);
  * fault tolerance: engines carry heartbeats; a stalled or failed engine
    is restarted and its in-flight requests re-queued (after router
    re-routing, since the failed arm may be deprioritized);
  * model addition (§6.3.4): ``add_engine`` registers a new pool member at
    runtime — the router grows a fresh arm, zero offline calibration.
"""
from __future__ import annotations

import time
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Sequence

if TYPE_CHECKING:  # pragma: no cover - annotation only
    from repro.cache import GreenCache
    from repro.costmodel import EnergyCostModel
    from repro.telemetry.hub import Telemetry

import numpy as np

from repro.cache.semantic import SemanticEntry
from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import Feedback, ModelProfile, Query, RouterConfig
from repro.serving.engine import BaseEngine, EngineFailure
from repro.serving.reliability import BreakerConfig, CircuitBreaker
from repro.serving.request import Request, RequestState, Response


class LivelockError(TimeoutError):
    """``run_until_drained`` exhausted its step budget with live requests —
    the continuous-batching loop stopped making progress (a bug), or the
    budget is simply too small for the workload.  Subclasses TimeoutError
    so callers treating drain exhaustion as a timeout keep working."""


class PoolServer:
    """The GreenServ scheduler: routes queries, steps engines, closes the
    bandit loop.  ``hedge_after_steps`` is measured in scheduler steps
    spent QUEUED; ``heartbeat_timeout_s`` in wall-clock seconds.  Every
    pool-level serving setting — ``prefill_chunk`` (prompt tokens per
    engine prefill tick), the ``cache`` handles (GreenCache prefix-KV /
    semantic reuse, consulted before routing), telemetry pre-binding — is
    applied through one ``_configure_engine`` choke point at construction
    and again on ``add_engine``, so a server-level setting governs the
    whole pool including late joiners."""

    def __init__(self, router: GreenServRouter,
                 engines: Dict[str, BaseEngine],
                 tokenizer: Optional[Callable[[str], List[int]]] = None,
                 hedge_after_steps: Optional[int] = None,
                 heartbeat_timeout_s: float = 30.0,
                 accuracy_fn: Optional[Callable] = None,
                 telemetry: Optional["Telemetry"] = None,
                 prefill_chunk: Optional[int] = None,
                 cache: Optional["GreenCache"] = None,
                 decode_engines: Optional[Dict[str, BaseEngine]] = None,
                 cost_model: Optional["EnergyCostModel"] = None,
                 admission_planner: bool = False,
                 clock: Optional[Callable[[], float]] = None,
                 deadline_s: Optional[float] = None,
                 max_retries: int = 0,
                 retry_backoff_steps: int = 2,
                 breaker_config: Optional[BreakerConfig] = None):
        names = router.pool.names
        missing = [n for n in names if n not in engines]
        if missing:
            raise ValueError(f"engines missing for pool members: {missing}")
        self.router = router
        self.engines = engines
        # prefill/decode disaggregation: primary-name → decode twin.  The
        # primary becomes the prefill-role engine; requests migrate to the
        # twin at phase boundary (docs/SERVING.md "Disaggregated serving")
        self.decode_engines: Dict[str, BaseEngine] = {}
        self.tokenizer = tokenizer or (lambda text: [1 + (ord(c) % 250)
                                                     for c in text[:32]])
        self.hedge_after_steps = hedge_after_steps
        self.heartbeat_timeout_s = heartbeat_timeout_s
        # injectable time source (same pattern as SemanticCache.clock):
        # virtual-clock benches pass the clock their SimEngines share, so
        # submit/heartbeat timestamps never mix wall and modeled time
        self.clock = clock or time.monotonic
        self.accuracy_fn = accuracy_fn
        self.telemetry = telemetry
        self.prefill_chunk = prefill_chunk
        self.cache = cache if (cache is None or cache.mode != "off") else None
        if self.cache is not None:
            # guard features must live in the router's embedding space
            self.cache.bind_context(router.context)
        # predictive energy cost model (repro.costmodel): pre-dispatch Wh
        # forecasts feeding the router tilt, the governor's in-flight
        # charge, and (when enabled) the energy-aware admission planner
        self.cost_model = cost_model
        self.admission_planner = bool(admission_planner)
        # reliability layer (docs/RELIABILITY.md): per-request end-to-end
        # deadline (None = no deadlines), retry budget per request, retry
        # backoff in *scheduler steps* (virtual-clock aware — the benches
        # advance modeled time, not wall time), and per-(engine, role)
        # circuit breakers (None = breakers off).  All defaults preserve
        # the reliability-off behaviour exactly.
        self.deadline_s = deadline_s
        self.max_retries = int(max_retries)
        self.retry_backoff_steps = max(int(retry_backoff_steps), 1)
        self.breaker_config = breaker_config
        self.breakers: Dict[str, CircuitBreaker] = {}
        self._step_idx = 0
        # retries parked for backoff: (due_step, request, failed engine).
        # Parked requests stay in ``inflight`` so drain conditions and
        # fleet fail-over account for them.
        self._retry_parked: List[tuple] = []
        self._parked_uids: set = set()
        # terminal registry: TIMED_OUT / FAILED requests (no Response
        # exists; ``responses`` ∪ ``failed`` covers every admitted uid)
        self.failed: Dict[int, Request] = {}
        for name, eng in engines.items():
            self._configure_engine(name, eng, initial=True)
        if telemetry is not None and telemetry.governor is not None:
            telemetry.governor.attach(router)
        self.inflight: Dict[int, Request] = {}
        self.hedges: Dict[int, Request] = {}
        self.responses: Dict[int, Response] = {}
        self.wait_steps: Dict[int, int] = {}
        # continuous-batching arrivals queue: ``enqueue``d queries wait
        # here until a step() tick has free prefill capacity for them
        self.arrivals: List[Query] = []
        self.stats = {"hedges": 0, "restarts": 0, "completed": 0,
                      "cache_hits": 0, "migrations": 0, "deferred": 0,
                      "retries": 0, "timeouts": 0, "failed": 0,
                      "slo_violations": 0, "breaker_opens": 0}
        # cumulative routing decisions landed per engine (primaries,
        # hedges, retries, restart replays) — the trajectory signal the
        # chaos bench reads to show breakers shifting share off a bad arm
        self.dispatch_counts: Dict[str, int] = {}
        # feedback for completions collected during the current step(); the
        # router is updated once per step via feedback_batch
        self._fb_buffer: List[Feedback] = []
        for name, twin in (decode_engines or {}).items():
            self.attach_decode_engine(name, twin)
        if self.breaker_config is not None:
            # OPEN arms vanish from route_batch's argmax on both scoring
            # backends (the mask rides the feasibility matrix)
            self.router.set_arm_health(self._arm_health_mask)

    # -- pool growth (paper §6.3.4) ---------------------------------------------

    def _configure_engine(self, name: str, engine: BaseEngine,
                          initial: bool = False,
                          role: Optional[str] = None) -> None:
        """Apply *every* pool-level serving setting to one engine — the
        single choke point used at construction and by ``add_engine``, so
        a late joiner can never silently miss a knob (prefill chunking,
        its prefix-KV cache handle, its phase role, telemetry
        pre-binding).  ``name`` is the telemetry display key (a decode
        twin shows up as ``<primary>#decode``); the prefix cache is keyed
        by the *model* name so twins share one cache (they share params —
        the KV blocks are interchangeable)."""
        if self.prefill_chunk is not None:
            engine.set_prefill_chunk(self.prefill_chunk)
        if self.cache is not None:
            model_name = name.split("#", 1)[0]
            engine.set_prefix_cache(self.cache.prefix_for(model_name))
        if role is not None:
            engine.set_role(role)
        if self.cost_model is not None:
            # the predictor is keyed by *model* name: a decode twin shares
            # the primary's cost model (same params, same shape terms —
            # the disaggregation surcharge is a flag set by
            # attach_decode_engine, not a separate predictor)
            self.cost_model.register_engine(name.split("#", 1)[0], engine)
        if self.telemetry is not None:
            self.telemetry.on_engine_added(name, engine, initial=initial)
        if self.breaker_config is not None and name not in self.breakers:
            # one breaker per (engine, role): primaries under their model
            # name, decode twins under ``<name>#decode``.  Only primary
            # breakers enter the routing mask (twins receive work through
            # migration, not routing); twin breakers still gate hedging
            # and feed the transition telemetry.
            self.breakers[name] = CircuitBreaker(
                self.breaker_config,
                on_transition=self._breaker_transition_hook(name))

    def _breaker_transition_hook(self, name: str) -> Callable:
        def hook(old: str, new: str, step: int) -> None:
            if new == "open":
                self.stats["breaker_opens"] += 1
            if self.telemetry is not None:
                self.telemetry.on_breaker(name, old, new, step)
        return hook

    def _arm_health_mask(self) -> Optional[np.ndarray]:
        """(n_models,) bool for the router: False = breaker holds the arm
        (OPEN, or HALF_OPEN at its probe quota).  Polled once per
        ``route_batch``; ``routable`` also advances OPEN→HALF_OPEN."""
        names = self.router.pool.names
        out = np.ones(len(names), bool)
        for j, n in enumerate(names):
            br = self.breakers.get(n)
            if br is None:
                continue
            eng = self.engines.get(n)
            out[j] = br.routable(self._step_idx,
                                 pending=(eng.pending if eng is not None
                                          else 0))
        return out

    def add_engine(self, profile: ModelProfile, engine: BaseEngine,
                   decode_engine: Optional[BaseEngine] = None) -> None:
        """Zero-calibration model addition: new engine + fresh bandit arm.
        Every server-level setting (``prefill_chunk``, cache handles,
        telemetry hooks) applies to late joiners via _configure_engine.
        Pass ``decode_engine`` to register the member disaggregated from
        the start (the twin must share the primary's params)."""
        self._configure_engine(profile.name, engine)
        self.engines[profile.name] = engine
        self.router.pool.add(profile)   # fires the router's add-arm hook
        if decode_engine is not None:
            self.attach_decode_engine(profile.name, decode_engine)

    def attach_decode_engine(self, name: str, twin: BaseEngine) -> None:
        """Disaggregate pool member ``name``: the existing engine becomes
        the prefill-role engine and ``twin`` (sharing its params) takes
        the decode phase via KV migration.  Layouts without a full-depth
        positional KV cache can't export/import KV — ``set_role`` falls
        back to ``unified`` there, and the twin is not registered (the
        member keeps serving both phases on one engine)."""
        if name not in self.engines:
            raise KeyError(f"no pool member named {name!r}")
        primary = self.engines[name]
        primary.set_role("prefill")
        if primary.role != "prefill":       # unified fallback (e.g. rwkv)
            return
        self._configure_engine(f"{name}#decode", twin, role="decode")
        self.decode_engines[name] = twin
        if self.cost_model is not None:
            # the prior now charges the phase-boundary KV DMA too
            self.cost_model.set_disaggregated(name, True)

    # -- submission ---------------------------------------------------------------

    def submit(self, query: Query) -> Request:
        """Route and enqueue one query (a batch of one; tools/demos)."""
        return self.submit_batch([query])[0]

    def enqueue(self, query: Query) -> None:
        """Continuous-batching entry point: park an arrival until a
        ``step()`` tick has free prefill capacity for it.  Unlike
        ``submit``, routing is deferred to admission time — the bandit
        sees the queue state that actually exists when the query gets a
        slot, and a burst never floods engine queues beyond what the
        slots can absorb."""
        self.arrivals.append(query)

    def enqueue_many(self, queries: Sequence[Query]) -> None:
        self.arrivals.extend(queries)

    def _admit_arrivals(self) -> None:
        """Admit as many parked arrivals as the pool has free slots this
        tick (FIFO).  Capacity is summed over the routable (prefill-side)
        engines only — decode twins receive work through migration, never
        admission.  Admitted queries go through the normal batched
        ``submit_batch`` hot path (cache probe → route_batch → slices)."""
        if not self.arrivals:
            return
        free = sum(e.free_capacity for e in self.engines.values())
        if free <= 0:
            return
        batch, rest = self.arrivals[:free], self.arrivals[free:]
        if self._planner_active():
            batch, deferred = self._plan_admissions(batch)
            rest = deferred + rest
        self.arrivals = rest
        if batch:
            self.submit_batch(batch)

    def _planner_active(self) -> bool:
        """Energy-aware admission requires all three legs: the planner
        knob, a cost model to forecast with, and a governor whose budget
        headroom is the thing being planned against."""
        return (self.admission_planner and self.cost_model is not None
                and self.telemetry is not None
                and self.telemetry.governor is not None)

    def _engine_occupancy(self, name: str) -> float:
        """Fraction of the engine's slot/queue capacity in use — the cost
        model's batching-pressure feature."""
        eng = self.engines.get(name)
        if eng is None:
            return 0.0
        cap = max(int(getattr(eng, "max_batch", 0)
                      or getattr(eng, "concurrency", 0) or 1), 1)
        return min(eng.pending / cap, 1.0)

    def _plan_admissions(self, batch: List[Query]) -> tuple:
        """(admit, deferred): FIFO stop-at-first-breach over the
        governor's remaining per-tick Wh headroom (docs/ENERGY.md).  Each
        arrival is costed at its *cheapest* arm (the planner must never
        defer a query the router could still serve within budget); the
        first arrival whose forecast would breach the headroom stops the
        scan — no reordering, no cherry-picking behind the breach.  An
        idle pool always admits the head-of-line arrival regardless of
        headroom: admission pressure may slow the pool down, but it must
        never stop it (``run_until_drained`` would raise LivelockError)."""
        gov = self.telemetry.governor
        headroom = gov.admission_headroom_wh()
        names = self.router.pool.names
        costs = self.cost_model.predict_matrix(
            names, [len(self.tokenizer(q.text)) for q in batch],
            [q.max_new_tokens for q in batch],
            occupancy={n: self._engine_occupancy(n) for n in names})
        per_query = costs.min(axis=1)
        admit: List[Query] = []
        planned = 0.0
        breach_wh = 0.0
        pool_idle = not self.inflight
        for q, wh in zip(batch, per_query):
            if planned + wh > headroom:
                if not admit and pool_idle:
                    admit.append(q)      # head-of-line liveness guarantee
                    planned += float(wh)
                else:
                    breach_wh = float(wh)
                break
            admit.append(q)
            planned += float(wh)
        deferred = list(batch[len(admit):])
        if deferred:
            self.stats["deferred"] += len(deferred)
            self.telemetry.on_admission_deferred(
                len(deferred), predicted_wh=breach_wh,
                headroom_wh=max(headroom - planned, 0.0))
        return admit, deferred

    def submit_batch(self, queries: Sequence[Query]) -> List[Request]:
        """Admit a batch: cache consultation, then one ``route_batch`` call
        routes every remaining query and each engine receives its slice in
        arrival order.  This is the serving hot path — featurization and
        LinUCB scoring amortize over the batch instead of paying per-query
        dispatch.

        GreenCache runs *before* routing: a semantic hit short-circuits
        the query entirely (its Request comes back already DONE, the
        cached Response is immediately available in ``responses``), and
        per-(query, engine) prefix-KV hit lengths become expected-energy
        discounts in the router's arm scores plus an in-flight savings
        credit for the governor."""
        # routed models always come from the pool, so checking the
        # pool/engine invariant up front fails before ANY bookkeeping
        # (router pending entries included) — a half-registered batch
        # would sit in inflight forever with nothing dispatched
        missing = [n for n in self.router.pool.names
                   if n not in self.engines]
        if missing:
            raise KeyError(f"no engine for pool member(s): {missing}")
        req_by_uid: Dict[int, Request] = {}
        routable: List[Query] = []
        miss_features: List[Optional[tuple]] = []
        # one batched probe featurizes the whole admission (on the device
        # path this is a single fused kernel call); the per-query loop
        # below only does the similarity lookups
        probe = None
        if (self.cache is not None and self.cache.semantic_enabled
                and queries):
            probe = self.cache.features_batch([q.text for q in queries])
        for i, query in enumerate(queries):
            feats_in = (None if probe is None else
                        (int(probe[0][i]), int(probe[1][i]), probe[2][i]))
            hit, feats = self._try_semantic(query, feats_in)
            if hit is not None:
                req_by_uid[query.uid] = hit
            else:
                routable.append(query)
                miss_features.append(feats)
        tokens = [self.tokenizer(q.text) for q in routable]
        discounts = self._prefix_discounts(routable, tokens)
        # forward the cache probe's feature work (one batched embed +
        # classify — the device embeddings included) into routing instead
        # of re-deriving it there
        embs = labels = None
        if routable and miss_features[0] is not None:
            labels = np.asarray([f[0] for f in miss_features], np.int64)
            embs = np.stack([f[2] for f in miss_features])
        # pre-dispatch joule forecasts: a (Q, M) predicted-Wh matrix tilts
        # the routing decision per (query, arm), replacing the bandit's
        # coarse per-arm energy statistics for this decision
        costs = occ = None
        if self.cost_model is not None and routable:
            names = self.router.pool.names
            occ = {n: self._engine_occupancy(n) for n in names}
            costs = self.cost_model.predict_matrix(
                names, [len(t) for t in tokens],
                [q.max_new_tokens for q in routable], occupancy=occ)
        decisions = self.router.route_batch(
            routable, energy_discounts_wh=discounts,
            energy_costs_wh=costs, embeddings=embs, task_labels=labels)
        per_engine: Dict[str, List[Request]] = {}
        expected_savings_wh = 0.0
        predicted = [] if costs is not None else None
        for i, (query, decision) in enumerate(zip(routable, decisions)):
            req = Request(query=query, prompt_tokens=tokens[i],
                          max_new_tokens=query.max_new_tokens,
                          cache_features=miss_features[i],
                          submit_s=self.clock(),
                          deadline_s=(self.deadline_s or 0.0),
                          max_retries=self.max_retries)
            per_engine.setdefault(decision.model_name, []).append(req)
            self.inflight[query.uid] = req
            self.wait_steps[query.uid] = 0
            req_by_uid[query.uid] = req
            if discounts is not None:
                expected_savings_wh += float(
                    discounts[i, decision.model_index])
            if costs is not None:
                # the query's forecast on the arm that won, net of its
                # predicted prefix-reuse saving (cold − discount = warm)
                wh = float(costs[i, decision.model_index])
                if discounts is not None:
                    wh = max(wh - float(discounts[i, decision.model_index]),
                             0.0)
                req.predicted_wh = wh
                self.cost_model.note_admission(
                    query.uid, decision.model_name, wh,
                    n_prompt=len(tokens[i]),
                    max_new_tokens=query.max_new_tokens,
                    occupancy=occ.get(decision.model_name, 0.0))
                predicted.append((query.uid, wh))
        for name, batch in per_engine.items():
            self.engines[name].submit_many(batch)
            self.dispatch_counts[name] = (
                self.dispatch_counts.get(name, 0) + len(batch))
        if self.telemetry is not None:
            # with the cost model on, the per-uid predictions are already
            # net of prefix reuse — also crediting expected_savings_wh
            # would discount the governor's in-flight commitment twice
            self.telemetry.on_admit(
                len(routable), sum(e.pending for e in self.engines.values()),
                expected_savings_wh=(0.0 if costs is not None
                                     else expected_savings_wh),
                predicted=predicted)
        return [req_by_uid[q.uid] for q in queries]

    # -- GreenCache consultation (docs/CACHING.md) -------------------------------

    def _try_semantic(self, query: Query,
                      feats: Optional[tuple] = None) -> tuple:
        """(already-DONE Request | None, probe features | None).

        A hit synthesizes the cached completion as this query's Response
        (zero engine work, zero routing) and returns an already-DONE
        Request; the avoided energy — the cached completion's measured Wh
        — is credited via ``Telemetry.on_cache_hit("semantic", …)``.
        Cached queries never touch router state (no k-means update, no
        bandit pull): they are invisible to the learning loop, exactly
        like traffic that never arrived.  On a miss the computed
        (task, cluster, embedding) features come back so the query is
        embedded exactly once per lifecycle — routing and the
        completion-time insert both reuse them.  ``feats`` carries the
        batched admission probe's row for this query (one featurization
        pass per batch; on the device path, one fused kernel call)."""
        if self.cache is None or not self.cache.semantic_enabled:
            return None, None
        if feats is None:
            feats = self.cache.features(query.text)
        task, cluster, emb = feats
        entry = self.cache.semantic.lookup(emb, task, cluster)
        if entry is None:
            return None, feats
        resp = Response(
            uid=query.uid, model_name=entry.model_name,
            tokens=list(entry.tokens), text=entry.text_out,
            latency_ms=0.0, queue_ms=0.0, energy_wh=0.0,
            input_tokens=entry.input_tokens,
            output_tokens=entry.output_tokens, ttft_ms=0.0)
        resp.accuracy = entry.accuracy  # type: ignore[attr-defined]
        self.responses[query.uid] = resp
        self.stats["cache_hits"] += 1
        if self.telemetry is not None:
            self.telemetry.on_cache_hit("semantic", entry.energy_wh,
                                        model=entry.model_name)
        req = Request(query=query, prompt_tokens=[],
                      max_new_tokens=query.max_new_tokens,
                      state=RequestState.DONE,
                      model_name=entry.model_name)
        return req, feats

    def _prefix_discounts(self, queries: Sequence[Query],
                          tokens: Sequence[List[int]]
                          ) -> Optional[np.ndarray]:
        """(Q, n_models) expected Wh each engine's prefix cache would save
        per query — the router adds λ·ΔWh/scale to those arms' scores.
        Probes use ``peek_len`` (no LRU touch): an unrouted probe must not
        keep blocks warm.  With a cost model attached the discount is the
        calibrated predicted-suffix-minus-full (``discount_wh``) instead
        of the engine's raw analytic prefill estimate, so the tilt and
        the governor's in-flight charge come from the same forecaster."""
        if self.cache is None or not self.cache.prefix_enabled or not queries:
            return None
        names = self.router.pool.names
        disc = np.zeros((len(queries), len(names)), np.float64)
        for j, name in enumerate(names):
            eng = self.engines[name]
            pc = getattr(eng, "prefix_cache", None)
            if pc is None:
                continue
            occ = (self._engine_occupancy(name)
                   if self.cost_model is not None else 0.0)
            for i, toks in enumerate(tokens):
                p = pc.peek_len(toks, max_tokens=len(toks) - 1)
                if p > 0:
                    if self.cost_model is not None:
                        disc[i, j] = self.cost_model.discount_wh(
                            name, len(toks), queries[i].max_new_tokens,
                            p, occ)
                    else:
                        disc[i, j] = eng.estimate_prefill_wh(p)
        return disc if disc.any() else None

    # -- hedged (straggler-mitigating) dispatch ------------------------------------

    def _engine_healthy(self, name: str, eng: BaseEngine) -> bool:
        """Hedge-target health gate: a failed/stalled-heartbeat engine or
        a breaker-held arm must never receive a hedge — duplicating onto
        a sick engine doubles the work and saves nothing."""
        if getattr(eng, "_failed", False):
            return False
        if self.clock() - eng.heartbeat() > self.heartbeat_timeout_s:
            return False
        br = self.breakers.get(name)
        if br is not None and not br.routable(self._step_idx,
                                              pending=eng.pending):
            return False
        return True

    def _maybe_hedge(self) -> None:
        if self.hedge_after_steps is None:
            return
        for uid, req in list(self.inflight.items()):
            if req.done or uid in self.hedges or req.hedge_of is not None:
                continue
            if uid in self._parked_uids:
                continue        # backing off for a retry, not straggling
            if (req.state == RequestState.QUEUED
                    and self.wait_steps[uid] >= self.hedge_after_steps):
                # pick the least-loaded *healthy* other engine as target
                others = [(e.pending, n) for n, e in self.engines.items()
                          if n != req.model_name
                          and self._engine_healthy(n, e)]
                if not others:
                    continue
                _, target = min(others)
                hedge = Request(query=req.query,
                                prompt_tokens=list(req.prompt_tokens),
                                max_new_tokens=req.max_new_tokens,
                                hedged=True, hedge_of=uid,
                                submit_s=self.clock(),
                                deadline_s=req.deadline_s)
                self.engines[target].submit(hedge)
                self.dispatch_counts[target] = (
                    self.dispatch_counts.get(target, 0) + 1)
                self.hedges[uid] = hedge
                self.stats["hedges"] += 1
                if self.telemetry is not None:
                    self.telemetry.on_hedge(uid, target)

    # -- fault tolerance -------------------------------------------------------------

    def _check_engines(self) -> None:
        now = self.clock()
        for name, eng in self.engines.items():
            stalled = now - eng.heartbeat() > self.heartbeat_timeout_s
            if stalled or getattr(eng, "_failed", False):
                self._restart_engine(name)
        for name, twin in self.decode_engines.items():
            stalled = now - twin.heartbeat() > self.heartbeat_timeout_s
            if stalled or getattr(twin, "_failed", False):
                self._restart_engine(name, decode=True)

    def _restart_engine(self, name: str, decode: bool = False) -> None:
        eng = self.decode_engines[name] if decode else self.engines[name]
        inflight = eng.restart()
        self.stats["restarts"] += 1
        if self.telemetry is not None:
            self.telemetry.on_restart(f"{name}#decode" if decode else name,
                                      len(inflight))
        # flush buffered feedback first so re-routing sees the updated
        # bandit, and so no pending decision consumed by the flush is
        # overwritten by the re-route below
        self._flush_feedback()
        # displaced hedges are dropped, not resubmitted — clear their
        # bookkeeping so _maybe_hedge can protect the primary again
        for req in inflight:
            if (req.hedge_of is not None
                    and self.hedges.get(req.hedge_of) is req):
                req.state = RequestState.CANCELLED
                del self.hedges[req.hedge_of]
        # re-route the displaced batch in one shot: the bandit may now
        # prefer a different (healthy) arm.  restart() resets every held
        # request to QUEUED — including a hedge loser whose query was
        # already answered; resurrecting it would re-insert a finished uid
        # into inflight (never drains) and duplicate the work.
        display = f"{name}#decode" if decode else name
        primaries = [req for req in inflight
                     if req.hedge_of is None
                     and req.uid not in self.responses
                     and req.uid not in self.failed]
        # retry-eligible requests go through the reliability path: the
        # failure is recorded (breaker + zero-accuracy bandit observation)
        # and the request backs off before re-routing *away* from this
        # arm.  Requests without a retry budget keep the legacy immediate
        # re-route (no failure feedback — their pending decision must
        # survive for the eventual completion).
        retriable = [r for r in primaries if r.max_retries > 0]
        replay = [r for r in primaries if r.max_retries == 0]
        if not retriable:
            br = self.breakers.get(display)
            if br is not None:
                # no per-request evidence will be recorded, but the
                # restart itself is evidence against the arm
                br.record_failure(self._step_idx)
        for req in retriable:
            self._schedule_retry_or_fail(req, display, "engine_restart")
        if not replay:
            return
        decisions = self.router.route_batch([req.query for req in replay])
        for req, decision in zip(replay, decisions):
            self.inflight[req.uid] = req
            self.engines[decision.model_name].submit(req)
            self.dispatch_counts[decision.model_name] = (
                self.dispatch_counts.get(decision.model_name, 0) + 1)

    def _flush_feedback(self) -> None:
        if self._fb_buffer:
            fbs, self._fb_buffer = self._fb_buffer, []
            self.router.feedback_batch(fbs, strict=False)

    # -- deadlines + retries (docs/RELIABILITY.md) ---------------------------------

    def _attempt_failed(self, req: Request, engine_name: str, reason: str,
                        energy_wh: float = 0.0) -> None:
        """One dispatch of ``req`` died on ``engine_name``: record it with
        the arm's breaker, feed the bandit the failure as a *real*
        observation (the energy actually burned, zero accuracy — LinUCB
        learns to avoid a degrading engine before the breaker trips), and
        let telemetry charge the wasted energy to the governor.  The
        bandit feedback consumes the attempt's pending routing decision
        (flushed strict=False, so a mismatch is skipped, never fatal)."""
        br = self.breakers.get(engine_name)
        if br is not None:
            br.record_failure(self._step_idx)
        if req.hedge_of is None:
            # a decode twin's display name maps to the primary's arm
            arm = engine_name.split("#", 1)[0]
            try:
                model_index = self.router.pool.index_of(arm)
            except KeyError:
                model_index = None
            if model_index is not None:
                self._fb_buffer.append(Feedback(
                    query_uid=req.uid, model_index=model_index,
                    accuracy=0.0, energy_wh=energy_wh, latency_ms=0.0,
                    input_tokens=len(req.prompt_tokens), output_tokens=0))
        if self.telemetry is not None:
            self.telemetry.on_attempt_failure(req.uid, engine_name, reason,
                                              energy_wh)

    def _reset_for_retry(self, req: Request) -> None:
        """Back to a clean QUEUED request (the same reset ``restart``
        applies): no slot, no generated tokens, no prompt cursor, no KV in
        transit.  ``submit_s`` is deliberately untouched — the deadline
        spans all attempts."""
        req.state = RequestState.QUEUED
        req.slot = -1
        req.generated = []
        req.n_prompt_fed = 0
        req.prefix_reused = 0
        req.first_token_s = 0.0
        req.start_s = 0.0
        req.kv_payload = None
        req.kv_migrated = 0
        req.prefill_wh = 0.0

    def _schedule_retry_or_fail(self, req: Request, engine_name: str,
                                reason: str, energy_wh: float = 0.0) -> None:
        """An attempt died: record the failure, then either park the
        request for an exponential-backoff retry (steps, virtual-clock
        aware) or — budget exhausted — declare it terminally FAILED."""
        self._attempt_failed(req, engine_name, reason, energy_wh)
        req.attempts += 1
        if req.attempts <= req.max_retries:
            self._reset_for_retry(req)
            due = self._step_idx + (self.retry_backoff_steps
                                    * (2 ** (req.attempts - 1)))
            self._retry_parked.append((due, req, engine_name))
            self._parked_uids.add(req.uid)
            self.inflight[req.uid] = req     # fail-over must still see it
            self.wait_steps[req.uid] = 0
            self.stats["retries"] += 1
        else:
            self._terminal_failure(req, RequestState.FAILED, reason)

    def _admit_retries(self) -> None:
        """Re-dispatch parked retries whose backoff elapsed: flush the
        buffered failure feedback first (the re-route must not overwrite
        a pending decision the flush consumes), then route the batch with
        each request's failed arm vetoed (``blocked``) and re-predict its
        in-flight charge — the governor *replaces* the prior charge for
        the uid, never stacks it."""
        if not self._retry_parked:
            return
        due = [e for e in self._retry_parked if e[0] <= self._step_idx]
        if not due:
            return
        self._retry_parked = [e for e in self._retry_parked
                              if e[0] > self._step_idx]
        for _, req, _ in due:
            self._parked_uids.discard(req.uid)
        self._flush_feedback()
        live = [(req, failed_arm) for _, req, failed_arm in due
                if not req.defunct and req.uid in self.inflight
                and req.uid not in self.responses]
        if not live:
            return
        names = self.router.pool.names
        blocked = np.zeros((len(live), len(names)), bool)
        for i, (req, failed_arm) in enumerate(live):
            arm = failed_arm.split("#", 1)[0]
            if arm in names:
                blocked[i, names.index(arm)] = True
        costs = occ = None
        if self.cost_model is not None:
            occ = {n: self._engine_occupancy(n) for n in names}
            costs = self.cost_model.predict_matrix(
                names, [len(req.prompt_tokens) for req, _ in live],
                [req.max_new_tokens for req, _ in live], occupancy=occ)
        decisions = self.router.route_batch(
            [req.query for req, _ in live], energy_costs_wh=costs,
            blocked=blocked)
        predicted = [] if costs is not None else None
        for i, ((req, failed_arm), decision) in enumerate(zip(live,
                                                              decisions)):
            self.wait_steps[req.uid] = 0
            if costs is not None:
                wh = float(costs[i, decision.model_index])
                req.predicted_wh = wh
                self.cost_model.note_admission(
                    req.uid, decision.model_name, wh,
                    n_prompt=len(req.prompt_tokens),
                    max_new_tokens=req.max_new_tokens,
                    occupancy=occ.get(decision.model_name, 0.0))
                predicted.append((req.uid, wh))
            self.engines[decision.model_name].submit(req)
            self.dispatch_counts[decision.model_name] = (
                self.dispatch_counts.get(decision.model_name, 0) + 1)
            if self.telemetry is not None:
                self.telemetry.on_retry(req.uid, req.attempts, failed_arm,
                                        decision.model_name)
        if self.telemetry is not None and predicted:
            # n=0: these uids were already counted at first admission —
            # this call only swaps their governor in-flight charges
            self.telemetry.on_admit(
                0, sum(e.pending for e in self.engines.values()),
                predicted=predicted)

    def _check_deadlines(self) -> None:
        """Expire requests whose end-to-end deadline passed: cancel any
        hedge, count the SLO violation, record the attempt failure on the
        arm that was holding it, and terminalize as TIMED_OUT.  Engines
        holding the request drop it on sight (``Request.defunct``)."""
        now = self.clock()
        for uid, req in list(self.inflight.items()):
            if req.deadline_s <= 0.0 or req.done:
                continue
            waited = now - req.submit_s
            if waited <= req.deadline_s:
                continue
            if uid in self._parked_uids:
                self._retry_parked = [e for e in self._retry_parked
                                      if e[1].uid != uid]
                self._parked_uids.discard(uid)
            hedge = self.hedges.get(uid)
            if hedge is not None:
                hedge.state = RequestState.CANCELLED
            if req.model_name:
                self._attempt_failed(req, req.model_name, "timeout", 0.0)
            self._terminal_failure(req, RequestState.TIMED_OUT, "timeout",
                                   waited_s=waited)

    def _terminal_failure(self, req: Request, state: RequestState,
                          reason: str, waited_s: float = 0.0) -> None:
        """The request is over without a Response: move it to the
        ``failed`` terminal registry, release its governor in-flight
        charge exactly once (``on_cancelled`` pops the uid; a second call
        is a no-op), and drop its cost-model pending prediction."""
        uid = req.uid
        req.state = state
        req.finish_s = self.clock()
        self.failed[uid] = req
        self.inflight.pop(uid, None)
        self.hedges.pop(uid, None)
        self.wait_steps.pop(uid, None)
        if state is RequestState.TIMED_OUT:
            self.stats["timeouts"] += 1
            self.stats["slo_violations"] += 1
            if self.telemetry is not None:
                self.telemetry.on_timeout(uid, waited_s)
        else:
            self.stats["failed"] += 1
            if self.telemetry is not None:
                self.telemetry.on_request_failed(uid, reason)
        if self.telemetry is not None:
            self.telemetry.on_cancelled(uid)
        if self.cost_model is not None:
            self.cost_model.forget_query(uid)

    def _handle_corrupt(self, resp: Response, req: Request,
                        engine_name: str) -> bool:
        """A completion came back marked ``corrupt`` (the NaN/inf-logits
        failure mode — energy burned, output garbage).  Returns True when
        the response was intercepted (retry scheduled / hedge dropped);
        False lets it complete as a zero-accuracy answer (the reliability-
        off baseline, and the retry-budget-exhausted fallthrough is
        handled inside ``_schedule_retry_or_fail``)."""
        if req.hedge_of is not None:
            # a corrupt hedge must never win the race: drop the duplicate,
            # the primary keeps running
            if self.hedges.get(req.hedge_of) is req:
                del self.hedges[req.hedge_of]
            req.state = RequestState.CANCELLED
            br = self.breakers.get(engine_name)
            if br is not None:
                br.record_failure(self._step_idx)
            if self.telemetry is not None:
                self.telemetry.on_attempt_failure(req.uid, engine_name,
                                                  "garbage", resp.energy_wh)
            return True
        if req.max_retries > 0:
            self._schedule_retry_or_fail(req, engine_name, "garbage",
                                         resp.energy_wh)
            return True
        br = self.breakers.get(engine_name)
        if br is not None:
            br.record_failure(self._step_idx)
        return False

    # -- completion -------------------------------------------------------------------

    def _complete(self, resp: Response, req: Request) -> None:
        primary_uid = req.hedge_of if req.hedge_of is not None else req.uid
        primary = self.inflight.get(primary_uid)
        if primary is None or primary_uid in self.responses:
            return                          # race already resolved
        br = self.breakers.get(resp.model_name)
        if br is not None:
            br.record_success(self._step_idx)
        if (primary.deadline_s > 0.0
                and self.clock() - primary.submit_s > primary.deadline_s):
            # answered, but late: served out of SLO (deadline enforcement
            # runs before engine steps, so a same-tick finish still wins)
            self.stats["slo_violations"] += 1
            if self.telemetry is not None:
                self.telemetry.on_slo_violation(primary_uid,
                                                resp.latency_ms)
        # cancel the loser of a hedged pair
        if req.hedge_of is not None:        # hedge won
            primary.state = RequestState.CANCELLED
        elif primary_uid in self.hedges:    # primary won
            self.hedges[primary_uid].state = RequestState.CANCELLED
        hedged_pair = (req.hedge_of is not None
                       or primary_uid in self.hedges)
        accuracy = getattr(resp, "accuracy", None)
        if accuracy is None:
            accuracy = (self.accuracy_fn(primary.query, resp)
                        if self.accuracy_fn else 0.0)
        # buffered: the router is updated once per step via feedback_batch
        # (a hedge that finished on a non-routed arm is skipped at flush; a
        # hedge that won on an engine outside the pool has no arm at all)
        try:
            model_index = self.router.pool.index_of(resp.model_name)
        except KeyError:
            model_index = None
        if model_index is not None:
            self._fb_buffer.append(Feedback(
                query_uid=primary_uid, model_index=model_index,
                accuracy=float(accuracy), energy_wh=resp.energy_wh,
                latency_ms=resp.latency_ms,
                input_tokens=resp.input_tokens,
                output_tokens=resp.output_tokens))
        self.responses[primary_uid] = resp
        self.inflight.pop(primary_uid, None)
        self.hedges.pop(primary_uid, None)
        self.wait_steps.pop(primary_uid, None)
        self.stats["completed"] += 1
        if self.cache is not None and self.cache.semantic_enabled:
            # the admission-time probe features (one embed per query);
            # fall back to a fresh probe only if they were never stashed
            # (e.g. the cache was attached mid-flight)
            task, cluster, emb = (primary.cache_features
                                  or self.cache.features(primary.query.text))
            self.cache.semantic.insert(emb, SemanticEntry(
                text=primary.query.text, task_label=task, cluster=cluster,
                model_name=resp.model_name, tokens=list(resp.tokens),
                text_out=resp.text, energy_wh=resp.energy_wh,
                accuracy=float(accuracy), input_tokens=resp.input_tokens,
                output_tokens=resp.output_tokens))
        predicted_wh = None
        if self.cost_model is not None:
            # reconcile the admission-time forecast against the metered
            # Wh and fold the completion into the residual calibration
            predicted_wh = self.cost_model.observe_response(
                resp, float(accuracy))
        if self.telemetry is not None:
            self.telemetry.on_completion(resp, float(accuracy),
                                         predicted_wh=predicted_wh)
            if hedged_pair:
                # the cancelled duplicate's work never completes; charge
                # the energy budget for it (winner's cost as proxy)
                self.telemetry.on_duplicate_work(resp.energy_wh)

    # -- main loop ---------------------------------------------------------------------

    def step(self) -> List[Response]:
        """One scheduler tick: health checks, hedging, arrival admission
        into free prefill slots, one ``step()`` per engine (each engine
        tick is one jitted chunk-prefill or decode call, prefill-side
        engines first so a phase boundary migrates the same tick it is
        reached), the migration pump, one batched feedback flush, one
        telemetry/governor step.  Returns the responses completed this
        tick."""
        done: List[Response] = []
        self._step_idx += 1
        self._check_engines()
        self._check_deadlines()
        self._admit_retries()
        self._maybe_hedge()
        self._admit_arrivals()
        for name, eng in self.engines.items():
            try:
                for resp in eng.step():
                    req = self._find_request(resp.uid, name)
                    if req is None:
                        continue
                    if (getattr(resp, "corrupt", False)
                            and self._handle_corrupt(resp, req, name)):
                        continue
                    self._complete(resp, req)
                    done.append(resp)
            except EngineFailure:
                self._restart_engine(name)
        for name, twin in self.decode_engines.items():
            try:
                for resp in twin.step():
                    req = self._find_request(resp.uid, name)
                    if req is None:
                        continue
                    if (getattr(resp, "corrupt", False)
                            and self._handle_corrupt(resp, req,
                                                     f"{name}#decode")):
                        continue
                    self._complete(resp, req)
                    done.append(resp)
            except EngineFailure:
                self._restart_engine(name, decode=True)
        self._pump_migrations()
        self._flush_feedback()
        for uid, req in self.inflight.items():
            if req.state == RequestState.QUEUED:
                self.wait_steps[uid] = self.wait_steps.get(uid, 0) + 1
        # telemetry last: power samples see the step's energy, and the
        # governor's λ adjustment lands after this step's feedback flush
        if self.telemetry is not None:
            self.telemetry.on_step(self._all_engines())
        return done

    def _all_engines(self) -> Dict[str, BaseEngine]:
        """Telemetry view of the pool: primaries under their model name,
        decode twins under ``<name>#decode``."""
        if not self.decode_engines:
            return self.engines
        view = dict(self.engines)
        for name, twin in self.decode_engines.items():
            view[f"{name}#decode"] = twin
        return view

    def _pump_migrations(self) -> None:
        """Move phase-boundary requests from each prefill-role engine's
        outbox into its decode twin's queue.  Runs after engine stepping,
        so a prefill that completes at tick t starts decoding at t+1 —
        the one-tick handoff is the (honest) migration latency.  If the
        twin vanished mid-flight the request re-prefills on the primary
        (payload dropped); nothing is ever lost."""
        for name, eng in self.engines.items():
            if eng.role != "prefill":
                continue
            twin = self.decode_engines.get(name)
            for req in eng.drain_migrations():
                if req.defunct:
                    continue
                if twin is None:
                    req.kv_payload = None
                    req.kv_migrated = 0
                    req.prefill_wh = 0.0
                    req.state = RequestState.QUEUED
                    req.generated = []
                    req.n_prompt_fed = 0
                    req.prefix_reused = 0
                    eng.submit(req)
                    continue
                twin.submit_migrated(req)
                self.stats["migrations"] += 1
                if self.telemetry is not None:
                    self.telemetry.on_migration(name, req.kv_migrated)

    def _find_request(self, uid: int, engine_name: str) -> Optional[Request]:
        req = self.inflight.get(uid)
        if req is not None and req.model_name == engine_name:
            return req
        for primary_uid, hedge in self.hedges.items():
            if hedge.uid == uid and hedge.model_name == engine_name:
                return hedge
        return req

    def drain_snapshot(self) -> str:
        """Multi-line diagnostic of everything that could hold a drain
        open: arrivals, retry parking, per-engine occupancy/health, and
        the in-flight uids with their states.  Embedded in LivelockError
        so a stuck drain is diagnosable from the exception alone."""
        lines = [f"arrivals queued: {len(self.arrivals)}; "
                 f"retry-parked: {len(self._retry_parked)} "
                 f"(next due step {min((e[0] for e in self._retry_parked), default='-')}; "
                 f"now step {self._step_idx})"]
        for name, eng in self._all_engines().items():
            br = self.breakers.get(name)
            lines.append(
                f"  engine {name}: pending={eng.pending} "
                f"free={eng.free_capacity} "
                f"role={getattr(eng, 'role', 'unified')} "
                f"failed={bool(getattr(eng, '_failed', False))}"
                + (f" breaker={br.state}" if br is not None else ""))
        if self.inflight:
            shown = list(self.inflight.items())[:16]
            more = len(self.inflight) - len(shown)
            lines.append("  inflight: " + ", ".join(
                f"{uid}:{req.state.value}@{req.model_name or '?'}"
                for uid, req in shown) + (f" …+{more} more" if more else ""))
        return "\n".join(lines)

    def run_until_drained(self, max_steps: int = 100_000) -> None:
        """Step until nothing is in flight *and* no arrival is parked.
        Raises ``LivelockError`` (a ``TimeoutError``) if the step budget
        runs out with live work — a silent return here would mask a
        scheduler livelock, which the continuous loop must never hide.
        The error message carries a full ``drain_snapshot`` (queue depth,
        per-engine occupancy/state, in-flight uids)."""
        for _ in range(max_steps):
            if not self.inflight and not self.arrivals:
                return
            self.step()
        if not self.inflight and not self.arrivals:
            return      # the budget's last step drained the pool
        raise LivelockError(
            f"{len(self.inflight)} request(s) still in flight and "
            f"{len(self.arrivals)} arrival(s) still parked after "
            f"{max_steps} steps\n" + self.drain_snapshot())
