"""GreenServ pool server: router → per-model engines → feedback loop.

Implements the paper's online deployment (§4.4) with the production
concerns of DESIGN §5:

  * routing: every query goes through GreenServRouter (context → feasible →
    LinUCB), execution through the selected model's engine, and the
    measured (accuracy, energy, latency) closes the bandit loop;
  * continuous operation: engines are stepped round-robin, admitting new
    work between decode steps;
  * straggler mitigation: a request stuck behind a deep queue past its
    hedge deadline is duplicated onto the fastest feasible engine; the
    first completion wins, the loser is cancelled (hedged requests);
  * fault tolerance: engines carry heartbeats; a stalled or failed engine
    is restarted and its in-flight requests re-queued (after router
    re-routing, since the failed arm may be deprioritized);
  * model addition (§6.3.4): ``add_engine`` registers a new pool member at
    runtime — the router grows a fresh arm, zero offline calibration.
"""
from __future__ import annotations

import time
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Sequence

if TYPE_CHECKING:  # pragma: no cover - annotation only
    from repro.telemetry.hub import Telemetry

import numpy as np

from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import Feedback, ModelProfile, Query, RouterConfig
from repro.serving.engine import BaseEngine, EngineFailure
from repro.serving.request import Request, RequestState, Response


class PoolServer:
    """The GreenServ scheduler: routes queries, steps engines, closes the
    bandit loop.  ``hedge_after_steps`` is measured in scheduler steps
    spent QUEUED; ``heartbeat_timeout_s`` in wall-clock seconds;
    ``prefill_chunk`` (prompt tokens per engine prefill tick) is pushed
    into every engine at construction and again on ``add_engine``, so a
    server-level setting governs the whole pool."""

    def __init__(self, router: GreenServRouter,
                 engines: Dict[str, BaseEngine],
                 tokenizer: Optional[Callable[[str], List[int]]] = None,
                 hedge_after_steps: Optional[int] = None,
                 heartbeat_timeout_s: float = 30.0,
                 accuracy_fn: Optional[Callable] = None,
                 telemetry: Optional["Telemetry"] = None,
                 prefill_chunk: Optional[int] = None):
        names = router.pool.names
        missing = [n for n in names if n not in engines]
        if missing:
            raise ValueError(f"engines missing for pool members: {missing}")
        self.router = router
        self.engines = engines
        self.tokenizer = tokenizer or (lambda text: [1 + (ord(c) % 250)
                                                     for c in text[:32]])
        self.hedge_after_steps = hedge_after_steps
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.accuracy_fn = accuracy_fn
        self.telemetry = telemetry
        self.prefill_chunk = prefill_chunk
        if prefill_chunk is not None:
            for eng in engines.values():
                eng.set_prefill_chunk(prefill_chunk)
        if telemetry is not None and telemetry.governor is not None:
            telemetry.governor.attach(router)
        self.inflight: Dict[int, Request] = {}
        self.hedges: Dict[int, Request] = {}
        self.responses: Dict[int, Response] = {}
        self.wait_steps: Dict[int, int] = {}
        self.stats = {"hedges": 0, "restarts": 0, "completed": 0}
        # feedback for completions collected during the current step(); the
        # router is updated once per step via feedback_batch
        self._fb_buffer: List[Feedback] = []

    # -- pool growth (paper §6.3.4) ---------------------------------------------

    def add_engine(self, profile: ModelProfile, engine: BaseEngine) -> None:
        """Zero-calibration model addition: new engine + fresh bandit arm.
        The server's ``prefill_chunk`` setting applies to late joiners too."""
        if self.prefill_chunk is not None:
            engine.set_prefill_chunk(self.prefill_chunk)
        self.engines[profile.name] = engine
        self.router.pool.add(profile)   # fires the router's add-arm hook

    # -- submission ---------------------------------------------------------------

    def submit(self, query: Query) -> Request:
        """Route and enqueue one query (a batch of one; tools/demos)."""
        return self.submit_batch([query])[0]

    def submit_batch(self, queries: Sequence[Query]) -> List[Request]:
        """Admit a batch: one ``route_batch`` call routes every query, then
        each engine receives its slice in arrival order.  This is the
        serving hot path — featurization and LinUCB scoring amortize over
        the batch instead of paying per-query dispatch."""
        # routed models always come from the pool, so checking the
        # pool/engine invariant up front fails before ANY bookkeeping
        # (router pending entries included) — a half-registered batch
        # would sit in inflight forever with nothing dispatched
        missing = [n for n in self.router.pool.names
                   if n not in self.engines]
        if missing:
            raise KeyError(f"no engine for pool member(s): {missing}")
        decisions = self.router.route_batch(queries)
        reqs: List[Request] = []
        per_engine: Dict[str, List[Request]] = {}
        for query, decision in zip(queries, decisions):
            req = Request(query=query,
                          prompt_tokens=self.tokenizer(query.text),
                          max_new_tokens=query.max_new_tokens)
            per_engine.setdefault(decision.model_name, []).append(req)
            self.inflight[query.uid] = req
            self.wait_steps[query.uid] = 0
            reqs.append(req)
        for name, batch in per_engine.items():
            self.engines[name].submit_many(batch)
        if self.telemetry is not None:
            self.telemetry.on_admit(
                len(reqs), sum(e.pending for e in self.engines.values()))
        return reqs

    # -- hedged (straggler-mitigating) dispatch ------------------------------------

    def _maybe_hedge(self) -> None:
        if self.hedge_after_steps is None:
            return
        for uid, req in list(self.inflight.items()):
            if req.done or uid in self.hedges or req.hedge_of is not None:
                continue
            if (req.state == RequestState.QUEUED
                    and self.wait_steps[uid] >= self.hedge_after_steps):
                # pick the least-loaded other engine as the hedge target
                others = [(e.pending, n) for n, e in self.engines.items()
                          if n != req.model_name]
                if not others:
                    continue
                _, target = min(others)
                hedge = Request(query=req.query,
                                prompt_tokens=list(req.prompt_tokens),
                                max_new_tokens=req.max_new_tokens,
                                hedged=True, hedge_of=uid)
                self.engines[target].submit(hedge)
                self.hedges[uid] = hedge
                self.stats["hedges"] += 1
                if self.telemetry is not None:
                    self.telemetry.on_hedge(uid, target)

    # -- fault tolerance -------------------------------------------------------------

    def _check_engines(self) -> None:
        now = time.monotonic()
        for name, eng in self.engines.items():
            stalled = now - eng.heartbeat() > self.heartbeat_timeout_s
            if stalled or getattr(eng, "_failed", False):
                self._restart_engine(name)

    def _restart_engine(self, name: str) -> None:
        eng = self.engines[name]
        inflight = eng.restart()
        self.stats["restarts"] += 1
        if self.telemetry is not None:
            self.telemetry.on_restart(name, len(inflight))
        # flush buffered feedback first so re-routing sees the updated
        # bandit, and so no pending decision consumed by the flush is
        # overwritten by the re-route below
        self._flush_feedback()
        # displaced hedges are dropped, not resubmitted — clear their
        # bookkeeping so _maybe_hedge can protect the primary again
        for req in inflight:
            if (req.hedge_of is not None
                    and self.hedges.get(req.hedge_of) is req):
                req.state = RequestState.CANCELLED
                del self.hedges[req.hedge_of]
        # re-route the displaced batch in one shot: the bandit may now
        # prefer a different (healthy) arm.  restart() resets every held
        # request to QUEUED — including a hedge loser whose query was
        # already answered; resurrecting it would re-insert a finished uid
        # into inflight (never drains) and duplicate the work.
        primaries = [req for req in inflight
                     if req.hedge_of is None
                     and req.uid not in self.responses]
        if not primaries:
            return
        decisions = self.router.route_batch([req.query for req in primaries])
        for req, decision in zip(primaries, decisions):
            self.inflight[req.uid] = req
            self.engines[decision.model_name].submit(req)

    def _flush_feedback(self) -> None:
        if self._fb_buffer:
            fbs, self._fb_buffer = self._fb_buffer, []
            self.router.feedback_batch(fbs, strict=False)

    # -- completion -------------------------------------------------------------------

    def _complete(self, resp: Response, req: Request) -> None:
        primary_uid = req.hedge_of if req.hedge_of is not None else req.uid
        primary = self.inflight.get(primary_uid)
        if primary is None or primary_uid in self.responses:
            return                          # race already resolved
        # cancel the loser of a hedged pair
        if req.hedge_of is not None:        # hedge won
            primary.state = RequestState.CANCELLED
        elif primary_uid in self.hedges:    # primary won
            self.hedges[primary_uid].state = RequestState.CANCELLED
        hedged_pair = (req.hedge_of is not None
                       or primary_uid in self.hedges)
        accuracy = getattr(resp, "accuracy", None)
        if accuracy is None:
            accuracy = (self.accuracy_fn(primary.query, resp)
                        if self.accuracy_fn else 0.0)
        # buffered: the router is updated once per step via feedback_batch
        # (a hedge that finished on a non-routed arm is skipped at flush; a
        # hedge that won on an engine outside the pool has no arm at all)
        try:
            model_index = self.router.pool.index_of(resp.model_name)
        except KeyError:
            model_index = None
        if model_index is not None:
            self._fb_buffer.append(Feedback(
                query_uid=primary_uid, model_index=model_index,
                accuracy=float(accuracy), energy_wh=resp.energy_wh,
                latency_ms=resp.latency_ms,
                input_tokens=resp.input_tokens,
                output_tokens=resp.output_tokens))
        self.responses[primary_uid] = resp
        self.inflight.pop(primary_uid, None)
        self.hedges.pop(primary_uid, None)
        self.wait_steps.pop(primary_uid, None)
        self.stats["completed"] += 1
        if self.telemetry is not None:
            self.telemetry.on_completion(resp, float(accuracy))
            if hedged_pair:
                # the cancelled duplicate's work never completes; charge
                # the energy budget for it (winner's cost as proxy)
                self.telemetry.on_duplicate_work(resp.energy_wh)

    # -- main loop ---------------------------------------------------------------------

    def step(self) -> List[Response]:
        """One scheduler tick: health checks, hedging, one ``step()`` per
        engine (each engine tick is one jitted chunk-prefill or decode
        call), one batched feedback flush, one telemetry/governor step.
        Returns the responses completed this tick."""
        done: List[Response] = []
        self._check_engines()
        self._maybe_hedge()
        for name, eng in self.engines.items():
            try:
                for resp in eng.step():
                    req = self._find_request(resp.uid, name)
                    if req is not None:
                        self._complete(resp, req)
                        done.append(resp)
            except EngineFailure:
                self._restart_engine(name)
        self._flush_feedback()
        for uid, req in self.inflight.items():
            if req.state == RequestState.QUEUED:
                self.wait_steps[uid] = self.wait_steps.get(uid, 0) + 1
        # telemetry last: power samples see the step's energy, and the
        # governor's λ adjustment lands after this step's feedback flush
        if self.telemetry is not None:
            self.telemetry.on_step(self.engines)
        return done

    def _find_request(self, uid: int, engine_name: str) -> Optional[Request]:
        req = self.inflight.get(uid)
        if req is not None and req.model_name == engine_name:
            return req
        for primary_uid, hedge in self.hedges.items():
            if hedge.uid == uid and hedge.model_name == engine_name:
                return hedge
        return req

    def run_until_drained(self, max_steps: int = 100_000) -> None:
        """Step until no request is in flight (or raise after max_steps)."""
        for _ in range(max_steps):
            if not self.inflight:
                return
            self.step()
        raise TimeoutError(f"{len(self.inflight)} requests still in flight")
