"""Elastic scaling: re-mesh on node loss and reshard from checkpoint.

When chips are lost, training resumes on the largest viable mesh: the TP
("model") extent is preserved — weight shards assume it — and data
parallelism shrinks to whatever still fits.  The global batch either shrinks
with it or is held constant by raising the microbatch count; both policies
are supported and the choice is recorded in the run log.

The recovery path is: detect loss → ``plan_remesh`` → rebuild step bundle on
the degraded mesh → ``checkpoint.restore(..., shardings=new)`` → continue.
``tests/test_distributed.py`` exercises it end-to-end on fake devices.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.launch.mesh import make_mesh


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    lost_chips: int
    batch_policy: str            # "shrink" | "hold" (raise n_micro)
    n_micro_multiplier: int

    @property
    def new_data_parallel(self) -> int:
        return self.new_shape[-2]


def plan_remesh(mesh: Mesh, lost_chips: int,
                batch_policy: str = "hold") -> RemeshPlan:
    """Largest (pods × data' × model) mesh on the surviving chips."""
    axes = tuple(mesh.axis_names)
    shape = tuple(mesh.devices.shape)
    model = shape[-1]
    total = mesh.devices.size - lost_chips
    # keep the pod axis only if a full pod-multiple survives
    if "pod" in axes:
        per_pod = shape[-2] * model
        pods = max(total // per_pod, 1)
        data = (total - (pods - 1) * per_pod) // model if pods == 1 else shape[-2]
        data = min(data, shape[-2])
        new_shape = (pods, data, model)
    else:
        data = total // model
        if data < 1:
            raise ValueError(
                f"{total} surviving chips cannot host model axis {model}")
        new_shape = (data, model)
    old_dp = shape[0] * shape[-2] if "pod" in axes else shape[0]
    new_dp = (new_shape[0] * new_shape[1] if "pod" in axes
              else new_shape[0])
    mult = max(1, -(-old_dp // new_dp)) if batch_policy == "hold" else 1
    return RemeshPlan(old_shape=shape, new_shape=new_shape, axes=axes,
                      lost_chips=lost_chips, batch_policy=batch_policy,
                      n_micro_multiplier=mult)


def build_mesh(plan: RemeshPlan) -> Mesh:
    return make_mesh(plan.new_shape, plan.axes)
