"""Distributed runtime: checkpointing, elastic re-meshing, fault detection."""
from repro.distributed import checkpoint, elastic, fault
from repro.distributed.checkpoint import latest_step, prune, restore, save
from repro.distributed.elastic import RemeshPlan, build_mesh, plan_remesh
from repro.distributed.fault import (HeartbeatMonitor, StragglerDetector,
                                     TrainWatchdog)

__all__ = ["checkpoint", "elastic", "fault", "latest_step", "prune",
           "restore", "save", "RemeshPlan", "build_mesh", "plan_remesh",
           "HeartbeatMonitor", "StragglerDetector", "TrainWatchdog"]
