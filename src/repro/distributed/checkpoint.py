"""Fault-tolerant checkpointing: sharded arrays, atomic manifest, restore.

Layout on disk:

    <dir>/step_000123/
        manifest.json        tree structure + per-leaf shape/dtype/spec
        leaf_00000.npy ...   one file per pytree leaf (host-gathered)
    <dir>/LATEST             atomic pointer (rename) to the newest step

Writes go to ``step_X.tmp/`` and are renamed into place only after the
manifest lands, so a crash mid-write can never corrupt the restore path —
the previous checkpoint stays LATEST.  Router/bandit state (plain dict of
numpy arrays) rides the same machinery as model/optimizer pytrees.

On a real multi-host pod each host writes its local shards and the manifest
carries the PartitionSpecs for resharded restore; on this single-host
container arrays are host-gathered (they are either small or test-sized).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import compat

MANIFEST = "manifest.json"
LATEST = "LATEST"


def _flatten(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = compat.tree_flatten_with_path(tree)
    return [(compat.path_str(path), leaf) for path, leaf in leaves], treedef


def save(directory: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> pathlib.Path:
    """Atomically write ``tree`` as checkpoint ``step``; returns final path."""
    root = pathlib.Path(directory)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:09d}"
    tmp = root / f"step_{step:09d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    named, _ = _flatten(tree)
    entries = []
    for i, (name, leaf) in enumerate(named):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        dtype = str(arr.dtype)
        if arr.dtype.kind not in "biufc":     # bf16/fp8: store as raw bits
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2
                           else np.uint8)
        np.save(tmp / fname, arr)
        entries.append({"name": name, "file": fname,
                        "shape": list(arr.shape), "dtype": dtype})
    manifest = {"step": step, "leaves": entries, "extra": extra or {}}
    # manifest written last inside tmp, then a single atomic rename
    (tmp / MANIFEST).write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)
    _point_latest(root, final.name)
    return final


def _point_latest(root: pathlib.Path, name: str) -> None:
    fd, tmppath = tempfile.mkstemp(dir=root)
    with os.fdopen(fd, "w") as f:
        f.write(name)
    os.replace(tmppath, root / LATEST)


def latest_step(directory: str) -> Optional[int]:
    root = pathlib.Path(directory)
    ptr = root / LATEST
    if not ptr.exists():
        return None
    name = ptr.read_text().strip()
    if not (root / name / MANIFEST).exists():
        return None
    return int(name.split("_")[1])


def restore(directory: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like``; reshard if shardings given.

    Returns (tree, extra).  Missing leaves raise — a checkpoint must match
    the model it restores (elastic re-meshing changes shardings, never the
    tree structure).
    """
    root = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = root / f"step_{step:09d}"
    manifest = json.loads((path / MANIFEST).read_text())
    by_name = {e["name"]: e for e in manifest["leaves"]}

    named, treedef = _flatten(like)
    flat_shardings = None
    if shardings is not None:
        flat_shardings = [s for _, s in _flatten(shardings)[0]]
    out = []
    for i, (name, leaf) in enumerate(named):
        e = by_name.get(name)
        if e is None:
            raise KeyError(f"checkpoint {path} missing leaf {name!r}")
        arr = np.load(path / e["file"])
        if str(arr.dtype) != e["dtype"]:      # raw-bits storage (bf16/fp8)
            import ml_dtypes
            arr = arr.view(getattr(ml_dtypes, e["dtype"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"leaf {name!r}: checkpoint shape {arr.shape} "
                             f"!= model shape {tuple(leaf.shape)}")
        if flat_shardings is not None:
            out.append(jax.device_put(arr, flat_shardings[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
    return tree, manifest.get("extra", {})


def prune(directory: str, keep: int = 3) -> None:
    """Retain the newest ``keep`` checkpoints (never the LATEST target)."""
    root = pathlib.Path(directory)
    steps = sorted(p for p in root.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep]:
        shutil.rmtree(p)
