"""Failure detection: heartbeats and straggler statistics.

Generic primitives used by both the serving scheduler (engine heartbeats,
hedged dispatch) and the training launcher (step-time watchdog that
triggers checkpoint-restore / elastic re-mesh).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Heartbeat:
    """One liveness track.  ``clock`` is injectable (default wall-clock
    ``time.monotonic``) so virtual-clock harnesses — the scenario lab,
    the fleet controller's shard liveness — can drive staleness from
    modeled time instead of sleeping through real timeouts."""

    name: str
    clock: Callable[[], float] = time.monotonic
    last_beat: Optional[float] = None

    def __post_init__(self) -> None:
        if self.last_beat is None:
            self.last_beat = self.clock()

    def beat(self) -> None:
        self.last_beat = self.clock()

    def stale(self, timeout_s: float) -> bool:
        return (self.clock() - self.last_beat) > timeout_s


class HeartbeatMonitor:
    """Tracks many heartbeats; reports the stale set.  All registered
    beats share the monitor's (injectable) clock."""

    def __init__(self, timeout_s: float,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self._beats: Dict[str, Heartbeat] = {}

    def register(self, name: str) -> Heartbeat:
        hb = Heartbeat(name, clock=self.clock)
        self._beats[name] = hb
        return hb

    def deregister(self, name: str) -> None:
        """Stop tracking ``name`` (e.g. a shard already failed over) —
        a dead entry would otherwise report stale forever."""
        self._beats.pop(name, None)

    def beat(self, name: str) -> None:
        self._beats[name].beat()

    def stale(self) -> List[str]:
        return [n for n, hb in self._beats.items()
                if hb.stale(self.timeout_s)]


class StragglerDetector:
    """Flags workers whose step times exceed a robust threshold.

    Threshold = median + k·IQR over a sliding window — the standard
    straggler test that tolerates global slowdowns (everyone slow ⇒ nobody
    flagged) while catching a single failing host.
    """

    def __init__(self, window: int = 50, k: float = 3.0):
        self.window = window
        self.k = k
        self._times: Dict[str, List[float]] = {}

    def record(self, worker: str, step_time_s: float) -> None:
        buf = self._times.setdefault(worker, [])
        buf.append(step_time_s)
        if len(buf) > self.window:
            buf.pop(0)

    def stragglers(self) -> List[str]:
        latest = {w: buf[-1] for w, buf in self._times.items() if buf}
        if len(latest) < 3:
            return []
        vals = np.array(list(latest.values()))
        med = np.median(vals)
        iqr = np.subtract(*np.percentile(vals, [75, 25])) or med * 0.05
        thresh = med + self.k * iqr
        return [w for w, t in latest.items() if t > thresh]


@dataclasses.dataclass
class TrainWatchdog:
    """Training-loop recovery policy: restore from the newest checkpoint,
    optionally on a degraded mesh (elastic)."""

    checkpoint_dir: str
    max_restarts: int = 5
    restarts: int = 0

    def should_restart(self) -> bool:
        return self.restarts < self.max_restarts

    def on_failure(self) -> int:
        self.restarts += 1
        from repro.distributed import checkpoint as ckpt
        step = ckpt.latest_step(self.checkpoint_dir)
        if step is None:
            raise RuntimeError("failure before first checkpoint — "
                               "cannot recover")
        return step
