"""Collective-traffic accounting from compiled HLO text (§Roofline).

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but not collective
traffic, so we parse the optimized (SPMD-partitioned) HLO: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction is charged per-chip ICI wire bytes under the standard ring/torus
cost model:

    all-reduce       2·(k-1)/k · operand_bytes
    all-gather         (k-1)/k · result_bytes
    reduce-scatter     (k-1)/k · operand_bytes
    all-to-all         (k-1)/k · operand_bytes
    collective-permute           result_bytes

where k is the replica-group size.  Operand shapes are resolved through a
name → shape table built from the instruction definitions (optimized HLO
prints operands by name only).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

# %name = dtype[d0,d1]{layout} opcode(...)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*\)|[\w\d]+\[[^\]]*\][^\s]*)\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(shape_text: str) -> int:
    """Total bytes of a shape string, incl. tuple shapes."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)           # iota form: [groups,size]<=[n]
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)      # explicit {{0,1},{2,3}} form
    if m:
        first = m.group(1).split("}")[0].strip("{ ")
        if first:
            return len(first.split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    """Per-chip ICI wire-byte totals by collective kind."""

    by_kind: Dict[str, float]
    n_ops: int
    ops: List[Tuple[str, float, int]]   # (kind, bytes, group_size)

    @property
    def total_bytes(self) -> float:
        return float(sum(self.by_kind.values()))

    def summary(self) -> str:
        parts = [f"{k}={v/1e6:.1f}MB" for k, v in sorted(self.by_kind.items())]
        return f"{self.n_ops} ops, {self.total_bytes/1e6:.1f}MB/chip: " + \
            ", ".join(parts)


def collective_stats(hlo_text: str, n_devices: int) -> CollectiveStats:
    shapes: Dict[str, str] = {}
    by_kind: Dict[str, float] = {}
    ops: List[Tuple[str, float, int]] = []
    n_ops = 0
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, shape_text, opcode = m.group(1), m.group(2), m.group(3)
        shapes[name] = shape_text
        kind = next((c for c in COLLECTIVES if opcode.startswith(c)), None)
        if kind is None or opcode.endswith("-start") and False:
            continue
        if opcode.endswith("-done"):
            continue  # async pair: charge the -start only
        k = _group_size(line, n_devices)
        result_bytes = _shape_bytes(shape_text)
        # operand bytes: resolve names inside the parens against the table
        paren = line[line.index("(") + 1:]
        operand_bytes = 0
        for om in _OPERAND_RE.finditer(paren.split(")")[0]):
            operand_bytes += _shape_bytes(shapes.get(om.group(1), ""))
        if operand_bytes == 0:
            operand_bytes = result_bytes

        if kind == "all-reduce":
            wire = 2.0 * (k - 1) / max(k, 1) * operand_bytes
        elif kind == "all-gather":
            wire = (k - 1) / max(k, 1) * result_bytes
        elif kind in ("reduce-scatter", "all-to-all"):
            wire = (k - 1) / max(k, 1) * operand_bytes
        else:  # collective-permute
            wire = float(result_bytes)
        by_kind[kind] = by_kind.get(kind, 0.0) + wire
        ops.append((kind, wire, k))
        n_ops += 1
    return CollectiveStats(by_kind=by_kind, n_ops=n_ops, ops=ops)


def count_op(hlo_text: str, opcode: str) -> int:
    return len(re.findall(rf"=\s*(?:\([^=]*\)|\S+)\s+{re.escape(opcode)}\(",
                          hlo_text))
