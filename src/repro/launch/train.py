"""Training launcher: data → sharded train loop → checkpoints → recovery.

Runs end-to-end on this host with a reduced config (examples/train_100m.py)
and lowers unchanged on the production mesh (launch/dryrun.py exercises the
identical step bundle at 512 chips).  Fault tolerance: periodic atomic
checkpoints, a step-time watchdog, restart-from-LATEST (optionally on an
elastically degraded mesh via --lost-chips).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs import ARCH_IDS, get_config
from repro.distributed import checkpoint as ckpt
from repro.distributed.elastic import build_mesh, plan_remesh
from repro.distributed.fault import StragglerDetector, TrainWatchdog
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import api
from repro.train import OptConfig, make_opt_state, make_train_step


def synthetic_batches(cfg, batch: int, seq: int, seed: int = 0
                      ) -> Iterator[dict]:
    """Deterministic LM data: next-token prediction over structured noise."""
    rng = np.random.default_rng(seed)
    while True:
        # Markov-ish stream so there is real signal to learn
        start = rng.integers(0, cfg.vocab_size - 1, size=(batch, 1))
        steps = rng.integers(1, 7, size=(batch, seq))
        toks = (start + np.cumsum(steps, axis=1)) % cfg.vocab_size
        b = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
             "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        if cfg.layout == "encdec" or cfg.frontend == "audio":
            b["frames"] = jnp.asarray(
                rng.standard_normal((batch, cfg.n_frontend_tokens,
                                     cfg.d_model)), cfg.dtype)
        elif cfg.frontend == "vision":
            b["frontend_embeddings"] = jnp.asarray(
                rng.standard_normal((batch, cfg.n_frontend_tokens,
                                     cfg.d_model)), cfg.dtype)
        yield b


def train(arch: str, smoke: bool, steps: int, batch: int, seq: int,
          ckpt_dir: Optional[str], ckpt_every: int = 20,
          n_micro: int = 1, use_ef_compress: bool = False,
          production_mesh: bool = False, lost_chips: int = 0,
          fail_at_step: Optional[int] = None, lr: float = 3e-4,
          log_every: int = 10) -> dict:
    cfg = get_config(arch, smoke=smoke)
    mesh = (make_production_mesh() if production_mesh else make_host_mesh())
    if lost_chips:
        mesh = build_mesh(plan_remesh(mesh, lost_chips))
    opt_cfg = OptConfig(lr=lr, total_steps=max(steps, 2), warmup_steps=min(
        20, steps // 5 + 1), moment_dtype=cfg.moment_dtype)

    data = synthetic_batches(cfg, batch, seq + 1)
    example = next(data)
    bundle = make_train_step(cfg, mesh, example, opt_cfg, n_micro=n_micro,
                             use_ef_compress=use_ef_compress,
                             loss_chunk=min(512, seq))
    step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                      out_shardings=bundle.out_shardings,
                      donate_argnums=(0, 1))

    params = api.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_opt_state(cfg, params, opt_cfg, use_ef_compress)
    start_step = 0
    if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        (params, opt), extra = ckpt.restore(
            ckpt_dir, (params, opt))
        start_step = int(extra.get("step", 0))
        print(f"[train] restored step {start_step} from {ckpt_dir}")

    watchdog = TrainWatchdog(ckpt_dir or "/tmp/ckpt")
    straggle = StragglerDetector()
    losses = []
    step = start_step
    with compat.set_mesh(mesh):
        while step < steps:
            t0 = time.monotonic()
            batch_data = next(data)
            try:
                if fail_at_step is not None and step == fail_at_step:
                    fail_at_step = None
                    raise RuntimeError("injected failure")
                params, opt, metrics = step_fn(params, opt, batch_data)
            except RuntimeError as e:
                if not ckpt_dir or not watchdog.should_restart():
                    raise
                print(f"[train] step {step} failed ({e}); restoring")
                restore_step = watchdog.on_failure()
                params = api.init_params(cfg, jax.random.PRNGKey(0))
                opt = make_opt_state(cfg, params, opt_cfg, use_ef_compress)
                (params, opt), extra = ckpt.restore(ckpt_dir, (params, opt))
                step = int(extra.get("step", restore_step))
                continue
            straggle.record("host0", time.monotonic() - t0)
            loss = float(metrics["loss"])
            losses.append(loss)
            step += 1
            if step % log_every == 0 or step == steps:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f}")
            if ckpt_dir and step % ckpt_every == 0:
                ckpt.save(ckpt_dir, step, (params, opt),
                          extra={"step": step, "arch": arch})
                ckpt.prune(ckpt_dir, keep=3)
    if ckpt_dir:
        ckpt.save(ckpt_dir, step, (params, opt),
                  extra={"step": step, "arch": arch})
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "steps": step, "losses": losses}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="rwkv6-1.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ef-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a failure (tests recovery)")
    args = ap.parse_args()
    out = train(args.arch, args.smoke, args.steps, args.batch, args.seq,
                args.ckpt_dir, args.ckpt_every, args.n_micro,
                args.ef_compress, fail_at_step=args.fail_at_step, lr=args.lr)
    print(f"[train] done: loss {out['first_loss']:.3f} -> "
          f"{out['final_loss']:.3f} over {out['steps']} steps")


if __name__ == "__main__":
    main()
