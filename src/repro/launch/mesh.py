"""Production mesh construction (DESIGN §5).

Defined as FUNCTIONS so importing this module never touches jax device
state — smoke tests and benches must keep seeing 1 CPU device; only
``dryrun.py`` (which sets XLA_FLAGS before any jax import) sees 512.

All meshes are built through ``repro.compat.make_mesh``, which requests
Auto axis types on JAX versions that have the AxisType enum and omits the
argument on 0.4.x (where auto is the only behaviour).
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256 chips/pod; the multi-pod mesh adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh with Auto axis types (tests, degraded/elastic meshes)."""
    return compat.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """The 1-device mesh every smoke test / bench runs under."""
    n = len(jax.devices())
    return compat.make_mesh((n, 1), ("data", "model"))


def degraded_mesh(lost_chips: int, *, multi_pod: bool = False) -> Mesh:
    """Elastic-scaling helper: the largest (data', model) mesh that fits the
    surviving device count — the 'model' extent is preserved (TP degree is
    fixed by weight shardings), data parallelism shrinks."""
    base = make_production_mesh(multi_pod=multi_pod)
    total = base.devices.size - lost_chips
    model = base.shape["model"]
    data = total // model
    if data < 1:
        raise ValueError(f"cannot remesh: {total} chips < model axis {model}")
    if multi_pod:
        return compat.make_mesh((1, data, model), ("pod", "data", "model"))
    return compat.make_mesh((data, model), ("data", "model"))
