"""Serving launcher: GreenServ pool server over real reduced-config models.

Builds a heterogeneous pool of small-but-real JAX models (one per requested
arch family), the GreenServ router with all three context features, the
GreenCache reuse layer (``--cache-mode``, default prefix-KV reuse), and the
continuous-batching scheduler; then drives a synthetic query stream through
it with hedging and fault injection available as flags.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --queries 60 \
        --pool granite-3-8b rwkv6-1.6b qwen2-moe-a2.7b --hedge 40 \
        --prefill-chunk 8 --cache-mode full --semantic-threshold 0.92
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from repro.cache import CACHE_MODES, GreenCache
from repro.configs import ARCH_IDS, get_config
from repro.costmodel import EnergyCostModel
from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import ModelProfile, Query, RouterConfig
from repro.data import stream as stream_lib
from repro.data import tokenizer as tok
from repro.serving import ModelEngine, PoolServer
from repro.telemetry import EnergyBudgetGovernor, Telemetry, dump_jsonl


def build_real_pool(arch_ids: List[str], max_batch: int = 4,
                    max_len: int = 192, seed: int = 0,
                    prefill_chunk: int = 8, disaggregate: bool = False):
    """Reduced-config real engines + matching pool profiles.

    ``prefill_chunk`` (prompt tokens per engine prefill tick, default 8 —
    recorded in ROADMAP conventions) cuts TTFT roughly by the chunk factor
    on attention-cached layouts; recurrent/ring layouts clamp to 1.

    With ``disaggregate`` each member also gets a decode twin sharing the
    primary's params (same weights, zero extra init cost beyond the twin's
    KV cache); the scheduler runs the pair role-specialized with KV
    migration at the phase boundary.  Returns ``(engines, pool,
    decode_engines)`` — the twin dict is empty when disaggregation is off,
    and layouts that can't migrate KV simply stay unified when attached."""
    engines: Dict[str, ModelEngine] = {}
    decode_engines: Dict[str, ModelEngine] = {}
    profiles: List[ModelProfile] = []
    for i, arch in enumerate(arch_ids):
        cfg = get_config(arch, smoke=True,
                         vocab_size=tok.VOCAB_SIZE, max_seq_len=max_len)
        eng = ModelEngine(arch, cfg, jax.random.PRNGKey(seed + i),
                          max_batch=max_batch, max_len=max_len,
                          detokenize=tok.decode, prefill_chunk=prefill_chunk)
        engines[arch] = eng
        profiles.append(eng.profile)
        if disaggregate:
            twin = ModelEngine(arch, cfg, jax.random.PRNGKey(seed + i),
                               max_batch=max_batch, max_len=max_len,
                               params=eng.params, detokenize=tok.decode,
                               prefill_chunk=prefill_chunk, role="decode")
            decode_engines[arch] = twin
    return engines, ModelPool(profiles), decode_engines


def exact_match_accuracy(query: Query, resp) -> float:
    """EM against the stream's reference (the examples' quality signal —
    untrained smoke models rarely match, which is itself informative: the
    router learns their true (low) quality online)."""
    if not query.reference:
        return 0.0
    return float(query.reference.strip().lower() in resp.text.strip().lower())


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pool", nargs="+", default=["granite-3-8b",
                                                  "rwkv6-1.6b",
                                                  "qwen2-moe-a2.7b"],
                    choices=ARCH_IDS)
    ap.add_argument("--queries", type=int, default=40)
    ap.add_argument("--lam", type=float, default=0.4)
    ap.add_argument("--hedge", type=int, default=None,
                    help="hedge after N scheduler steps in queue")
    ap.add_argument("--fail-engine", default=None,
                    help="inject a failure into this engine mid-run")
    ap.add_argument("--energy-budget-wh", type=float, default=None,
                    help="cumulative Wh cap for the run; the governor "
                         "tightens λ online to stay under it")
    ap.add_argument("--metrics-out", default=None,
                    help="write the JSONL telemetry dump to this path")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens consumed per engine prefill tick "
                         "(1 = token-wise legacy path; TTFT drops roughly "
                         "by this factor on attention-cached layouts)")
    ap.add_argument("--cache-mode", default="prefix", choices=CACHE_MODES,
                    help="GreenCache layers: prefix (launcher default — "
                         "cross-query prompt-KV reuse), semantic "
                         "(near-duplicate response cache), full (both), "
                         "off")
    ap.add_argument("--kv-cache-blocks", type=int, default=512,
                    help="per-engine prefix-KV pool capacity in blocks "
                         "(8 tokens each, host memory; LRU-evicted)")
    ap.add_argument("--semantic-threshold", type=float, default=0.92,
                    help="cosine similarity floor for a semantic response "
                         "cache hit (task-type/cluster guards always "
                         "apply)")
    ap.add_argument("--semantic-ttl", type=float, default=None,
                    help="max age in seconds for semantic response-cache "
                         "entries; older answers age out (default: no "
                         "staleness bound)")
    ap.add_argument("--featurize", default="auto",
                    choices=["auto", "host", "device"],
                    help="featurization placement: device = fused Pallas "
                         "featurize→score pipeline (kernels/featurize), "
                         "host = reference numpy path, auto = device on "
                         "TPU (elsewhere Pallas runs in interpret mode)")
    ap.add_argument("--cost-model", default="on", choices=["on", "off"],
                    help="predictive energy cost model (docs/ENERGY.md): "
                         "pre-dispatch Wh forecasts tilt routing, charge "
                         "the governor's in-flight budget, and calibrate "
                         "online from the metered joule ledger")
    ap.add_argument("--admission-planner", action="store_true",
                    help="energy-aware admission: defer arrivals whose "
                         "predicted Wh would breach the governor's "
                         "remaining budget this tick (needs --cost-model "
                         "on and --energy-budget-wh)")
    ap.add_argument("--disaggregate", action="store_true",
                    help="role-specialized serving: each member gets a "
                         "decode twin (shared params); prompts prefill on "
                         "the primary, KV migrates at the phase boundary, "
                         "decode streams from the twin (layouts without a "
                         "full-depth KV cache stay unified)")
    args = ap.parse_args()

    engines, pool, decode_engines = build_real_pool(
        args.pool, prefill_chunk=args.prefill_chunk,
        disaggregate=args.disaggregate)
    config = RouterConfig(lam=args.lam, energy_scale_wh=0.05,
                          featurize=args.featurize)
    router = GreenServRouter(config, pool)
    queries = stream_lib.make_stream(per_task=max(args.queries // 5, 1))
    queries = queries[: args.queries]
    governor = None
    if args.energy_budget_wh is not None:
        governor = EnergyBudgetGovernor(args.energy_budget_wh,
                                        horizon_queries=len(queries))
    telemetry = Telemetry(governor=governor)
    cache = GreenCache(mode=args.cache_mode,
                       kv_cache_blocks=args.kv_cache_blocks,
                       semantic_threshold=args.semantic_threshold,
                       semantic_ttl_s=args.semantic_ttl)
    cost_model = (EnergyCostModel() if args.cost_model == "on" else None)
    server = PoolServer(router, engines, tokenizer=tok.encode,
                        hedge_after_steps=args.hedge,
                        accuracy_fn=exact_match_accuracy,
                        telemetry=telemetry,
                        prefill_chunk=args.prefill_chunk,
                        cache=cache,
                        decode_engines=decode_engines or None,
                        cost_model=cost_model,
                        admission_planner=args.admission_planner)
    t0 = time.monotonic()
    # continuous-batching drive: arrivals park in the scheduler's queue and
    # are admitted into free prefill slots each tick (routing happens at
    # admission, so the bandit sees the live queue state)
    for i, q in enumerate(queries):
        server.enqueue(q)
        if args.fail_engine and i == len(queries) // 2:
            engines[args.fail_engine].inject_failure()
        server.step()
    server.run_until_drained()
    wall = time.monotonic() - t0

    counts = router.selection_counts()
    print(f"[serve] {len(server.responses)}/{len(queries)} queries in "
          f"{wall:.1f}s; restarts={server.stats['restarts']} "
          f"hedges={server.stats['hedges']} "
          f"migrations={server.stats['migrations']}")
    for name, c in zip(pool.names, counts):
        print(f"  {name:20s} selected {int(c):4d}×")
    total_wh = sum(r.energy_wh for r in server.responses.values())
    print(f"  total modeled energy: {total_wh:.4f} Wh; mean routing "
          f"overhead {router.mean_decision_ms:.2f} ms/query")
    if args.cache_mode != "off":
        cs = cache.stats()
        sem = cs.get("semantic", {})
        pre = cs.get("prefix", {})
        hit_tokens = sum(p["hit_tokens"] for p in pre.values())
        blocks = sum(p["blocks"] for p in pre.values())
        print(f"  cache[{args.cache_mode}]: semantic hits "
              f"{sem.get('hits', 0)}/{sem.get('lookups', 0)}; prefix hit "
              f"tokens {hit_tokens}; {blocks} KV blocks resident "
              f"({server.stats['cache_hits']} short-circuits)")
    if cost_model is not None:
        cm = cost_model.stats()
        print(f"  cost model: {cm['n_reconciled']}/{cm['n_predicted']} "
              f"forecasts reconciled; MAE {cm['mae_ratio']:.1%} of metered "
              f"Wh; deferred admissions {server.stats['deferred']}")
    print(telemetry.summary())
    if args.metrics_out:
        n = dump_jsonl(args.metrics_out, telemetry.registry, telemetry.power,
                       telemetry.events,
                       meta={"queries": len(queries), "wall_s": wall,
                             "lam": args.lam,
                             "budget_wh": args.energy_budget_wh})
        print(f"[serve] wrote {n} telemetry rows to {args.metrics_out}")


if __name__ == "__main__":
    main()
