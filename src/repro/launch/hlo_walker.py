"""While-aware HLO cost walker (§Roofline ground truth).

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of trip
count — a 62-layer scanned transformer under-reports flops and collective
traffic by 62×.  This walker parses the optimized (SPMD-partitioned) HLO
text and computes, per device:

    flops        2·M·N·K for every dot, ×(product of enclosing while trips)
    bytes        operand+result bytes of every top-level memory op
                 (fusion boundaries = HBM traffic granularity), ×trips
    coll_bytes   per-chip ICI wire bytes of every collective under the
                 ring/torus model (see launch.hlo), ×trips

Trip counts come from the canonical scan lowering: the while condition
compares the induction variable against a constant.  Conditionals take the
mean of their branch costs and raise a warning — the model code avoids
lax.cond on hot paths for exactly this reason.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\d]+\[[^\]]*\]\S*))\s+([\w\-]+)\((.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_NAME_REF = re.compile(r"%([\w.\-]+)")
_ATTR = re.compile(r"(\w+)=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONSTANT = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota", "partition-id", "replica-id"}


def _shape_dims(shape_text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE.finditer(shape_text):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 0)
    return total


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str       # everything after the opening paren (operands + attrs)

    def attr(self, key: str) -> Optional[str]:
        m = re.search(rf"{key}=%?([\w.\-]+)", self.rest)
        return m.group(1) if m else None

    def operand_names(self) -> List[str]:
        # names inside the first top-level paren group
        depth = 1
        out = []
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    out = _NAME_REF.findall(self.rest[:i])
                    break
        else:
            out = _NAME_REF.findall(self.rest.split(")")[0])
        return out


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        h = _COMP_HEADER.match(line)
        if h and ("{" in line) and " = " not in line:
            cur = Computation(h.group(1), [], {})
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.shape
        else:
            # parameter declarations etc inside the computation header — the
            # parameter instructions also match _INSTR; anything else skipped
            pm = re.match(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
                          r"((?:\([^)]*\))|(?:[\w\d]+\[[^\]]*\]\S*))\s+parameter\(",
                          line)
            if pm:
                ins = Instr(pm.group(1), pm.group(2), "parameter", "")
                cur.instrs.append(ins)
                cur.shapes[ins.name] = ins.shape
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_collectives: float = 0.0
    warnings: List[str] = dataclasses.field(default_factory=list)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.n_collectives += other.n_collectives * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        for w in other.warnings:
            if w not in self.warnings:
                self.warnings.append(w)


class HloWalker:
    def __init__(self, text: str, n_devices: int):
        self.comps, self.entry = parse_module(text)
        self.n_devices = n_devices
        self._memo: Dict[Tuple[str, str], Cost] = {}

    # -- helpers ---------------------------------------------------------------

    def _operand_bytes(self, comp: Computation, instr: Instr) -> int:
        return sum(_shape_bytes(comp.shapes.get(n, ""))
                   for n in instr.operand_names())

    def _dot_flops(self, comp: Computation, instr: Instr) -> float:
        result = 1
        for _, dims in _shape_dims(instr.shape):
            for d in dims:
                result *= d
        ops = instr.operand_names()
        lhs_shape = comp.shapes.get(ops[0], "") if ops else ""
        lhs_dims = _shape_dims(lhs_shape)
        contract = 1
        m = _CONTRACT.search(instr.rest)
        if m and lhs_dims:
            dims = lhs_dims[0][1]
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(dims):
                    contract *= dims[idx]
        return 2.0 * result * contract

    def _group_size(self, instr: Instr) -> int:
        m = _GROUPS_IOTA.search(instr.rest)
        if m:
            return max(int(m.group(2)), 1)
        m = _GROUPS_LIST.search(instr.rest)
        if m:
            first = [s for s in m.group(1).split(",") if s.strip() != ""]
            return max(len(first), 1)
        return self.n_devices

    def _collective_wire(self, comp: Computation, instr: Instr,
                         kind: str) -> float:
        k = self._group_size(instr)
        result_bytes = _shape_bytes(instr.shape)
        operand_bytes = self._operand_bytes(comp, instr) or result_bytes
        if kind == "all-reduce":
            return 2.0 * (k - 1) / k * operand_bytes
        if kind == "all-gather":
            return (k - 1) / k * result_bytes
        if kind in ("reduce-scatter", "all-to-all"):
            return (k - 1) / k * operand_bytes
        return float(result_bytes)       # collective-permute

    def _trip_count(self, cond_name: str) -> Tuple[float, Optional[str]]:
        comp = self.comps.get(cond_name)
        if comp is None:
            return 1.0, f"missing while condition {cond_name}"
        consts = {}
        cmp_operands = None
        for ins in comp.instrs:
            m = _CONSTANT.search(ins.rest) if ins.opcode == "constant" else None
            if ins.opcode == "constant":
                mc = _CONSTANT.search("constant(" + ins.rest)
                if mc:
                    consts[ins.name] = int(mc.group(1))
            if ins.opcode == "compare":
                cmp_operands = ins.operand_names()
        if cmp_operands:
            for n in cmp_operands:
                if n in consts:
                    return float(consts[n]), None
        # fallback: any constant in the condition
        if consts:
            return float(max(consts.values())), None
        return 1.0, f"no trip count for {cond_name}"

    # -- recursive cost ----------------------------------------------------------

    def _fusion_bytes(self, comp: Computation, ins: Instr) -> float:
        """HBM traffic of one fusion: slice-aware reads + root-aware writes.

        A fusion whose parameters are only consumed through dynamic-slice
        (xs slicing in a scan body) reads slices, not the whole buffer; a
        fusion rooted in dynamic-update-slice writes the update, not the
        whole buffer.  convert/bitcast/copy are traversed transparently —
        XLA:CPU's bf16→f32 emulation inserts them around everything.
        """
        callee = self.comps.get(ins.attr("calls") or "")
        if callee is None:
            return self._operand_bytes(comp, ins) + _shape_bytes(ins.shape)
        uses: Dict[str, List[Instr]] = {}
        for i in callee.instrs:
            for o in i.operand_names():
                uses.setdefault(o, []).append(i)

        def charged_read(name: str, depth: int = 0) -> Optional[float]:
            """Bytes read from buffer `name`; None ⇒ fully read."""
            if depth > 12:
                return None
            total = 0.0
            for u in uses.get(name, []):
                if u.opcode in ("convert", "bitcast", "copy", "transpose",
                                "reshape"):
                    sub = charged_read(u.name, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                elif u.opcode in ("dynamic-slice", "slice", "gather"):
                    total += _shape_bytes(u.shape)
                elif u.opcode == "dynamic-update-slice":
                    o = u.operand_names()
                    if o and o[0] == name:
                        continue       # pass-through buffer, not a read
                    return None
                else:
                    return None
            return total

        read_bytes = 0.0
        for i in callee.instrs:
            if i.opcode != "parameter":
                continue
            eff = charged_read(i.name)
            full = _shape_bytes(i.shape)
            read_bytes += full if eff is None else min(eff, full)

        by_name = {i.name: i for i in callee.instrs}
        root = callee.instrs[-1] if callee.instrs else None
        for _ in range(12):   # resolve through CPU-emulation convert chains
            if root is None or root.opcode not in ("convert", "bitcast",
                                                   "copy", "reshape"):
                break
            ops_ = root.operand_names()
            root = by_name.get(ops_[0]) if ops_ else None
        root_op = root.opcode if root else ""
        if root_op == "dynamic-update-slice":
            o = root.operand_names()
            upd = callee.shapes.get(o[1], "") if len(o) > 1 else ins.shape
            write_bytes = float(_shape_bytes(upd))
        else:
            write_bytes = float(_shape_bytes(ins.shape))
        return read_bytes + write_bytes

    def flops_only(self, comp_name: str) -> float:
        """Dot flops inside a fused computation (bytes stay at the boundary)."""
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        total = 0.0
        for ins in comp.instrs:
            if ins.opcode == "dot":
                total += self._dot_flops(comp, ins)
            elif ins.opcode == "fusion":
                callee = ins.attr("calls")
                if callee:
                    total += self.flops_only(callee)
        return total

    def cost_of(self, comp_name: str) -> Cost:
        memo_key = ("cost", comp_name)
        if memo_key in self._memo:
            return self._memo[memo_key]
        comp = self.comps.get(comp_name)
        cost = Cost()
        if comp is None:
            cost.warnings.append(f"missing computation {comp_name}")
            self._memo[memo_key] = cost
            return cost
        for ins in comp.instrs:
            op = ins.opcode
            if op in _SKIP_OPS:
                continue
            if op == "while":
                cond = ins.attr("condition")
                body = ins.attr("body")
                trip, warn = self._trip_count(cond) if cond else (1.0, "no cond")
                if warn:
                    cost.warnings.append(warn)
                if body:
                    cost.add(self.cost_of(body), trip)
                continue
            if op == "conditional":
                branches = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
                names = (_NAME_REF.findall(branches.group(1)) if branches
                         else [n for n in [ins.attr("true_computation"),
                                           ins.attr("false_computation")] if n])
                if names:
                    sub = Cost()
                    for n in names:
                        sub.add(self.cost_of(n), 1.0 / len(names))
                    cost.add(sub)
                    cost.warnings.append("conditional branch costs averaged")
                continue
            if op in ("call", "async-start"):
                callee = ins.attr("to_apply") or ins.attr("called_computation")
                if callee:
                    cost.add(self.cost_of(callee))
                continue
            kind = next((c for c in COLLECTIVES if op.startswith(c)), None)
            if kind is not None:
                if op.endswith("-done"):
                    continue
                wire = self._collective_wire(comp, ins, kind)
                cost.coll_bytes += wire
                cost.coll_by_kind[kind] = cost.coll_by_kind.get(kind, 0) + wire
                cost.n_collectives += 1
                cost.bytes += self._operand_bytes(comp, ins) + _shape_bytes(ins.shape)
                continue
            if op == "dot":
                cost.flops += self._dot_flops(comp, ins)
                cost.bytes += self._operand_bytes(comp, ins) + _shape_bytes(ins.shape)
                continue
            if op == "fusion":
                callee = ins.attr("calls")
                if callee:
                    cost.flops += self.flops_only(callee)
                cost.bytes += self._fusion_bytes(comp, ins)
                continue
            if op in ("custom-call",):
                # XLA:CPU sometimes lowers big matmuls to oneDNN custom-calls
                if "matmul" in ins.rest or "dot" in ins.rest:
                    cost.warnings.append("custom-call matmul not counted")
                cost.bytes += self._operand_bytes(comp, ins) + _shape_bytes(ins.shape)
                continue
            # ops that touch a slice, not their full operands: charging the
            # whole operand would bill a 64-iteration scan for 64 full-cache
            # reads when each iteration slices one layer
            if op in ("dynamic-slice", "slice", "gather", "broadcast"):
                cost.bytes += 2 * _shape_bytes(ins.shape)
                continue
            if op == "dynamic-update-slice":
                ops_ = ins.operand_names()
                upd = (comp.shapes.get(ops_[1], "") if len(ops_) > 1 else
                       ins.shape)
                cost.bytes += 2 * _shape_bytes(upd)
                continue
            if op == "scatter":
                ops_ = ins.operand_names()
                upd = (comp.shapes.get(ops_[2], "") if len(ops_) > 2 else
                       ins.shape)
                cost.bytes += 2 * _shape_bytes(upd)
                continue
            if op == "convert":
                # bf16<->f32 converts are XLA:CPU emulation artifacts; TPU
                # computes bf16 natively and fuses genuine casts
                continue
            # plain top-level op (copy, reduce, select, transpose, ...)
            cost.bytes += self._operand_bytes(comp, ins) + _shape_bytes(ins.shape)
        self._memo[memo_key] = cost
        return cost

    def entry_cost(self) -> Cost:
        entry = self.entry
        if entry is None:
            entry = next((n for n in self.comps if "main" in n),
                         next(iter(self.comps)))
        return self.cost_of(entry)


def module_cost(text: str, n_devices: int) -> Cost:
    return HloWalker(text, n_devices).entry_cost()


def profile_bytes(text: str, n_devices: int, top: int = 20):
    """Per-instruction byte attribution (trip-multiplied) — the 'profile'
    the §Perf iterations read in place of a wall-clock trace."""
    w = HloWalker(text, n_devices)
    tally: Dict[str, float] = {}

    def walk(comp_name: str, mult: float) -> None:
        comp = w.comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.opcode
            if op in _SKIP_OPS:
                continue
            if op == "while":
                trip, _ = w._trip_count(ins.attr("condition") or "")
                body = ins.attr("body")
                if body:
                    walk(body, mult * trip)
                continue
            if op == "call":
                callee = ins.attr("to_apply")
                if callee:
                    walk(callee, mult)
                continue
            if op == "conditional":
                continue
            if op == "fusion":
                b = w._fusion_bytes(comp, ins)
            elif op in ("dynamic-slice", "slice", "gather", "broadcast"):
                b = 2 * _shape_bytes(ins.shape)
            elif op == "dynamic-update-slice":
                o = ins.operand_names()
                upd = comp.shapes.get(o[1], "") if len(o) > 1 else ins.shape
                b = 2 * _shape_bytes(upd)
            elif op == "convert":
                continue
            else:
                b = w._operand_bytes(comp, ins) + _shape_bytes(ins.shape)
            tally[f"{op}:{ins.name}"] = tally.get(f"{op}:{ins.name}", 0) + b * mult

    walk(w.entry or next(iter(w.comps)), 1.0)
    return sorted(tally.items(), key=lambda kv: -kv[1])[:top]
