import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any jax import (jax locks the device
count at first init), which is why this module is the only place they live —
tests and benches keep seeing 1 CPU device.

For every cell this proves on 512 placeholder devices what would have to be
true on 512 real TPU v5e chips: the shardings are coherent, the collectives
lower, and the per-device memory fits.  The compiled artifact's
cost_analysis + parsed collective traffic feed EXPERIMENTS §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
        --shape train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import dataclasses
import json
import pathlib
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro import compat
from repro.configs import (ARCH_IDS, SHAPES, cell_applicable, for_mode,
                           get_config, input_specs)
from repro.core import energy as energy_lib
from repro.launch import hlo_walker as walker_lib
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.train import make_prefill_step, make_serve_step, make_train_step
from repro.train.step import opt_state_shapes

HBM_PER_CHIP = 16 * 1024**3    # TPU v5e: 16 GB


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: Optional[Dict[str, Any]] = None,
               n_micro: int = 1):
    """Build the jitted step for one cell and return (lowered, meta)."""
    import dataclasses as _dc
    cell = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    if cell.kind != "train":
        cfg = for_mode(cfg, "serve")
    if overrides:
        cfg = _dc.replace(cfg, **overrides)   # overrides take final precedence
    if not cell_applicable(cfg, cell):
        return None, {"skipped": True,
                      "reason": "long_500k needs sub-quadratic attention"}

    if cell.kind == "train":
        if n_micro == 0:
            # default: 2 sequences/device/microbatch; 1 for 314B grok —
            # capped so every microbatch keeps ≥1 row per data shard
            dp = mesh.devices.size // mesh.shape["model"]
            rows_per_dev = max(cell.global_batch // dp, 1)
            target = 16 if cfg.param_count() > 1e11 else 8
            n_micro = max(min(target, rows_per_dev), 1)
        batch = input_specs(cfg, cell)
        bundle = make_train_step(cfg, mesh, batch, n_micro=n_micro)
        params = api.param_shapes(cfg)
        opt = opt_state_shapes(cfg)
        args = (params, opt, batch)
        donate = (0, 1)          # params + optimizer state update in place
    elif cell.kind == "prefill":
        batch = input_specs(cfg, cell)
        bundle = make_prefill_step(cfg, mesh, batch)
        params = api.param_shapes(cfg)
        args = (params, batch)
        donate = ()
    else:  # decode
        spec = input_specs(cfg, cell)
        bundle = make_serve_step(cfg, mesh, cell.global_batch, cell.seq_len)
        params = api.param_shapes(cfg)
        args = (params, spec["cache"], spec["token"])
        donate = (1,)            # cache appended in place

    fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                 out_shardings=bundle.out_shardings, donate_argnums=donate)
    with compat.set_mesh(mesh):
        lowered = fn.lower(*args)
    meta = {"cfg": cfg, "mesh": mesh, "cell": cell, "bundle": bundle}
    return lowered, meta


def analyse(lowered, meta, compile_it: bool = True) -> Dict[str, Any]:
    cfg, mesh, cell = meta["cfg"], meta["mesh"], meta["cell"]
    chips = mesh.devices.size
    rec: Dict[str, Any] = {
        "arch": cfg.name, "shape": cell.name, "kind": cell.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    t0 = time.time()
    if compile_it:
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        mem = compiled.memory_analysis()
        rec["arg_bytes_per_dev"] = int(mem.argument_size_in_bytes)
        rec["temp_bytes_per_dev"] = int(mem.temp_size_in_bytes)
        rec["out_bytes_per_dev"] = int(mem.output_size_in_bytes)
        rec["alias_bytes_per_dev"] = int(mem.alias_size_in_bytes)
        peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes +
                mem.output_size_in_bytes - mem.alias_size_in_bytes)
        rec["peak_bytes_per_dev"] = int(peak)
        rec["fits_hbm"] = bool(peak <= HBM_PER_CHIP)
        ca = compat.cost_analysis(compiled)
        # raw XLA numbers (NOT trip-count-aware — kept for cross-checking)
        rec["xla_flops_per_dev"] = float(ca.get("flops", 0.0))
        rec["xla_bytes_per_dev"] = float(ca.get("bytes accessed", 0.0))
        txt = compiled.as_text()
    else:
        txt = lowered.as_text()
    # while-aware walker: multiplies scan bodies by trip count (XLA's own
    # cost_analysis counts a 62-layer scanned stack once — see hlo_walker)
    cost = walker_lib.module_cost(txt, chips)
    rec["hlo_flops_per_dev"] = cost.flops
    rec["hlo_bytes_per_dev"] = cost.bytes
    rec["collective_bytes_per_dev"] = cost.coll_bytes
    rec["collective_ops"] = cost.n_collectives
    rec["collective_by_kind"] = {k: round(v) for k, v in
                                 cost.coll_by_kind.items()}
    if cost.warnings:
        rec["walker_warnings"] = cost.warnings

    if compile_it:
        terms = energy_lib.roofline(
            rec["hlo_flops_per_dev"], rec["hlo_bytes_per_dev"],
            cost.coll_bytes, chips=1)     # walker numbers are per-device
        rec["t_compute_s"] = terms.t_compute
        rec["t_memory_s"] = terms.t_memory
        rec["t_collective_s"] = terms.t_collective
        rec["bottleneck"] = terms.bottleneck
        rec["roofline_fraction"] = terms.roofline_fraction
        # MODEL_FLOPS sanity ratio: useful model FLOPs vs compiled FLOPs
        rec["model_flops"] = model_flops(cfg, cell)
        total_hlo = rec["hlo_flops_per_dev"] * chips
        rec["model_vs_hlo"] = (rec["model_flops"] / total_hlo
                               if total_hlo else 0.0)
        rec["energy_wh_per_step"] = energy_lib.energy_wh(
            energy_lib.roofline(rec["hlo_flops_per_dev"] * chips,
                                rec["hlo_bytes_per_dev"] * chips,
                                cost.coll_bytes * chips, chips=chips))
    return rec


def model_flops(cfg, cell) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE) for train; 2·N·D for inference."""
    n = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch   # decode: one token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             compile_it: bool = True, n_micro: int = 0,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    lowered, meta = lower_cell(arch, shape_name, multi_pod,
                               overrides=overrides, n_micro=n_micro)
    if lowered is None:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16", **meta}
    rec = analyse(lowered, meta, compile_it=compile_it)

    # XLA:CPU emulates bf16 by upcasting every op to f32, materializing f32
    # copies of weights/caches that do not exist on a real TPU (bf16-native
    # MXU/VPU).  When the raw CPU-measured peak misses the HBM budget, we
    # recompile the cell with f32 end-to-end (no convert artifacts) and
    # estimate the TPU peak as exact bf16 args/outs + temp_f32 / 2.
    if compile_it and not rec.get("fits_hbm", True):
        ov = dict(overrides or {})
        ov.update(dtype="float32", param_dtype="float32")
        try:
            l32, m32 = lower_cell(arch, shape_name, multi_pod,
                                  overrides=ov, n_micro=n_micro)
            mem32 = l32.compile().memory_analysis()
            corrected = (rec["arg_bytes_per_dev"] + rec["out_bytes_per_dev"]
                         - rec["alias_bytes_per_dev"]
                         + mem32.temp_size_in_bytes // 2)
            rec["temp_f32_bytes_per_dev"] = int(mem32.temp_size_in_bytes)
            rec["peak_bytes_tpu_est"] = int(corrected)
            rec["fits_hbm_tpu_est"] = bool(corrected <= HBM_PER_CHIP)
        except Exception as e:  # noqa: BLE001
            rec["tpu_correction_error"] = f"{type(e).__name__}: {e}"
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-compile", action="store_true",
                    help="lower only (debug)")
    ap.add_argument("--n-micro", type=int, default=0,
                    help="microbatches for train cells (0 = auto: 8)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        cells = [(args.arch, args.shape)]

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    failures = 0
    for arch, shape in cells:
        tag = f"{arch}__{shape}__{mesh_tag}"
        try:
            rec = run_cell(arch, shape, args.multi_pod,
                           compile_it=not args.no_compile,
                           n_micro=args.n_micro)
            status = ("SKIP" if rec.get("skipped")
                      else "OK" if rec.get("fits_hbm", True)
                      else "OK*" if rec.get("fits_hbm_tpu_est") else "OOM")
            print(f"[{status}] {tag}: "
                  f"peak={rec.get('peak_bytes_per_dev', 0)/2**30:.2f}GiB "
                  f"flops/dev={rec.get('hlo_flops_per_dev', 0):.3g} "
                  f"coll={rec.get('collective_bytes_per_dev', 0)/1e6:.1f}MB "
                  f"bottleneck={rec.get('bottleneck', '-')}")
            if status == "OOM":
                failures += 1
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                   "error": f"{type(e).__name__}: {e}"}
            print(f"[FAIL] {tag}: {e}")
            failures += 1
        (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    print(f"done: {len(cells)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
