"""Version-dispatch layer for JAX API drift (supported: 0.4.x and 0.5+).

The repo targets two JAX generations at once: the pinned floor (0.4.37,
what this container ships) and current releases, which renamed or moved
several APIs the stack depends on.  Every shim below follows the same
pattern — feature-detect once at import time, prefer the modern spelling,
fall back to the legacy one — so call sites never branch on versions.

Covered drift:

  =============================  ==================================  ====
  modern (0.5+/0.6+)             legacy (0.4.x)                      shim
  =============================  ==================================  ====
  jax.tree.flatten_with_path     jax.tree_util.tree_flatten_with_…   tree_flatten_with_path
  pltpu.CompilerParams           pltpu.TPUCompilerParams             tpu_compiler_params
  jax.make_mesh(axis_types=…)    jax.make_mesh (no axis_types)       make_mesh
  jax.sharding.AxisType.Auto     (implicit; no enum)                 auto_axis_types
  jax.set_mesh(mesh)             ``with mesh:`` context              set_mesh
  compiled.cost_analysis()→dict  …→[dict]                            cost_analysis
  jax.shard_map(check_vma=…)     jax.experimental.shard_map          shard_map
                                 .shard_map(check_rep=…)
  =============================  ==================================  ====

Adding a new shim: feature-detect with ``hasattr``/``inspect.signature``
(never parse ``jax.__version__`` for behaviour — only export it for
diagnostics), keep the modern call signature as the shim's signature, and
add a case to ``tests/test_compat.py``.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.tree_util

JAX_VERSION: Tuple[int, ...] = tuple(
    int(p) for p in jax.__version__.split(".")[:3] if p.isdigit())

__all__ = [
    "JAX_VERSION", "tree_flatten_with_path", "path_str",
    "tpu_compiler_params", "auto_axis_types", "make_mesh", "set_mesh",
    "cost_analysis", "shard_map", "donation_kwargs",
]


# ---------------------------------------------------------------------------
# Pytree paths
# ---------------------------------------------------------------------------

if hasattr(jax.tree, "flatten_with_path"):          # 0.5+
    def tree_flatten_with_path(tree: Any, is_leaf=None):
        return jax.tree.flatten_with_path(tree, is_leaf=is_leaf)
else:                                               # 0.4.x
    def tree_flatten_with_path(tree: Any, is_leaf=None):
        return jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)


def path_str(path: Sequence[Any]) -> str:
    """Render a key path as 'outer/inner/leaf' (DictKey/SequenceKey/…)."""
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


# ---------------------------------------------------------------------------
# Pallas TPU compiler params
# ---------------------------------------------------------------------------

_COMPILER_PARAMS_CLS = None


def tpu_compiler_params(**kwargs):
    """Build pltpu.CompilerParams / TPUCompilerParams, whichever exists.

    Pallas is imported lazily (cached on first call) so non-kernel
    consumers of this module (checkpointing, meshes, the train launcher)
    don't pull in the Pallas TPU stack.  Unknown fields are dropped rather
    than raised: compiler hints (dimension_semantics & co.) are
    performance knobs, and a missing knob on some JAX version must not
    break kernel construction.
    """
    global _COMPILER_PARAMS_CLS
    if _COMPILER_PARAMS_CLS is None:
        from jax.experimental.pallas import tpu as pltpu
        _COMPILER_PARAMS_CLS = (getattr(pltpu, "CompilerParams", None)
                                or getattr(pltpu, "TPUCompilerParams"))
    fields = {f.name for f in dataclasses.fields(_COMPILER_PARAMS_CLS)}
    return _COMPILER_PARAMS_CLS(**{k: v for k, v in kwargs.items()
                                   if k in fields})


# ---------------------------------------------------------------------------
# Mesh construction / ambient mesh
# ---------------------------------------------------------------------------

_MAKE_MESH_HAS_AXIS_TYPES = ("axis_types"
                             in inspect.signature(jax.make_mesh).parameters)


def auto_axis_types(n: int) -> Optional[tuple]:
    """(AxisType.Auto,) * n where the enum exists, else None (0.4.x default
    semantics are already 'auto')."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None) -> "jax.sharding.Mesh":
    """jax.make_mesh with Auto axis types wherever the arg is supported."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _MAKE_MESH_HAS_AXIS_TYPES:
        types = auto_axis_types(len(tuple(axis_shapes)))
        if types is not None:
            kwargs["axis_types"] = types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Modern JAX spells this jax.set_mesh(mesh); on 0.4.x the Mesh object is
    its own context manager with the same scoping behaviour.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# ---------------------------------------------------------------------------
# Compiled-artifact cost analysis
# ---------------------------------------------------------------------------


def cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() normalized to a flat dict.

    0.4.x returns a single-element list of dicts (one per program), newer
    versions return the dict directly; either may be None/empty.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


# ---------------------------------------------------------------------------
# shard_map
# ---------------------------------------------------------------------------


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """jax.shard_map with the modern signature; legacy fallback maps
    check_vma onto the old check_rep flag (same meaning, renamed)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _legacy
    return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_vma)


# ---------------------------------------------------------------------------
# Buffer donation
# ---------------------------------------------------------------------------


def donation_kwargs(*argnums: int) -> dict:
    """``jax.jit`` donation kwargs for state-carrying jitted programs.

    Buffer donation (input aliased to output, no copy) is implemented on
    TPU/GPU but not on CPU, where XLA emits a warning per traced call —
    so on CPU this returns no kwargs and the jit simply copies.  Callers
    splat the result: ``jax.jit(f, **compat.donation_kwargs(0))``.  Only
    donate arguments the caller immediately replaces with the call's
    output (e.g. a ``BanditState`` threaded through update, the k-means
    device tuple) — a donated input buffer is dead after the call.
    """
    if jax.default_backend() in ("tpu", "gpu", "cuda", "rocm"):
        return {"donate_argnums": tuple(argnums)}
    return {}
