"""Gradient compression: int8 quantization with error feedback.

Two integration points (DESIGN §5, distributed-optimization tricks):

  * ``ef_compress(grads, ef)`` — quantize each gradient leaf to int8 with a
    per-tensor scale and carry the quantization residual into the next step
    (error feedback, Seide et al. / Karimireddy et al.).  Applied at the
    gradient-accumulation boundary; the returned grads are the dequantized
    values so the optimizer math is unchanged.
  * ``compressed_psum(tree, axis, mesh)`` — an explicit int8 cross-replica
    all-reduce built with shard_map: shared max-scale (pmax) → int8 encode →
    int32 psum → dequantize.  4× less ICI traffic than an fp32 ring
    all-reduce at <0.4% relative quantization error (verified in tests).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat
from repro.models.layers import Params

INT8_MAX = 127.0


def quantize_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q int8, scale f32)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / INT8_MAX
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(g32 / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_init(params: Params) -> Params:
    """Zero error-feedback residuals matching the parameter tree."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress(grads: Params, ef: Params) -> Tuple[Params, Params]:
    """Quantize (grads + residual); residual carries what int8 dropped."""
    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_leaf(corrected)
        deq = dequantize_leaf(q, s)
        return deq.astype(g.dtype), corrected - deq

    out = jax.tree.map(leaf, grads, ef)
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_g = jax.tree.unflatten(treedef, [x[0] for x in flat])
    new_ef = jax.tree.unflatten(treedef, [x[1] for x in flat])
    return new_g, new_ef


# ---------------------------------------------------------------------------
# Explicit compressed all-reduce (shard_map over the data axis)
# ---------------------------------------------------------------------------


def _psum_int8_leaf(g: jax.Array, axis) -> jax.Array:
    g32 = g.astype(jnp.float32)
    # shared scale so every replica's int8 grid aligns
    scale = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis) / INT8_MAX
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(g32 / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    # int8 payload on the wire; accumulate in int32 to avoid overflow
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return (total.astype(jnp.float32) * scale).astype(g.dtype)


def compressed_psum(stacked: Params, axis: str, mesh: Mesh) -> Params:
    """All-reduce per-replica gradients over mesh axis ``axis`` in int8.

    ``stacked`` leaves carry a leading replica dim (n_axis, ...) sharded over
    ``axis`` — i.e. replica i's partial gradient lives on mesh slice i.  The
    result drops the leading dim and is the dequantized sum, replicated along
    ``axis``.  This is the wire-compression building block the shard_map
    training variant calls after per-replica backward passes.
    """
    in_spec = jax.tree.map(
        lambda g: P(axis, *([None] * (g.ndim - 1))), stacked)
    out_spec = jax.tree.map(
        lambda g: P(*([None] * (g.ndim - 1))), stacked)

    def body(t):
        return jax.tree.map(lambda g: _psum_int8_leaf(g[0], axis), t)

    fn = compat.shard_map(body, mesh=mesh, in_specs=(in_spec,),
                          out_specs=out_spec, check_vma=False)
    return fn(stacked)
