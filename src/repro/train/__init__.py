"""Training substrate: AdamW, step factories, gradient compression."""
from repro.train.compress import (compressed_psum, ef_compress, ef_init,
                                  quantize_leaf, dequantize_leaf)
from repro.train.optim import (OptConfig, adamw_update, cosine_lr,
                               init_opt_state, opt_shapes)
from repro.train.step import (StepBundle, make_opt_state, make_prefill_step,
                              make_serve_step, make_train_step,
                              opt_state_shapes)

__all__ = [
    "OptConfig", "adamw_update", "cosine_lr", "init_opt_state", "opt_shapes",
    "StepBundle", "make_opt_state", "make_prefill_step", "make_serve_step",
    "make_train_step", "opt_state_shapes", "compressed_psum", "ef_compress",
    "ef_init", "quantize_leaf", "dequantize_leaf",
]
