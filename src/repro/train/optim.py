"""AdamW + cosine schedule in pure JAX, with sharded low-precision moments.

Moments are stored in ``cfg.moment_dtype`` (fp32 default; bf16 for grok-314B
where fp32 moments alone exceed the 16 GB/chip HBM budget) and promoted to
fp32 inside the update.  Moment trees inherit the parameter PartitionSpecs,
so ZeRO-style optimizer-state sharding falls out of the param sharding policy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, sds


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: str = "float32"


def cosine_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def opt_shapes(param_tree: Params, cfg: OptConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct tree for the optimizer state (dry-run input spec)."""
    mom = lambda s: sds(s.shape, cfg.moment_dtype)
    return {"m": jax.tree.map(mom, param_tree),
            "v": jax.tree.map(mom, param_tree),
            "step": sds((), "int32")}


def init_opt_state(params: Params, cfg: OptConfig) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.dtype(cfg.moment_dtype))
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads: Params, opt_state: Dict[str, Any], params: Params,
                 cfg: OptConfig) -> Tuple[Params, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step; returns (params, opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    mom_dt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p32
        return ((p32 - lr * delta).astype(p.dtype),
                m32.astype(mom_dt), v32.astype(mom_dt))

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.unflatten(treedef, [x[0] for x in flat])
    new_m = jax.tree.unflatten(treedef, [x[1] for x in flat])
    new_v = jax.tree.unflatten(treedef, [x[2] for x in flat])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
