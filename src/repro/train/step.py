"""jit-ready step factories: train / prefill / serve with full shardings.

Each factory returns a ``StepBundle`` carrying the function plus pytrees of
NamedShardings for every input and output — ``jax.jit(bundle.fn,
in_shardings=bundle.in_shardings, out_shardings=bundle.out_shardings)`` is
exactly what the dry-run lowers and what the launchers execute.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import api
from repro.models.config import ModelConfig
from repro.models.sharding import ShardingPolicy, _fit
from repro.train import optim
from repro.train.compress import ef_compress, ef_init
from repro.train.optim import OptConfig


@dataclasses.dataclass
class StepBundle:
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    policy: ShardingPolicy
    describe: str = ""


def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _batch_shardings(policy: ShardingPolicy, batch_tree) -> Any:
    def spec_of(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        return NamedSharding(policy.mesh, policy.batch_spec(name, tuple(leaf.shape)))
    return jax.tree_util.tree_map_with_path(spec_of, batch_tree)


def _microbatch(batch: Dict[str, Any], n_micro: int) -> Dict[str, Any]:
    def split(x):
        b = x.shape[0]
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(split, batch)


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, mesh: Mesh, example_batch: Dict[str, Any],
                    opt_cfg: Optional[OptConfig] = None, n_micro: int = 1,
                    use_ef_compress: bool = False,
                    loss_chunk: int = 512) -> StepBundle:
    """Full train step: microbatched grads → (optional) int8 error-feedback
    compression → AdamW.  FSDP/TP/DP come from the ShardingPolicy."""
    policy = ShardingPolicy(cfg, mesh, "train")
    shard = policy.shard_fn()
    ocfg = opt_cfg or OptConfig(moment_dtype=cfg.moment_dtype)

    def compute_loss(params, mb):
        loss, metrics = api.loss_fn(params, mb, cfg, shard,
                                    loss_chunk=loss_chunk)
        return loss, metrics

    grad_fn = jax.value_and_grad(compute_loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, aux_metrics), grads = grad_fn(params, batch)
        else:
            mbs = _microbatch(batch, n_micro)
            accum_dt = jnp.dtype(cfg.grad_accum_dtype)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dt), params)

            def micro(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, gi: (a.astype(jnp.float32)
                                   + gi.astype(jnp.float32) / n_micro
                                   ).astype(accum_dt), acc, g)
                return acc, l

            grads, losses = jax.lax.scan(micro, acc0, mbs)
            loss = jnp.mean(losses)
            aux_metrics = {}

        ef = opt_state.get("ef")
        if use_ef_compress:
            grads, ef = ef_compress(grads, ef)

        core = {"m": opt_state["m"], "v": opt_state["v"],
                "step": opt_state["step"]}
        params, core, om = optim.adamw_update(grads, core, params, ocfg)
        new_opt = dict(core)
        if use_ef_compress:
            new_opt["ef"] = ef
        metrics = {"loss": loss, **om, **aux_metrics}
        return params, new_opt, metrics

    # --- shardings -----------------------------------------------------------
    param_tree = api.param_shapes(cfg)
    pspecs = policy.param_specs(param_tree)
    psh = _named(mesh, pspecs)
    osh: Dict[str, Any] = {"m": psh, "v": psh,
                           "step": NamedSharding(mesh, P())}
    if use_ef_compress:
        osh["ef"] = psh
    bsh = _batch_shardings(policy, example_batch)
    rep = NamedSharding(mesh, P())
    metric_keys = {"loss": rep, "lr": rep, "grad_norm": rep}
    if n_micro == 1:
        metric_keys.update({"ce": rep, "aux": rep})
    return StepBundle(
        fn=train_step,
        in_shardings=(psh, osh, bsh),
        out_shardings=(psh, osh, metric_keys),
        policy=policy,
        describe=f"train_step n_micro={n_micro} ef={use_ef_compress}")


def make_opt_state(cfg: ModelConfig, params, opt_cfg: Optional[OptConfig] = None,
                   use_ef_compress: bool = False):
    ocfg = opt_cfg or OptConfig(moment_dtype=cfg.moment_dtype)
    state = optim.init_opt_state(params, ocfg)
    if use_ef_compress:
        state["ef"] = ef_init(params)
    return state


def opt_state_shapes(cfg: ModelConfig, opt_cfg: Optional[OptConfig] = None,
                     use_ef_compress: bool = False):
    ocfg = opt_cfg or OptConfig(moment_dtype=cfg.moment_dtype)
    tree = optim.opt_shapes(api.param_shapes(cfg), ocfg)
    if use_ef_compress:
        from repro.models.layers import sds
        tree["ef"] = jax.tree.map(lambda s: sds(s.shape, "float32"),
                                  api.param_shapes(cfg))
    return tree


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, mesh: Mesh,
                      example_batch: Dict[str, Any]) -> StepBundle:
    policy = ShardingPolicy(cfg, mesh, "serve")
    shard = policy.shard_fn()

    def prefill_step(params, batch):
        return api.prefill(params, batch, cfg, shard)

    param_tree = api.param_shapes(cfg)
    psh = _named(mesh, policy.param_specs(param_tree))
    bsh = _batch_shardings(policy, example_batch)
    b = example_batch["tokens"].shape[0]
    out = NamedSharding(mesh, _fit(mesh, (b, cfg.vocab_size),
                                   ("data", "model")))
    return StepBundle(fn=prefill_step, in_shardings=(psh, bsh),
                      out_shardings=out, policy=policy, describe="prefill")


def make_serve_step(cfg: ModelConfig, mesh: Mesh, batch_size: int,
                    seq_len: int) -> StepBundle:
    """One-token decode against a KV/state cache of depth ``seq_len``."""
    policy = ShardingPolicy(cfg, mesh, "serve")
    cfg = dataclasses.replace(
        cfg, kv_update=policy.kv_update_mode(batch_size, cfg.n_kv_heads))
    shard = policy.shard_fn()

    def serve_step(params, cache, token):
        logits, new_cache = api.serve_step(params, token, cache, cfg, shard)
        return logits, new_cache

    param_tree = api.param_shapes(cfg)
    psh = _named(mesh, policy.param_specs(param_tree))
    cache_tree = api.cache_shapes(cfg, batch_size, seq_len)
    csh = _named(mesh, policy.cache_specs(cache_tree))
    tsh = NamedSharding(mesh, policy.batch_spec("token", (batch_size, 1)))
    lsh = NamedSharding(
        mesh, policy.act_spec("dec_btv", (batch_size, 1, cfg.vocab_size)))
    return StepBundle(fn=serve_step, in_shardings=(psh, csh, tsh),
                      out_shardings=(lsh, csh), policy=policy,
                      describe=f"serve_step kv={seq_len}")
