"""Bounded host-side KV block pool with LRU eviction.

The unit of storage is a *block*: ``block_tokens`` consecutive prompt
tokens' worth of per-layer K and V for one model — numpy arrays of shape
``(n_layers, block_tokens, kv_heads, head_dim)`` each, in the engine's
cache dtype.  Blocks live on the host (the device-side slot cache is
transient per request; the pool is what survives across requests and
engine restarts), so capacity is a host-memory knob, not an HBM one.

The pool itself is policy-free: it stores, hands out, frees, and keeps a
deterministic recency order (a monotonic operation counter, never wall
time).  *Which* block to evict is the index's decision — a radix/trie
index must keep chains contiguous, so it evicts least-recently-used
**leaves** (``repro.cache.prefix.PrefixIndex``); the pool only reports
who is least recently used.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np


class KVBlockPool:
    """Bounded store of (k, v) host blocks keyed by integer block id.

    ``put`` refuses (returns ``None``) when full — the caller frees a
    victim first.  Recency is a monotonic counter bumped by ``touch``;
    ``lru_order`` is therefore deterministic for a deterministic call
    sequence (seeded workloads replay to identical eviction traces).
    """

    def __init__(self, max_blocks: int, block_tokens: int = 8):
        if max_blocks < 1:
            raise ValueError(f"max_blocks must be >= 1, got {max_blocks}")
        if block_tokens < 1:
            raise ValueError(f"block_tokens must be >= 1, got {block_tokens}")
        self.max_blocks = max_blocks
        self.block_tokens = block_tokens
        self._blocks: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._last_used: Dict[int, int] = {}
        self._next_id = 0
        self._tick = 0
        self.evictions = 0
        self.nbytes = 0

    # -- capacity ------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._blocks)

    @property
    def full(self) -> bool:
        return len(self._blocks) >= self.max_blocks

    # -- storage -------------------------------------------------------------

    def put(self, k: np.ndarray, v: np.ndarray):
        """Store one block; returns its id, or None when at capacity."""
        if self.full:
            return None
        if k.shape[1] != self.block_tokens:
            raise ValueError(
                f"block must hold {self.block_tokens} tokens, got {k.shape}")
        bid = self._next_id
        self._next_id += 1
        self._blocks[bid] = (k, v)
        self.nbytes += k.nbytes + v.nbytes
        self.touch(bid)
        return bid

    def get(self, bid: int) -> Tuple[np.ndarray, np.ndarray]:
        return self._blocks[bid]

    def free(self, bid: int) -> None:
        k, v = self._blocks.pop(bid)
        self.nbytes -= k.nbytes + v.nbytes
        self._last_used.pop(bid, None)
        self.evictions += 1

    def touch(self, bid: int) -> None:
        """Bump recency (monotonic op counter — replayable, no wall time)."""
        self._tick += 1
        self._last_used[bid] = self._tick

    def lru_order(self) -> List[int]:
        """Block ids, least recently used first."""
        return sorted(self._last_used, key=self._last_used.get)

    def stats(self) -> dict:
        return {"blocks": len(self._blocks), "max_blocks": self.max_blocks,
                "block_tokens": self.block_tokens, "nbytes": self.nbytes,
                "evictions": self.evictions}
