"""Prefix-KV reuse: a token-level radix trie over completed prompts.

Per-slot decode caches in this repo are absolute-positioned from 0
(``models/lm.py`` / ``models/encdec.py``): the K/V written for prompt
position ``i`` depends only on tokens ``0..i`` (causal attention, RoPE by
absolute position).  Two prompts sharing a token prefix therefore produce
**identical** KV for the shared region, so a completed prompt's KV can be
captured once and spliced into any later slot whose prompt starts with the
same tokens — the engine then prefill-chunks only the uncached suffix
(GreenServ's cheapest token: the one never computed).

Structure: a trie whose edges are whole ``block_tokens``-token blocks
(radix over token tuples, vLLM-style).  Each node owns exactly one block
in a bounded :class:`~repro.cache.kvpool.KVBlockPool`; a lookup walks
whole-block matches from the root, so hits are block-aligned and the
chain root→node is always contiguous.  Eviction removes the
least-recently-used **leaf** (interior nodes anchor live chains), which
keeps every remaining chain usable and makes eviction deterministic for a
seeded workload.

Partial tail blocks are not cached: the capture path rounds the prompt
down to whole blocks (a short tail is cheap to recompute and would
fragment the index).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cache.kvpool import KVBlockPool


class _Node:
    __slots__ = ("key", "parent", "children", "bid")

    def __init__(self, key: Tuple[int, ...], parent: Optional["_Node"],
                 bid: Optional[int]):
        self.key = key
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.bid = bid


class PrefixIndex:
    """Block-granular radix trie mapping token prefixes to pooled KV."""

    def __init__(self, pool: KVBlockPool):
        self.pool = pool
        self.root = _Node((), None, None)
        self._node_by_bid: Dict[int, _Node] = {}
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserted_blocks = 0

    def _walk(self, tokens: Sequence[int], touch: bool) -> List[_Node]:
        """Longest chain of whole-block matches from the root."""
        bt = self.pool.block_tokens
        node, chain = self.root, []
        for i in range(0, (len(tokens) // bt) * bt, bt):
            child = node.children.get(tuple(tokens[i:i + bt]))
            if child is None:
                break
            if touch:
                self.pool.touch(child.bid)
            chain.append(child)
            node = child
        return chain

    # -- queries -------------------------------------------------------------

    def peek_len(self, tokens: Sequence[int]) -> int:
        """Longest cached prefix in tokens, **without** bumping recency —
        for routing-time probes that may not materialize into a hit."""
        return len(self._walk(tokens, touch=False)) * self.pool.block_tokens

    def lookup(self, tokens: Sequence[int]
               ) -> Tuple[int, List[Tuple[np.ndarray, np.ndarray]]]:
        """Longest cached prefix: (n_tokens, [(k, v) blocks, root-first]).

        Matched blocks are LRU-touched (a used chain stays warm)."""
        self.lookups += 1
        chain = self._walk(tokens, touch=True)
        if not chain:
            return 0, []
        self.hits += 1
        n = len(chain) * self.pool.block_tokens
        self.hit_tokens += n
        return n, [self.pool.get(node.bid) for node in chain]

    # -- growth / eviction ---------------------------------------------------

    def insert(self, tokens: Sequence[int], k: np.ndarray,
               v: np.ndarray) -> int:
        """Register a completed prompt's KV; returns blocks newly stored.

        ``k``/``v``: host arrays ``(n_layers, P, kv_heads, head_dim)``
        covering at least the whole-block span of ``tokens``.  Existing
        nodes on the path are only recency-bumped (dedup); new nodes are
        allocated, evicting LRU leaves when the pool is at capacity.  If
        every pooled block is an ancestor on the current path (pool far
        smaller than one prompt), insertion stops rather than evicting
        its own chain.
        """
        bt = self.pool.block_tokens
        node, added = self.root, 0
        on_path = set()
        # never index tokens the arrays don't cover (defense in depth —
        # the engine already skips overflowed prompts)
        span = min(len(tokens), k.shape[1], v.shape[1])
        for i in range(0, (span // bt) * bt, bt):
            key = tuple(tokens[i:i + bt])
            child = node.children.get(key)
            if child is None:
                while self.pool.full:
                    if not self._evict_leaf(protect=on_path):
                        return added
                bid = self.pool.put(np.ascontiguousarray(k[:, i:i + bt]),
                                    np.ascontiguousarray(v[:, i:i + bt]))
                child = _Node(key, node, bid)
                node.children[key] = child
                self._node_by_bid[bid] = child
                self.inserted_blocks += 1
                added += 1
            else:
                self.pool.touch(child.bid)
            on_path.add(child.bid)
            node = child
        return added

    def _evict_leaf(self, protect: set) -> bool:
        """Drop the least-recently-used childless node; False if none."""
        for bid in self.pool.lru_order():
            node = self._node_by_bid[bid]
            if node.children or bid in protect:
                continue
            del node.parent.children[node.key]
            del self._node_by_bid[bid]
            self.pool.free(bid)
            return True
        return False

    def __len__(self) -> int:
        return len(self._node_by_bid)


class PrefixCache:
    """Per-engine facade: trie + pool + the match shape engines consume.

    One instance per engine (KV is parameter-specific); created by the
    ``GreenCache`` facade and attached via ``ModelEngine.set_prefix_cache``.
    """

    def __init__(self, max_blocks: int = 256, block_tokens: int = 8):
        self.pool = KVBlockPool(max_blocks, block_tokens)
        self.index = PrefixIndex(self.pool)

    @property
    def block_tokens(self) -> int:
        return self.pool.block_tokens

    def peek_len(self, tokens: Sequence[int],
                 max_tokens: Optional[int] = None) -> int:
        n = self.index.peek_len(tokens)
        return min(n, max_tokens) if max_tokens is not None else n

    def match(self, tokens: Sequence[int], max_tokens: int
              ) -> Tuple[int, Optional[np.ndarray], Optional[np.ndarray]]:
        """Longest usable prefix for a prompt: (p, k, v) with k/v shaped
        ``(L, p, Hk, hd)``, or (0, None, None).  ``max_tokens`` caps the
        splice (an engine must keep >= 1 prompt token to feed, so it
        passes ``len(prompt) - 1``)."""
        if max_tokens <= 0:
            return 0, None, None
        n, blocks = self.index.lookup(tokens)
        p = min(n, max_tokens)
        if p <= 0:
            return 0, None, None
        k = np.concatenate([b[0] for b in blocks], axis=1)[:, :p]
        v = np.concatenate([b[1] for b in blocks], axis=1)[:, :p]
        return p, k, v

    def insert(self, tokens: Sequence[int], k: np.ndarray,
               v: np.ndarray) -> int:
        if len(tokens) < self.block_tokens:
            return 0
        return self.index.insert(tokens, k, v)

    def stats(self) -> dict:
        return {"lookups": self.index.lookups, "hits": self.index.hits,
                "hit_tokens": self.index.hit_tokens,
                "inserted_blocks": self.index.inserted_blocks,
                **self.pool.stats()}
