"""Semantic response cache: answer near-duplicate queries with zero engine
work.

Keys are the router's own hashed-n-gram sentence embeddings
(``core/embedding.py`` — unit vectors, so cosine similarity is one dot
product against the entry matrix).  A hit requires *all three* guards:

  * cosine(query, entry) >= ``threshold``;
  * same task type (a "summarize X" answer must never serve a "solve X"
    query, however close the vocabulary);
  * same semantic cluster, when cluster features are enabled (catches
    task-classifier confusions between topically distant duplicates).

Entries store the completed ``Response`` payload (tokens/text, the model
that produced it, its measured energy and accuracy) so the scheduler can
synthesize an answer without routing — the avoided energy is the cached
completion's own measured Wh, credited to telemetry/governor as
``kind="semantic"``.

Eviction is LRU over a fixed slot array with a monotonic op counter, so a
seeded workload replays to the same cache state.  An optional TTL
(``ttl_s``) ages entries out against an injectable clock — wall time in
live serving, a virtual clock in simulation — so a stale answer (a
"today's status" query, a since-updated document) stops being served once
it is older than the staleness budget.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import numpy as np


@dataclasses.dataclass
class SemanticEntry:
    """One cached completion (the Response payload + its guard features)."""

    text: str                    # the query text that produced it
    task_label: int
    cluster: int
    model_name: str
    tokens: List[int]
    text_out: str
    energy_wh: float             # the original completion's measured energy
    accuracy: float
    input_tokens: int
    output_tokens: int


class SemanticCache:
    """Fixed-capacity embedding-similarity cache with task/cluster guards.

    Callers supply unit-norm embeddings (the ``GreenCache`` facade shares
    the router's ``EmbeddingModel`` so a query is embedded once).
    """

    def __init__(self, dim: int = 384, threshold: float = 0.92,
                 max_entries: int = 512, cluster_guard: bool = True,
                 ttl_s: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None):
        if not (0.0 < threshold <= 1.0):
            raise ValueError(f"threshold must be in (0, 1], got {threshold}")
        if ttl_s is not None and ttl_s <= 0.0:
            raise ValueError(f"ttl_s must be positive, got {ttl_s}")
        self.threshold = threshold
        self.max_entries = max_entries
        self.cluster_guard = cluster_guard
        self.ttl_s = ttl_s
        self._clock = clock or time.monotonic
        self._emb = np.zeros((max_entries, dim), np.float32)
        self._task = np.full(max_entries, -1, np.int64)      # -1 = free slot
        self._cluster = np.zeros(max_entries, np.int64)
        self._entries: List[Optional[SemanticEntry]] = [None] * max_entries
        self._last_used = np.zeros(max_entries, np.int64)
        self._born = np.zeros(max_entries, np.float64)       # insert time
        self._tick = 0
        self.lookups = 0
        self.hits = 0
        self.insertions = 0
        self.evictions = 0
        self.expirations = 0

    def __len__(self) -> int:
        return int(np.sum(self._task >= 0))

    def _expire(self) -> None:
        """Free every entry older than ``ttl_s`` (TTL aging runs lazily at
        lookup time — an expired slot must never answer, and freeing it
        here also makes it the next insertion target)."""
        if self.ttl_s is None:
            return
        stale = (self._task >= 0) \
            & (self._clock() - self._born > self.ttl_s)
        if stale.any():
            for slot in np.flatnonzero(stale):
                self._entries[int(slot)] = None
            self._task[stale] = -1
            self._last_used[stale] = 0
            self.expirations += int(stale.sum())

    def lookup(self, embedding: np.ndarray, task_label: int,
               cluster: int) -> Optional[SemanticEntry]:
        """Best guarded match above threshold, or None.  Ties break to the
        lowest slot index (deterministic).  With a TTL configured, aged-out
        entries are expired (freed) before matching."""
        self.lookups += 1
        self._expire()
        live = self._task == task_label
        if self.cluster_guard:
            live &= self._cluster == cluster
        if not live.any():
            return None
        sims = self._emb @ np.asarray(embedding, np.float32)
        sims = np.where(live, sims, -np.inf)
        best = int(np.argmax(sims))
        if sims[best] < self.threshold:
            return None
        self.hits += 1
        self._tick += 1
        self._last_used[best] = self._tick
        return self._entries[best]

    def insert(self, embedding: np.ndarray, entry: SemanticEntry) -> None:
        """Store a completion; evicts the LRU entry when full (expired
        slots are freed first, so a TTL'd-out entry never outcompetes a
        live one for residency)."""
        self._expire()
        free = np.flatnonzero(self._task < 0)
        if free.size:
            slot = int(free[0])
        else:
            slot = int(np.argmin(self._last_used))
            self.evictions += 1
        self._emb[slot] = np.asarray(embedding, np.float32)
        self._task[slot] = entry.task_label
        self._cluster[slot] = entry.cluster
        self._entries[slot] = entry
        self._tick += 1
        self._last_used[slot] = self._tick
        self._born[slot] = self._clock()
        self.insertions += 1

    def stats(self) -> dict:
        return {"entries": len(self), "max_entries": self.max_entries,
                "threshold": self.threshold, "lookups": self.lookups,
                "hits": self.hits, "insertions": self.insertions,
                "evictions": self.evictions, "ttl_s": self.ttl_s,
                "expirations": self.expirations}
