"""GreenCache: cross-query reuse for the GreenServ serving stack.

The cheapest token is the one never computed.  Two cooperating layers cut
engine work before routing ever sees a query:

  * **Prefix-KV reuse** (``prefix``/``kvpool``) — a per-engine radix trie
    over completed prompts backed by a bounded host-side KV block pool;
    on admission the longest cached prefix is spliced into the decode
    slot's cache (``models/api.splice_prefix``) and the engine
    prefill-chunks only the uncached suffix.
  * **Semantic response cache** (``semantic``) — embedding-similarity
    lookup (cosine + task-type/cluster guards) that answers exact or
    near-duplicate queries with the cached completion, zero engine work.

``GreenCache`` is the facade ``PoolServer`` holds: it owns the semantic
cache, creates per-engine prefix caches on demand, and (once bound to the
router's ``ContextGenerator``) computes the guard features with the same
embedder/classifier/centroids the router uses — read-only, so cache
probes never perturb routing state (no k-means updates, no classifier
fits).  Every hit is credited as avoided energy to telemetry
(``greenserv_energy_joules_avoided_total{kind=prefix|semantic}``) and the
governor's credit ledger; see ``docs/CACHING.md``.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.cache.kvpool import KVBlockPool
from repro.cache.prefix import PrefixCache, PrefixIndex
from repro.cache.semantic import SemanticCache, SemanticEntry

CACHE_MODES = ("off", "prefix", "semantic", "full")


class GreenCache:
    """Facade over the prefix-KV and semantic layers.

    ``mode`` selects which layers are live: ``off`` (inert — convenient
    for flag plumbing), ``prefix``, ``semantic``, or ``full`` (both).
    ``kv_cache_blocks``/``block_tokens`` size each per-engine KV pool;
    ``semantic_threshold``/``semantic_entries``/``semantic_ttl_s``
    parameterize the response cache (``semantic_ttl_s``: max entry age in
    seconds before a cached answer ages out; ``clock`` supplies the time
    source — pass a virtual clock in simulation, default is monotonic
    wall time).
    """

    def __init__(self, mode: str = "full", kv_cache_blocks: int = 256,
                 block_tokens: int = 8, semantic_threshold: float = 0.92,
                 semantic_entries: int = 512, cluster_guard: bool = True,
                 semantic_ttl_s: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None):
        if mode not in CACHE_MODES:
            raise ValueError(f"mode must be one of {CACHE_MODES}, got {mode!r}")
        self.mode = mode
        self.kv_cache_blocks = kv_cache_blocks
        self.block_tokens = block_tokens
        self.semantic: Optional[SemanticCache] = None
        if mode in ("semantic", "full"):
            self.semantic = SemanticCache(threshold=semantic_threshold,
                                          max_entries=semantic_entries,
                                          cluster_guard=cluster_guard,
                                          ttl_s=semantic_ttl_s, clock=clock)
        self._prefix: Dict[str, PrefixCache] = {}
        self._context = None            # router's ContextGenerator, read-only

    # -- layer gates ---------------------------------------------------------

    @property
    def prefix_enabled(self) -> bool:
        return self.mode in ("prefix", "full")

    @property
    def semantic_enabled(self) -> bool:
        return self.semantic is not None

    def prefix_for(self, engine_name: str) -> Optional[PrefixCache]:
        """The engine's own prefix cache (KV is parameter-specific), or
        None when prefix reuse is off."""
        if not self.prefix_enabled:
            return None
        cache = self._prefix.get(engine_name)
        if cache is None:
            cache = self._prefix[engine_name] = PrefixCache(
                max_blocks=self.kv_cache_blocks,
                block_tokens=self.block_tokens)
        return cache

    # -- guard features (shared with the router, read-only) -------------------

    def bind_context(self, context) -> None:
        """Share the router's ContextGenerator for guard features.  The
        semantic cache must see the *same* embedding space and task labels
        the router routes by — a private embedder would let a hit fire on
        a pair the router considers unrelated."""
        self._context = context
        if (self.semantic is not None and len(self.semantic) == 0
                and self.semantic._emb.shape[1] != context.embedder.dim):
            # re-key an EMPTY cache to the embedder's dimensionality;
            # a populated cache keeps its space (entries would be orphaned)
            self.semantic._emb = np.zeros(
                (self.semantic.max_entries, context.embedder.dim), np.float32)

    def features(self, text: str) -> Tuple[int, int, np.ndarray]:
        """(task_label, cluster, unit embedding) for one query — read-only
        probes of the bound context (``predict``/``assign`` mutate
        nothing; k-means updates stay exclusive to ``route_batch``)."""
        if self._context is None:
            raise RuntimeError("GreenCache.features before bind_context")
        ctx = self._context
        emb = ctx.embedder.encode(text)
        task = (int(ctx.task_classifier.predict(text)) if ctx.use_task else 0)
        cluster = (ctx.kmeans.assign(emb) if ctx.use_cluster else 0)
        return task, cluster, emb

    def features_batch(self, texts) -> Tuple[np.ndarray, np.ndarray,
                                             np.ndarray]:
        """Batched ``features``: (labels (Q,), clusters (Q,), embeddings
        (Q, dim)) in one featurization pass via the bound context's
        read-only ``probe_batch`` — on the device path a single fused
        Pallas call whose embeddings the router reuses afterwards."""
        if self._context is None:
            raise RuntimeError("GreenCache.features_batch before bind_context")
        return self._context.probe_batch(texts)

    # -- introspection --------------------------------------------------------

    def stats(self) -> dict:
        out = {"mode": self.mode}
        if self.semantic is not None:
            out["semantic"] = self.semantic.stats()
        if self._prefix:
            out["prefix"] = {name: pc.stats()
                             for name, pc in sorted(self._prefix.items())}
        return out


__all__ = ["CACHE_MODES", "GreenCache", "KVBlockPool", "PrefixCache",
           "PrefixIndex", "SemanticCache", "SemanticEntry"]
