"""Fleet planning: partition the device set into engine-pool shards.

``plan_fleet`` splits ``jax.devices()`` round-robin into ``n_shards``
disjoint groups and builds one ``(len(group), 1)`` ``("data", "model")``
mesh per group — the same axis convention as ``launch.mesh.make_host_mesh``
so ``models.sharding.ShardingPolicy`` specs apply unchanged on a shard
mesh (on a 1-device shard the policy's mesh-size fallback replicates).

Each shard runs a full replica of the model pool behind its own
``PoolServer`` + ``GreenServRouter`` (weak scaling: n shards absorb n×
the arrival rate at ~flat per-query latency).  The fleet-level mesh over
*all* devices exists only for degradation bookkeeping: when a shard
dies, ``FleetController`` records a ``distributed.elastic.plan_remesh``
plan over it (how the remaining chips would re-mesh), mirroring the
training-side elastic story.

Planning is pure bookkeeping — importing this module never touches jax
device state; meshes are built lazily from the recorded device ids.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


def base_model_name(member: str) -> str:
    """Strip the ``@shard`` adoption suffix: arms a shard adopts during
    fail-over are named ``<base>@<dead-shard>`` so pool names stay unique
    while the all-reduce still merges their statistics per base model."""
    return member.split("@", 1)[0]


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """One engine-pool shard: a named, disjoint slice of the device set."""

    index: int
    name: str
    device_ids: Tuple[int, ...]
    models: Tuple[str, ...]

    @property
    def n_devices(self) -> int:
        return len(self.device_ids)


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """Shard layout + mesh builders (axes fixed to ("data", "model"))."""

    shards: Tuple[ShardSpec, ...]
    axes: Tuple[str, str] = ("data", "model")

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def _devices_by_id(self):
        import jax
        return {d.id: d for d in jax.devices()}

    def shard_mesh(self, spec: ShardSpec):
        """Per-shard mesh: (n_dev, 1) over exactly the shard's devices."""
        from jax.sharding import Mesh
        by_id = self._devices_by_id()
        devs = [by_id[i] for i in spec.device_ids]
        return Mesh(np.array(devs).reshape(len(devs), 1), self.axes)

    def fleet_mesh(self):
        """Whole-fleet mesh (all shards' devices) — the frame of reference
        for ``plan_remesh`` degradation records on shard loss."""
        from jax.sharding import Mesh
        by_id = self._devices_by_id()
        ids = [i for spec in self.shards for i in spec.device_ids]
        devs = [by_id[i] for i in ids]
        return Mesh(np.array(devs).reshape(len(devs), 1), self.axes)


def plan_fleet(n_shards: int,
               pool_names: Sequence[str],
               devices: Optional[Sequence] = None) -> FleetPlan:
    """Partition devices round-robin (``devices[i::n_shards]``) into
    ``n_shards`` shard specs, each replicating the full ``pool_names``.

    With fewer devices than shards (the common CPU case: 1 device, many
    virtual shards), shards share devices one-to-one by index modulo the
    device count — the controller is a concurrency structure there, not a
    placement one, and the meshes degenerate to (1, 1).
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if not pool_names:
        raise ValueError("pool_names must be non-empty")
    if devices is None:
        import jax
        devices = jax.devices()
    ids = [d.id for d in devices]
    shards: List[ShardSpec] = []
    models = tuple(pool_names)
    for i in range(n_shards):
        mine = tuple(ids[i::n_shards]) if len(ids) >= n_shards \
            else (ids[i % len(ids)],)
        shards.append(ShardSpec(index=i, name=f"shard{i}",
                                device_ids=mine, models=models))
    return FleetPlan(shards=tuple(shards))
