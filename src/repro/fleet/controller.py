"""Fleet controller: sharded engine pools behind one dispatch plane.

Topology (docs/FLEET.md): every shard is a full replica of the model
pool — its own ``PoolServer``, its own ``GreenServRouter`` — placed on a
disjoint device group from ``plan_fleet``.  The controller load-balances
arrivals across live shards, beats each shard's heartbeat on every
controller tick, periodically all-reduces the routers' feedback
sufficient statistics (``FeedbackAllReduce`` — exact, LinUCB stats are
additive), and fails over dead shards without losing a request.

Failure semantics: ``kill_shard`` only stops a shard's clock — detection
happens through the shared ``HeartbeatMonitor`` (virtual-clock
injectable, satellite of ``distributed.fault``), exactly like a real
shard silently dropping off the network.  ``_fail_over`` then

  1. harvests completions that landed before death (responses are read
     through a per-shard ``harvested`` set, never popped — PoolServer's
     hedge-resurrection guard inspects ``server.responses``);
  2. collects every unanswered query: parked arrivals plus in-flight
     primaries (hedges are retries of a primary, not work of their own);
  3. re-registers the dead shard's engines on survivors via
     ``PoolServer.add_engine`` under ``<base>@<dead-shard>`` names —
     zero-calibration arms whose statistics the next all-reduce seeds
     from the global per-base totals;
  4. records a ``distributed.elastic.plan_remesh`` degradation plan over
     the fleet mesh (how the surviving chips would re-mesh);
  5. re-dispatches the collected queries to live shards.

``drive_fleet`` is the virtual-clock loop (same idle-jump discipline as
``benchmarks.common.run_scenario``) used by ``benchmarks/bench_pool_scale``
and the fleet test suite.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.distributed.fault import HeartbeatMonitor
from repro.fleet.plan import FleetPlan, ShardSpec, base_model_name
from repro.fleet.sync import FeedbackAllReduce
from repro.serving.scheduler import LivelockError, PoolServer


def _arrayify(tree):
    """Map python scalars in a nested dict to 0-d numpy arrays."""
    if isinstance(tree, dict):
        return {k: _arrayify(v) for k, v in tree.items()}
    if isinstance(tree, (bool, int, float)):
        return np.asarray(tree)
    return tree


class FleetShard:
    """One pool replica: spec + server + liveness + harvest bookkeeping."""

    def __init__(self, spec: ShardSpec, server: PoolServer, mesh=None):
        self.spec = spec
        self.name = spec.name
        self.server = server
        self.mesh = mesh
        self.alive = True
        # uids whose responses the controller has already read out of
        # server.responses (which is never popped — see module docstring)
        self.harvested: set = set()

    @property
    def load(self) -> int:
        return len(self.server.arrivals) + len(self.server.inflight)


class FleetController:
    """Dispatch + liveness + stat-sync + fail-over over a set of shards."""

    def __init__(self, shards: Sequence[FleetShard],
                 sync_every: int = 8,
                 heartbeat_timeout_s: float = 5.0,
                 clock: Optional[Callable[[], float]] = None,
                 engine_factory: Optional[
                     Callable[..., object]] = None,
                 fleet_mesh=None):
        if not shards:
            raise ValueError("fleet needs at least one shard")
        self.shards: Dict[str, FleetShard] = {s.name: s for s in shards}
        if len(self.shards) != len(shards):
            raise ValueError("duplicate shard names")
        self.clock = clock or time.monotonic
        self.sync_every = int(sync_every)
        self.monitor = HeartbeatMonitor(heartbeat_timeout_s,
                                        clock=self.clock)
        for s in shards:
            self.monitor.register(s.name)
        # (profile, target_shard_spec) -> engine, for fail-over adoption;
        # without it fail-over still re-dispatches, just without the
        # extra capacity
        self.engine_factory = engine_factory
        self.fleet_mesh = fleet_mesh
        cfg = shards[0].server.router.config
        self.allreduce = FeedbackAllReduce(cfg.lambda_reg, cfg.context_dim)
        self.responses: Dict[int, object] = {}
        self.dispatched: Dict[int, str] = {}     # uid -> shard name
        self.unanswered: set = set()
        # terminal failures (reliability layer): uid -> TIMED_OUT/FAILED
        # state string, harvested from each shard's ``server.failed``
        # registry exactly like responses — a terminally-failed request
        # is answered (negatively), never re-dispatched, and must not
        # hold ``drive_fleet``'s drain condition open
        self.failures: Dict[int, str] = {}
        # the controller's *belief* about routable shards: a killed shard
        # keeps receiving traffic until its heartbeat goes stale — the
        # controller has no oracle channel to the failure (queries
        # dispatched into the detection window are exactly what fail-over
        # must recover)
        self._routable: set = {s.name for s in shards}
        self.events: List[dict] = []
        self.stats = {"dispatched": 0, "redispatched": 0, "completed": 0,
                      "failed": 0, "failovers": 0, "syncs": 0,
                      "adopted_engines": 0}
        self._steps = 0

    # -- dispatch -------------------------------------------------------
    def live_shards(self) -> List[FleetShard]:
        """Shards whose process is actually running (steppable)."""
        return [s for s in self.shards.values() if s.alive]

    def routable_shards(self) -> List[FleetShard]:
        """Shards the controller *believes* are healthy — includes a dead
        shard until its heartbeat times out (see ``_routable``)."""
        return [s for s in self.shards.values() if s.name in self._routable]

    def dispatch(self, query) -> str:
        """Least-loaded believed-healthy shard (ties by shard index)."""
        routable = self.routable_shards()
        if not routable:
            raise RuntimeError("no routable shards to dispatch to")
        shard = min(routable, key=lambda s: (s.load, s.spec.index))
        shard.server.enqueue(query)
        first_time = query.uid not in self.dispatched
        self.dispatched[query.uid] = shard.name
        self.unanswered.add(query.uid)
        self.stats["dispatched" if first_time else "redispatched"] += 1
        return shard.name

    def dispatch_many(self, queries: Sequence) -> None:
        for q in queries:
            self.dispatch(q)

    # -- main loop ------------------------------------------------------
    def step(self) -> List:
        """One fleet tick: step live shards (beating their heartbeats),
        harvest fresh responses, fail over shards the monitor flags
        stale, run the periodic stat sync.  Returns fresh responses."""
        self._steps += 1
        done: List = []
        for shard in self.live_shards():
            shard.server.step()
            self.monitor.beat(shard.name)
            done.extend(self._harvest(shard))
        for name in self.monitor.stale():
            self._fail_over(self.shards[name])
        if self.sync_every and self._steps % self.sync_every == 0 \
                and len(self._sync_targets()) > 1:
            self.sync_now()
        return done

    def _sync_targets(self) -> List[FleetShard]:
        # a dead-but-undetected shard is routable but unreadable — the
        # all-reduce can only touch shards that are both
        return [s for s in self.live_shards()
                if s.name in self._routable]

    def _harvest(self, shard: FleetShard) -> List:
        fresh = []
        for uid, resp in shard.server.responses.items():
            if uid in shard.harvested:
                continue
            shard.harvested.add(uid)
            if uid in self.responses:     # answered earlier by a survivor
                continue
            self.responses[uid] = resp
            self.unanswered.discard(uid)
            self.stats["completed"] += 1
            fresh.append(resp)
        for uid, req in getattr(shard.server, "failed", {}).items():
            if uid in shard.harvested:
                continue
            shard.harvested.add(uid)
            if uid in self.responses or uid in self.failures:
                continue
            self.failures[uid] = req.state.value
            self.unanswered.discard(uid)
            self.stats["failed"] += 1
        return fresh

    # -- liveness / fail-over -------------------------------------------
    def kill_shard(self, name: str) -> None:
        """Simulate shard death: it stops stepping (and so stops beating
        its heartbeat).  Detection and recovery happen in ``step`` once
        the monitor times the shard out."""
        self.shards[name].alive = False

    def next_stale_deadline(self) -> Optional[float]:
        """Earliest virtual time a dead-but-undetected shard goes stale
        (None if all registered shards are alive) — virtual-clock drivers
        jump here when live shards are idle."""
        deadlines = [hb.last_beat + self.monitor.timeout_s + 1e-6
                     for name, hb in self.monitor._beats.items()
                     if not self.shards[name].alive]
        return min(deadlines) if deadlines else None

    def _fail_over(self, dead: FleetShard) -> None:
        self.monitor.deregister(dead.name)
        dead.alive = False
        self._routable.discard(dead.name)
        survivors = self.routable_shards()
        if not survivors:
            raise RuntimeError(
                f"shard {dead.name} died with no survivors")
        srv = dead.server
        self._harvest(dead)   # completions that landed before death
        lost = list(srv.arrivals)
        lost += [req.query for uid, req in srv.inflight.items()
                 if req.hedge_of is None and uid not in self.responses
                 and uid not in self.failures]
        adopted = 0
        if self.engine_factory is not None:
            for i, member in enumerate(srv.router.pool.names):
                target = survivors[i % len(survivors)]
                profile = srv.router.pool[i]
                new_name = (f"{base_model_name(profile.name)}"
                            f"@{dead.name}")
                if new_name in target.server.engines:
                    continue   # chained failure already adopted this base
                new_profile = dataclasses.replace(profile, name=new_name)
                target.server.add_engine(
                    new_profile,
                    self.engine_factory(new_profile, target.spec))
                adopted += 1
        self.stats["adopted_engines"] += adopted
        remesh = self._remesh_record(dead)
        self.events.append({"kind": "failover", "shard": dead.name,
                            "t": self.clock(), "redispatched": len(lost),
                            "adopted_engines": adopted, "remesh": remesh})
        self.stats["failovers"] += 1
        for q in lost:
            self.dispatch(q)

    def _remesh_record(self, dead: FleetShard) -> Optional[dict]:
        """Elastic-degradation bookkeeping: how the surviving fleet chips
        would re-mesh (distributed.elastic.plan_remesh), recorded on the
        fail-over event.  None when the fleet shares devices (CPU) or the
        survivor count can't host the model axis."""
        if self.fleet_mesh is None:
            return None
        try:
            from repro.distributed.elastic import plan_remesh
            plan = plan_remesh(self.fleet_mesh,
                               lost_chips=dead.spec.n_devices)
            return dataclasses.asdict(plan)
        except (ImportError, ValueError):
            return None

    # -- stat sync ------------------------------------------------------
    def sync_now(self) -> dict:
        report = self.allreduce.sync(
            {s.name: s.server.router for s in self._sync_targets()})
        self.stats["syncs"] += 1
        return report

    def set_lambda(self, lam: float) -> None:
        """Fleet-uniform scalarization: governance retunes every live
        replica together so the all-reduce merges like with like."""
        for shard in self._sync_targets():
            shard.server.router.set_lambda(lam)

    # -- telemetry ------------------------------------------------------
    def modeled_time_s(self) -> float:
        """Max modeled engine time across the whole fleet (dead shards
        included — their past work happened) — the virtual-clock pace."""
        times = [eng.modeled_time_s()
                 for shard in self.shards.values()
                 for eng in shard.server.engines.values()
                 if hasattr(eng, "modeled_time_s")]
        return max(times, default=0.0)

    def total_joules(self) -> float:
        return sum(eng.cumulative_joules()
                   for shard in self.shards.values()
                   for eng in shard.server.engines.values())

    @property
    def mean_decision_ms(self) -> float:
        """Routing overhead per query, averaged over live replicas."""
        ms = [s.server.router.mean_decision_ms
              for s in self.live_shards()
              if s.server.router.n_routed > 0]
        return sum(ms) / len(ms) if ms else 0.0

    def sample(self, t_s: float) -> dict:
        return {"t_s": round(t_s, 4),
                "completed": self.stats["completed"],
                "failed": self.stats["failed"],
                "inflight": sum(len(s.server.inflight)
                                for s in self.live_shards()),
                "parked": sum(len(s.server.arrivals)
                              for s in self.live_shards()),
                "shards_alive": len(self.live_shards()),
                "joules": round(self.total_joules(), 3)}

    # -- checkpointing --------------------------------------------------
    def state_dict(self) -> dict:
        """Fleet-wide control-plane state: every shard's full router
        state (bandit + k-means + λ), shared cost models where present,
        and the all-reduce accumulators/snapshots."""
        out = {"shards": {}, "allreduce": self.allreduce.state_dict()}
        for name, shard in self.shards.items():
            entry = {"router": shard.server.router.state_dict()}
            cm = shard.server.cost_model
            if cm is not None:
                entry["cost_model"] = cm.state_dict()
            out["shards"][name] = entry
        # distributed.checkpoint wants array leaves (restore compares
        # shapes); the routers' python int/float scalars become 0-d
        # arrays and load_state_dict's int()/float() casts take them back
        return _arrayify(out)

    def load_state_dict(self, d: Mapping) -> None:
        for name, entry in d["shards"].items():
            shard = self.shards[name]
            shard.server.router.load_state_dict(entry["router"])
            if "cost_model" in entry and shard.server.cost_model is not None:
                shard.server.cost_model.load_state_dict(
                    entry["cost_model"])
        self.allreduce.load_state_dict(d["allreduce"])

    def save_checkpoint(self, directory: str, step: int) -> str:
        from repro.distributed import checkpoint as ckpt
        return ckpt.save(directory, step, self.state_dict())

    def load_checkpoint(self, directory: str,
                        step: Optional[int] = None) -> int:
        from repro.distributed import checkpoint as ckpt
        tree, _ = ckpt.restore(directory, like=self.state_dict(),
                               step=step)
        self.load_state_dict(tree)
        loaded = step if step is not None \
            else ckpt.latest_step(directory)
        return int(loaded)


def build_fleet(plan: FleetPlan,
                router_factory: Callable[[ShardSpec], object],
                engine_factory: Callable[..., object],
                sync_every: int = 8,
                heartbeat_timeout_s: float = 5.0,
                clock: Optional[Callable[[], float]] = None,
                build_meshes: bool = False,
                server_kwargs: Optional[dict] = None) -> FleetController:
    """Wire a ``FleetController`` from a plan: one router replica + one
    ``PoolServer`` per shard, engines from ``engine_factory(profile,
    spec)``.  ``build_meshes=True`` additionally materializes per-shard
    and fleet meshes (requires the plan's device ids to be live and
    disjoint — skip on a shared-device CPU fleet)."""
    shards = []
    for spec in plan.shards:
        router = router_factory(spec)
        engines = {p.name: engine_factory(p, spec)
                   for p in [router.pool[i]
                             for i in range(len(router.pool))]}
        server = PoolServer(router, engines, clock=clock,
                            **(server_kwargs or {}))
        mesh = plan.shard_mesh(spec) if build_meshes else None
        shards.append(FleetShard(spec, server, mesh=mesh))
    fleet_mesh = plan.fleet_mesh() if build_meshes else None
    return FleetController(shards, sync_every=sync_every,
                           heartbeat_timeout_s=heartbeat_timeout_s,
                           clock=clock, engine_factory=engine_factory,
                           fleet_mesh=fleet_mesh)


def drive_fleet(controller: FleetController,
                queries: Sequence,
                arrivals_s: Sequence[float],
                clk: Dict[str, float],
                events: Sequence[Tuple[float, Callable[[], None]]] = (),
                max_steps: int = 200_000,
                trace_every: int = 50) -> List[dict]:
    """Virtual-clock drive (the ``run_scenario`` discipline): arrivals
    enter at their timestamps, the clock advances by the fleet's modeled
    work per tick, idle gaps jump straight to the next arrival, scripted
    event, or heartbeat deadline — so fail-over detection costs zero wall
    time.  Returns the telemetry trajectory."""
    order = sorted(range(len(events)), key=lambda i: events[i][0])
    events = [events[i] for i in order]
    ev_i = arr_i = steps = 0
    last_modeled = controller.modeled_time_s()
    traj: List[dict] = []
    while arr_i < len(queries) or controller.unanswered:
        if steps >= max_steps:
            snaps = "\n".join(
                f"[shard {s.name}] " + s.server.drain_snapshot()
                for s in controller.live_shards())
            raise LivelockError(
                f"fleet not drained after {max_steps} steps "
                f"({len(controller.unanswered)} unanswered)\n{snaps}")
        while ev_i < len(events) and events[ev_i][0] <= clk["t"]:
            events[ev_i][1]()
            ev_i += 1
        live_pending = sum(s.load for s in controller.live_shards())
        if live_pending == 0:
            targets = []
            if arr_i < len(queries):
                targets.append(arrivals_s[arr_i])
            if ev_i < len(events):
                targets.append(events[ev_i][0])
            if controller.unanswered:
                deadline = controller.next_stale_deadline()
                if deadline is not None:
                    targets.append(deadline)
            ahead = [t for t in targets if t > clk["t"]]
            if ahead:
                # land on the next wake-up and fall through — the
                # admission loop below uses <=, so a jump exactly onto an
                # arrival admits it this very iteration (a `continue`
                # here would re-enter this block, see the target as
                # no-longer-ahead, and leapfrog it)
                clk["t"] = min(ahead)
                while ev_i < len(events) and events[ev_i][0] <= clk["t"]:
                    events[ev_i][1]()
                    ev_i += 1
        while arr_i < len(queries) and arrivals_s[arr_i] <= clk["t"]:
            controller.dispatch(queries[arr_i])
            arr_i += 1
        controller.step()
        steps += 1
        now = controller.modeled_time_s()
        clk["t"] += max(now - last_modeled, 1e-7)
        last_modeled = now
        if trace_every and steps % trace_every == 0:
            traj.append(controller.sample(clk["t"]))
    traj.append(controller.sample(clk["t"]))
    return traj
