"""Fleet subsystem: device-resident router state + sharded engine pools.

Two halves (docs/FLEET.md):

* residency — ``route_batch`` keeps all learning state (bandit
  sufficient statistics, k-means centroids, CTS posterior draws) on
  device across batches; ``TransferLedger`` (re-exported from
  ``repro.core.residency``) is the audit trail proving zero per-call
  host↔device state transfer;
* scale-out — ``plan_fleet`` partitions devices into shards, each a full
  pool replica behind its own ``PoolServer``; ``FleetController``
  load-balances, all-reduces feedback statistics (exact — they are
  additive), and fails shards over without losing requests.
"""
from repro.core.residency import TransferLedger
from repro.fleet.plan import (FleetPlan, ShardSpec, base_model_name,
                              plan_fleet)
from repro.fleet.sync import FeedbackAllReduce
from repro.fleet.controller import (FleetController, FleetShard,
                                    build_fleet, drive_fleet)

__all__ = ["TransferLedger", "FleetPlan", "ShardSpec", "base_model_name",
           "plan_fleet", "FeedbackAllReduce", "FleetController",
           "FleetShard", "build_fleet", "drive_fleet"]
