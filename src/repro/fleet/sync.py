"""Periodic all-reduce of router feedback sufficient statistics.

Each shard's router replica learns from the queries *it* served.  Because
every statistic LinUCB/CTS needs is an additive sum over feedback events
— ``A_m = λI + Σ x xᵀ``, ``b_m = Σ r x``, pull counts, reward sums, and
the λ-decomposed accuracy/energy sums the router keeps for
``set_lambda`` — merging replicas is *exact*: sum each replica's delta
since the last sync into a global per-base-model accumulator, then write
the global totals back.  After a sync every replica holds the posterior
a single router would have learned from the union of all feedback (up to
float addition order; tests/test_fleet.py::test_allreduce_exact_merge).

Delta bookkeeping (the classic all-reduce-with-residual pattern): for
every (shard, arm) we snapshot the prior-free stats at each sync; the
next sync contributes only ``current − snapshot``, so nothing is ever
double-counted no matter how many replicas hold the same base model.

Merging is keyed by *base* model name (``plan.base_model_name``): arms a
survivor adopts during fail-over (``m@shard1``) pool their feedback into
base ``m``'s global stats, but the write-back only overwrites the
original (un-suffixed) arms — adopted arms keep their own posterior so
their scores don't tie bit-for-bit with the original replica's arm
(argmax would then starve one of the two engines).

K-means centroids are deliberately *not* merged: the assignment step
makes them order-dependent (non-additive), so each replica keeps its own
clustering; the bandit all-reduce is what makes decisions converge.

Not synced across shards: ε/t/PRNG key (per-replica exploration state)
and λ, which the controller keeps fleet-uniform via ``set_lambda``.
"""
from __future__ import annotations

from typing import Dict, List, Mapping

import numpy as np

from repro.core.router import GreenServRouter
from repro.fleet.plan import base_model_name

# additive, prior-free per-arm statistics carried through the all-reduce:
# xxt = A − λI, plus the router's λ-decomposed reward splits
VECTOR_STATS = ("b", "b_acc", "b_cost")
SCALAR_STATS = ("counts", "reward_sum", "acc_sum", "cost_sum")
MATRIX_STATS = ("xxt",)
ALL_STATS = MATRIX_STATS + VECTOR_STATS + SCALAR_STATS

_SNAP_SEP = "|"


class FeedbackAllReduce:
    """Exact periodic merge of per-shard bandit sufficient statistics."""

    def __init__(self, lambda_reg: float, context_dim: int):
        self.lambda_reg = float(lambda_reg)
        self.dim = int(context_dim)
        # base model name -> stats accumulated over every shard's deltas
        self._global: Dict[str, Dict[str, np.ndarray]] = {}
        # "shard|member" -> stats at the last sync (delta baseline)
        self._snap: Dict[str, Dict[str, np.ndarray]] = {}
        self.syncs = 0

    # -- stats algebra -------------------------------------------------
    def _zeros(self) -> Dict[str, np.ndarray]:
        d = self.dim
        out = {"xxt": np.zeros((d, d), np.float64)}
        for k in VECTOR_STATS:
            out[k] = np.zeros(d, np.float64)
        for k in SCALAR_STATS:
            out[k] = np.zeros((), np.float64)
        return out

    @staticmethod
    def _copy(stats: Mapping[str, np.ndarray]) -> Dict[str, np.ndarray]:
        return {k: np.array(v, np.float64) for k, v in stats.items()}

    @staticmethod
    def _add_delta(acc: Dict[str, np.ndarray],
                   new: Mapping[str, np.ndarray],
                   old: Mapping[str, np.ndarray]) -> None:
        for k in ALL_STATS:
            acc[k] = acc[k] + (new[k] - old[k])

    # -- per-router extraction / write-back ----------------------------
    def _extract(self, router: GreenServRouter):
        """(bandit state dict, per-arm prior-free stats) — one d2h sync."""
        sd = router.policy.state_dict()
        eye = self.lambda_reg * np.eye(self.dim, dtype=np.float64)
        arms = []
        for i, _ in enumerate(router.pool.names):
            arms.append({
                "xxt": np.asarray(sd["A"][i], np.float64) - eye,
                "b": np.asarray(sd["b"][i], np.float64),
                "b_acc": np.array(router._b_acc[i], np.float64),
                "b_cost": np.array(router._b_cost[i], np.float64),
                "counts": np.float64(sd["counts"][i]),
                "reward_sum": np.float64(sd["reward_sum"][i]),
                "acc_sum": np.float64(router._acc_sum[i]),
                "cost_sum": np.float64(router._cost_sum[i]),
            })
        return sd, arms

    def sync(self, routers: Mapping[str, GreenServRouter]) -> dict:
        """All-reduce: fold every replica's delta into the global stats,
        then write global totals back into each replica's original arms.
        Returns a small report for telemetry/benchmarks."""
        extracts = {s: self._extract(r) for s, r in routers.items()}
        # reduce: deltas since last sync, summed per base model
        for shard, (_, arms) in extracts.items():
            names = routers[shard].pool.names
            for i, member in enumerate(names):
                base = base_model_name(member)
                snap = self._snap.get(shard + _SNAP_SEP + member)
                old = snap if snap is not None else self._zeros()
                g = self._global.setdefault(base, self._zeros())
                self._add_delta(g, arms[i], old)
        # broadcast: rebuild each original arm from the global totals
        arms_updated = 0
        eye = self.lambda_reg * np.eye(self.dim, dtype=np.float64)
        for shard, router in routers.items():
            sd, arms = extracts[shard]
            new = {k: np.array(sd[k]) for k in
                   ("A", "A_inv", "b", "theta", "counts", "reward_sum")}
            for i, member in enumerate(router.pool.names):
                base = base_model_name(member)
                g = self._global[base]
                if member == base:
                    a_full = eye + g["xxt"]
                    a_inv = np.linalg.inv(a_full)
                    new["A"][i] = a_full
                    new["A_inv"][i] = a_inv
                    new["b"][i] = g["b"]
                    new["theta"][i] = a_inv @ g["b"]
                    new["counts"][i] = g["counts"]
                    new["reward_sum"][i] = g["reward_sum"]
                    router._b_acc[i] = g["b_acc"]
                    router._b_cost[i] = g["b_cost"]
                    router._acc_sum[i] = g["acc_sum"]
                    router._cost_sum[i] = g["cost_sum"]
                    self._snap[shard + _SNAP_SEP + member] = self._copy(g)
                    arms_updated += 1
                else:
                    # adopted arm: contributes deltas, keeps its own
                    # posterior (see module docstring)
                    self._snap[shard + _SNAP_SEP + member] = \
                        self._copy(arms[i])
            sd.update(new)
            router.policy.load_state_dict(sd)
        self.syncs += 1
        return {"syncs": self.syncs, "bases": len(self._global),
                "arms_updated": arms_updated}

    # -- checkpointing --------------------------------------------------
    def state_dict(self) -> dict:
        return {"global": {b: self._copy(s)
                           for b, s in sorted(self._global.items())},
                "snap": {k: self._copy(s)
                         for k, s in sorted(self._snap.items())},
                "syncs": np.int64(self.syncs)}

    def load_state_dict(self, d: Mapping) -> None:
        self._global = {b: self._copy(s) for b, s in d["global"].items()}
        self._snap = {k: self._copy(s) for k, s in d["snap"].items()}
        self.syncs = int(d["syncs"])
