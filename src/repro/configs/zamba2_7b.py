"""zamba2-7b [hybrid] — 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64.  Mamba2 backbone with a *shared* full-attention
block applied every ``attn_every`` layers (same weights at every site, per
the Zamba2 design).  SSM state carries long context → long_500k runs.
[arXiv:2411.15242; unverified]
"""
from repro.models.config import ModelConfig

ARCH_ID = "zamba2-7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    layout="mamba_hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    attn_every=6,                # shared attention block every 6 mamba layers
    attn_pattern="full",
    rope_theta=10000.0,
    max_seq_len=1_048_576,
)
