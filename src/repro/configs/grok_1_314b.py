"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  Pure full attention → long_500k skipped.
Adam moments are stored in bf16 (beyond-paper memory trick recorded in
EXPERIMENTS §Perf) — at 314B params fp32 moments alone would blow the
16 GB/chip HBM budget on 256 chips.
[hf:xai-org/grok-1; unverified]
"""
from repro.models.config import ModelConfig

ARCH_ID = "grok-1-314b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    layout="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    top_k=2,
    moe_d_ff=32768,
    n_shared_experts=0,
    capacity_factor=1.25,
    attn_pattern="full",
    rope_theta=10000.0,
    max_seq_len=8192,
    # 314B on 16 GB/chip: fp32 params + fp32 moments + fp32 grads alone are
    # 3.8 TB ≈ the whole pod's HBM.  bf16 moments + bf16 grad accumulation +
    # sequence-parallel activations bring the peak under budget (DESIGN §5).
    moment_dtype="bfloat16",
    grad_accum_dtype="bfloat16",
    seq_shard_train=True,
)
# REFUTED (§Perf log): attn_shard="sequence" for grok was hypothesized to cut
# prefill transients 16×; measured: peak unchanged, compiled flops ×3.9
# (gathered-KV attention recomputes every head on every shard).  Reverted.
