"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144.  5:1 local:global (window=1024), 128k.  head_dim=256 per the
real gemma-3-12b (16 heads × 256 = 4096 ≠ d_model).  long_500k runs.
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.models.config import ModelConfig

ARCH_ID = "gemma3-12b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    layout="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    attn_pattern="local_global",
    window=1024,
    local_per_global=5,
    rope_theta=1_000_000.0,
    max_seq_len=131072,
    tie_embeddings=True,
)
