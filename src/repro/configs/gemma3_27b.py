"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144.  5:1 local:global attention (window=1024 local layers), 128k
context.  Local:global makes the stack effectively sub-quadratic →
long_500k runs.  head_dim=128 per the real gemma-3 family (q_dim ≠ d_model).
[hf:google/gemma-3-1b-pt; unverified]
"""
from repro.models.config import ModelConfig

ARCH_ID = "gemma3-27b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    layout="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    attn_pattern="local_global",
    window=1024,
    local_per_global=5,
    rope_theta=1_000_000.0,
    max_seq_len=131072,
    tie_embeddings=True,
)
