"""whisper-medium [audio] — enc-dec, 24L decoder (+24L encoder) d_model=1024
16H (kv=16, MHA) d_ff=4096 vocab=51865.  The conv mel frontend is a STUB:
``input_specs`` provides precomputed frame embeddings (B, 1500, d) and the
transformer backbone (encoder + causal decoder with cross-attention) is what
the cells exercise.  Pure full attention → long_500k skipped.
[arXiv:2212.04356; unverified]
"""
from repro.models.config import ModelConfig

ARCH_ID = "whisper-medium"

CONFIG = ModelConfig(
    name=ARCH_ID,
    layout="encdec",
    n_layers=24,
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    attn_pattern="full",
    frontend="audio",
    n_frontend_tokens=1500,      # 30 s of mel frames after conv stride 2
    rope_theta=10000.0,
    max_seq_len=65536,           # backbone-only cells exceed whisper's 448
    tie_embeddings=True,
)
