"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000.  Yi-34B-style language backbone; the anyres vision tiling is a
STUB — ``input_specs`` provides precomputed patch embeddings (B, 576, d) for
one base tile, which occupy part of the sequence budget.  Pure full attention
→ long_500k skipped.  56 heads don't divide a 16-wide model axis → query
heads are padded to 64 (standard TPU grid alignment; ~14% extra attention
compute, recorded in EXPERIMENTS §Roofline) so weights/activations shard
instead of replicating ~16 GB/chip.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
from repro.models.config import ModelConfig

ARCH_ID = "llava-next-34b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    layout="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    attn_pattern="full",
    frontend="vision",
    n_frontend_tokens=576,       # one anyres base tile of CLIP patches
    pad_heads_to=64,             # 56 → 64: shard cleanly on 16-wide TP axis
    rope_theta=5_000_000.0,
    max_seq_len=131072,
)
