"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60 routed experts top-4 + 4 shared experts.  Pure full
attention → long_500k skipped.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.models.config import ModelConfig

ARCH_ID = "qwen2-moe-a2.7b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    layout="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    n_experts=60,
    top_k=4,
    moe_d_ff=1408,
    n_shared_experts=4,
    capacity_factor=1.25,
    attn_pattern="full",
    rope_theta=1_000_000.0,
    max_seq_len=32768,
)
