"""granite-3-8b [dense] — 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155.  Pure full attention → long_500k is skipped (DESIGN §3).
[hf:ibm-granite/granite-3.0-2b-base; hf]
"""
from repro.models.config import ModelConfig

ARCH_ID = "granite-3-8b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    layout="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    attn_pattern="full",
    rope_theta=10000.0,
    max_seq_len=131072,
)
