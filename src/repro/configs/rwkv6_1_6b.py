"""rwkv6-1.6b [ssm] — "Finch": 24L d_model=2048 (attention-free)
d_ff=7168 vocab=65536.  Data-dependent per-channel decay linear attention;
O(1) decode state → long_500k runs.  Heads are d_model/64 = 32.
[arXiv:2404.05892; unverified]
"""
from repro.models.config import ModelConfig

ARCH_ID = "rwkv6-1.6b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    layout="rwkv",
    n_layers=24,
    d_model=2048,
    n_heads=32,                  # d_model / RWKV_HEAD_DIM(64)
    n_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    attn_pattern="full",         # unused by the rwkv layout
    max_seq_len=1_048_576,
    # §Perf iteration 4: rwkv6 train is collective-bound on backward dx
    # all-reduces of its 9 column-parallel projections per layer; sequence-
    # sharding the residual stream turns them into reduce-scatters.
    seq_shard_train=True,
)
