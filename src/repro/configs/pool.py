"""Model pools: the paper's 16-model pool and the assigned-architecture pool.

The paper's pool (Table 2) is reproduced as profiles with parameter counts
and analytic latency estimates; per-(model, task) accuracy/energy behaviour
lives in ``repro.data.profiles`` (calibrated to the paper's aggregates).

The assigned-arch pool makes the 10 graded architectures first-class
GreenServ pool members — each backed by a real ModelConfig, so the router's
energy signal can come straight from the analytic TPU cost model.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.energy import CostModelParams
from repro.core.pool import ModelPool
from repro.core.types import ModelProfile
from repro.models.config import ModelConfig

# (name, family, params_b) — paper Table 2, ordered as the paper lists them.
PAPER_POOL = [
    ("qwen2.5-0.5b", "qwen", 0.5),
    ("qwen2.5-1.5b", "qwen", 1.5),
    ("qwen2.5-3b", "qwen", 3.0),
    ("qwen2.5-7b", "qwen", 7.0),
    ("qwen2.5-14b", "qwen", 14.0),
    ("mistral-7b", "mistral", 7.0),
    ("gemma-3-1b", "gemma", 1.0),
    ("gemma-3-4b", "gemma", 4.0),
    ("gemma-3-12b", "gemma", 12.0),
    ("gemma-3-27b", "gemma", 27.0),
    ("llama-3.1-1b", "llama", 1.0),
    ("llama-3.2-3b", "llama", 3.0),
    ("llama-3.1-8b", "llama", 8.0),
    ("phi-4-mini-4b", "phi", 4.0),
    ("phi-4-14b", "phi", 14.0),
    ("yi-34b", "yi", 34.0),
]

# Models the paper holds out of the initial pool for the adaptability
# experiment (§6.2.4: gemma-3-12b joins at query 1000).
ADDITION_MODEL = "gemma-3-12b"


def _latency_profile(params_b: float) -> tuple:
    """(ms_per_token, prefill_ms): weight-bandwidth-bound decode + a fixed
    dispatch floor, shaped to reproduce the ordering in paper Table 3."""
    ms_per_token = 0.9 * params_b + 0.5
    prefill_ms = 18.0 + 4.5 * params_b
    return ms_per_token, prefill_ms


def make_profile(name: str, family: str, params_b: float) -> ModelProfile:
    mpt, pre = _latency_profile(params_b)
    return ModelProfile(name=name, family=family, params_b=params_b,
                        ms_per_token=mpt, prefill_ms=pre)


def build_paper_pool(exclude: Optional[List[str]] = None) -> ModelPool:
    """The 16-arm pool of the paper's experiments (optionally holding models
    out for the §6.2.4 addition experiment)."""
    exclude = set(exclude or [])
    unknown = exclude - {row[0] for row in PAPER_POOL}
    if unknown:
        # a typo'd exclude silently running the full 16-model pool is a
        # miscalibrated experiment, not a smaller one — fail loudly
        raise ValueError(f"exclude names not in PAPER_POOL: "
                         f"{sorted(unknown)}")
    return ModelPool([make_profile(*row) for row in PAPER_POOL
                      if row[0] not in exclude])


def cost_model_params(cfg: ModelConfig) -> CostModelParams:
    return CostModelParams(
        n_params=float(cfg.param_count()),
        n_active_params=float(cfg.active_param_count()),
        d_model=cfg.d_model,
        n_layers=cfg.n_layers,
        kv_heads=max(cfg.n_kv_heads, 1),
        head_dim=cfg.head_dim,
    )


def build_assigned_pool() -> ModelPool:
    """The 10 assigned architectures as routable GreenServ pool members."""
    from repro.configs import ARCH_IDS, get_config
    profiles = []
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        params_b = cfg.param_count() / 1e9
        mpt, pre = _latency_profile(cfg.active_param_count() / 1e9)
        profiles.append(ModelProfile(
            name=arch_id, family=cfg.layout, params_b=params_b,
            arch_config=cfg, ms_per_token=mpt, prefill_ms=pre))
    return ModelPool(profiles)
