"""Assigned input-shape cells + ShapeDtypeStruct input specs for the dry-run.

Each architecture is paired with the LM shape set (40 cells total):

    train_4k      seq_len=4,096    global_batch=256   (training, train_step)
    prefill_32k   seq_len=32,768   global_batch=32    (inference prefill)
    decode_32k    seq_len=32,768   global_batch=128   (serve_step, 1 new token)
    long_500k     seq_len=524,288  global_batch=1     (long-context decode)

``decode_*`` / ``long_*`` lower ``serve_step`` — one new token with a KV/state
cache of seq_len — NOT train_step.  ``long_500k`` requires sub-quadratic
attention (ModelConfig.sub_quadratic); pure full-attention archs skip it and
the skip is recorded in DESIGN.md §Arch-applicability.

``input_specs`` returns weak-type-correct ShapeDtypeStructs only — no device
allocation happens here, which is what lets a 314B model "fit" a CPU host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import sds


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> bool:
    """long_500k only runs on sub-quadratic attention families (skip policy)."""
    if cell.name == "long_500k":
        return cfg.sub_quadratic
    return True


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """For modality-frontend archs the stub embeddings occupy part of the
    sequence budget; decoder-only text tokens fill the remainder."""
    if cfg.frontend == "vision":
        return max(seq_len - cfg.n_frontend_tokens, 1)
    return seq_len


def input_specs(cfg: ModelConfig, cell: ShapeCell,
                scale_batch: int = 1) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    ``scale_batch`` multiplies global batch (multi-pod meshes double the data
    parallelism, so the global batch doubles with it).
    """
    b = cell.global_batch * scale_batch
    s = cell.seq_len
    d = cfg.d_model

    if cell.kind == "train":
        st = text_len(cfg, s)
        specs: Dict[str, Any] = {
            "tokens": sds((b, st), jnp.int32),
            "labels": sds((b, st), jnp.int32),
        }
        if cfg.layout == "encdec" or cfg.frontend == "audio":
            specs["frames"] = sds((b, cfg.n_frontend_tokens, d), cfg.dtype)
        elif cfg.frontend == "vision":
            specs["frontend_embeddings"] = sds(
                (b, cfg.n_frontend_tokens, d), cfg.dtype)
        return specs

    if cell.kind == "prefill":
        st = text_len(cfg, s)
        specs = {"tokens": sds((b, st), jnp.int32)}
        if cfg.layout == "encdec" or cfg.frontend == "audio":
            specs["frames"] = sds((b, cfg.n_frontend_tokens, d), cfg.dtype)
        elif cfg.frontend == "vision":
            specs["frontend_embeddings"] = sds(
                (b, cfg.n_frontend_tokens, d), cfg.dtype)
        return specs

    if cell.kind == "decode":
        from repro.models import api
        return {"token": sds((b, 1), jnp.int32),
                "cache": api.cache_shapes(cfg, b, s)}

    raise ValueError(cell.kind)
