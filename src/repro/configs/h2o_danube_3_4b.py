"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000.  Llama+Mistral mix with sliding-window attention (window=4096)
→ sub-quadratic → long_500k runs.  head_dim = 3840/32 = 120 (as in the real
danube family; not 128-aligned — noted in EXPERIMENTS §Roofline).
[arXiv:2401.16818; unverified]
"""
from repro.models.config import ModelConfig

ARCH_ID = "h2o-danube-3-4b"

CONFIG = ModelConfig(
    name=ARCH_ID,
    layout="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    attn_pattern="swa",
    window=4096,
    rope_theta=10000.0,
    max_seq_len=131072,
)
