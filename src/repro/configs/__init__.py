"""Architecture registry: the 10 assigned archs + reduced smoke variants.

Usage:
    from repro.configs import get_config, ARCH_IDS, SHAPES
    cfg  = get_config("grok-1-314b")            # exact assigned dims
    tiny = get_config("grok-1-314b", smoke=True)  # reduced same-family config
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs import (gemma3_12b, gemma3_27b, granite_3_8b, grok_1_314b,
                           h2o_danube_3_4b, llava_next_34b, qwen2_moe_a2_7b,
                           rwkv6_1_6b, whisper_medium, zamba2_7b)
from repro.configs.shapes import (SHAPES, ShapeCell, cell_applicable,
                                  input_specs, text_len)
from repro.models.config import ModelConfig, scaled_down

_MODULES = [granite_3_8b, gemma3_27b, h2o_danube_3_4b, gemma3_12b,
            whisper_medium, zamba2_7b, llava_next_34b, rwkv6_1_6b,
            grok_1_314b, qwen2_moe_a2_7b]

REGISTRY: Dict[str, ModelConfig] = {m.ARCH_ID: m.CONFIG for m in _MODULES}
ARCH_IDS: List[str] = list(REGISTRY)


def get_config(arch_id: str, smoke: bool = False, **overrides) -> ModelConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    cfg = REGISTRY[arch_id]
    if smoke:
        cfg = scaled_down(cfg)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def for_mode(cfg: ModelConfig, mode: str) -> ModelConfig:
    """Serving stores weights in bf16 (no optimizer → no fp32 master needed);
    training keeps fp32 storage with FSDP sharding."""
    if mode in ("serve", "prefill", "decode"):
        return dataclasses.replace(cfg, param_dtype="bfloat16")
    return cfg


__all__ = ["REGISTRY", "ARCH_IDS", "get_config", "for_mode", "SHAPES",
           "ShapeCell", "cell_applicable", "input_specs", "text_len"]
