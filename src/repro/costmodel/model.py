"""Predictive per-(model, shape) energy cost model (pre-dispatch forecasts).

Before a query is dispatched, ``EnergyCostModel.predict`` forecasts the
Wh its completion will meter, per candidate engine:

  * an **analytic prior** composed from the roofline terms in
    ``core.energy`` over (prompt len, expected decode len, prefix reuse,
    phase, role) — it mirrors, term for term, the charging rules the
    engines' own per-query accounting uses (``ModelEngine._query_wh`` /
    ``_migrated_query_wh``), so for a real engine the only irreducible
    error is the unknown decode length;
  * an **online residual** per (engine, phase) bucket — exponentially-
    forgetting RLS (``residual.RLSResidual``) fitted from the metered
    joule ledger at completion time, which also carries engines with no
    analytic shape model at all (``SimEngine``: zero prior, the residual
    learns Wh from token counts alone);
  * a per-engine **decode-length EWMA**: the ratio of generated tokens
    to ``max_new_tokens``, so predictions use the *expected* decode
    length while residual training uses the *actual* one.

Consumers (docs/ENERGY.md): the router's per-(query, arm) energy tilt,
the cache's predicted prefix discounts, the governor's in-flight
predicted-Wh charge, and the scheduler's admission planner.  State is a
plain dict of numpy arrays — it rides ``distributed.checkpoint`` next to
the router state.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.energy import (JOULES_PER_WH, decode_step_cost,
                               energy_joules, kv_migration_cost,
                               prefill_chunk_cost, prefill_cost, roofline)
from repro.costmodel.residual import RLSResidual

PHASES = ("prefill", "decode")
FEATURE_DIM = 4      # [analytic phase Wh, 1, tokens/256, occupancy]
_TOK_SCALE = 256.0


def _phi(analytic_wh: float, tokens: float, occupancy: float) -> np.ndarray:
    return np.array([analytic_wh, 1.0, tokens / _TOK_SCALE, occupancy],
                    np.float64)


class EngineCostModel:
    """Per-engine predictor: analytic prior + per-phase RLS residuals.

    ``cost_params`` is the engine's ``CostModelParams`` (None for engines
    without a shape model — the prior is then 0 and the residual carries
    the whole prediction).  ``prefill_chunk``/``chips``/``max_len``/
    ``disaggregated`` replicate the knobs that change what the engine's
    accounting charges, so the prior stays an exact mirror.
    """

    def __init__(self, name: str, cost_params=None, prefill_chunk: int = 1,
                 chips: int = 1, max_len: Optional[int] = None,
                 disaggregated: bool = False, forget: float = 0.99,
                 out_alpha: float = 0.2):
        self.name = name
        self.cost_params = cost_params
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.chips = int(chips)
        self.max_len = max_len
        self.disaggregated = bool(disaggregated)
        self.out_alpha = float(out_alpha)
        # split phase buckets only when a shape model exists: engines
        # without one meter a single undifferentiated Wh, so their
        # observations (and predictions) live in one "decode" bucket
        self.split_phases = cost_params is not None
        self.residuals = {ph: RLSResidual(FEATURE_DIM, forget=forget,
                                          w0=[1.0, 0.0, 0.0, 0.0])
                          for ph in PHASES}
        # expected fraction of max_new_tokens actually generated (EOS cuts
        # generations short); cold start assumes the full budget is used,
        # which over-predicts decode — the safe direction for admission
        self.out_ratio = 1.0
        self.n_obs = 0

    # -- analytic prior ------------------------------------------------------

    def _slab_joules(self, n_tokens: int, kv_start: int) -> float:
        """Chunk-slab prefill joules — ``ModelEngine._prefill_joules``'s
        charging rule: slabs > 1 token cost ``prefill_chunk_cost``, single
        tokens ``decode_step_cost``, walked from ``kv_start``."""
        p = self.cost_params
        C = self.prefill_chunk
        joules, kv = 0.0, kv_start
        end = kv_start + n_tokens
        while kv < end:
            n = min(C, end - kv)
            if n > 1:
                f, b = prefill_chunk_cost(p, n, kv)
            else:
                f, b = decode_step_cost(p, max(kv + n, 1))
            joules += energy_joules(roofline(f, b, 0.0, self.chips))
            kv += n
        return joules

    def analytic_split_wh(self, n_prompt: int, n_out: int, reused: int = 0,
                          migrated: Optional[bool] = None
                          ) -> Tuple[float, float]:
        """(prefill Wh, decode Wh) the engine's accounting of record would
        charge for this shape — ``_query_wh`` for unified members,
        ``_prefill_phase_wh`` + decode + KV DMA for disaggregated ones.
        The migration DMA is booked on the prefill side, exactly where the
        decode twin's ``_migrated_query_wh`` charges it."""
        p = self.cost_params
        if p is None:
            return 0.0, 0.0
        n_prompt = int(n_prompt)
        n_out = int(n_out)
        reused = max(int(reused), 0)
        n_p = max(n_prompt, 1)
        if migrated is None:
            migrated = self.disaggregated
        if migrated and self.max_len is not None \
                and n_prompt > self.max_len - 1:
            migrated = False     # per-request unified fallback (overflow)
        if reused > 0:
            pre_j = self._slab_joules(max(n_p - reused, 1), reused)
        else:
            f, b = prefill_cost(p, n_p)
            pre_j = energy_joules(roofline(f, b, 0.0, self.chips))
        if migrated:
            f, b = kv_migration_cost(p, n_p)
            pre_j += energy_joules(roofline(f, b, 0.0, self.chips))
        mid_kv = n_prompt + max(n_out, 1) // 2
        f, b = decode_step_cost(p, mid_kv)
        dec_j = max(n_out, 0) * energy_joules(
            roofline(f, b, 0.0, self.chips))
        return pre_j / JOULES_PER_WH, dec_j / JOULES_PER_WH

    # -- prediction ----------------------------------------------------------

    def expected_out(self, max_new_tokens: int) -> int:
        return max(int(round(self.out_ratio * max(int(max_new_tokens), 1))),
                   1)

    def _features(self, n_prompt: int, n_out: int, reused: int,
                  occupancy: float) -> Dict[str, np.ndarray]:
        a_pre, a_dec = self.analytic_split_wh(n_prompt, n_out, reused)
        if self.split_phases:
            return {"prefill": _phi(a_pre, n_prompt, occupancy),
                    "decode": _phi(a_dec, n_out, occupancy)}
        # single-bucket engines: one feature row over the whole query
        return {"decode": _phi(a_pre + a_dec, n_prompt + n_out, occupancy)}

    def predict_split(self, n_prompt: int, max_new_tokens: int,
                      reused: int = 0, occupancy: float = 0.0
                      ) -> Tuple[float, float]:
        """(prefill Wh, decode Wh) forecast at the *expected* decode
        length.  Negative residual outputs clamp to 0 — energy is spent,
        never earned."""
        n_out = self.expected_out(max_new_tokens)
        feats = self._features(n_prompt, n_out, reused, occupancy)
        if self.split_phases:
            return (max(self.residuals["prefill"].predict(feats["prefill"]),
                        0.0),
                    max(self.residuals["decode"].predict(feats["decode"]),
                        0.0))
        return 0.0, max(self.residuals["decode"].predict(feats["decode"]),
                        0.0)

    def predict_wh(self, n_prompt: int, max_new_tokens: int,
                   reused: int = 0, occupancy: float = 0.0) -> float:
        pre, dec = self.predict_split(n_prompt, max_new_tokens, reused,
                                      occupancy)
        return pre + dec

    def discount_wh(self, n_prompt: int, max_new_tokens: int,
                    reused: int, occupancy: float = 0.0) -> float:
        """Predicted-suffix-minus-full: the Wh a ``reused``-token prefix
        hit is forecast to save on this engine (the decode terms cancel —
        prefix reuse avoids prefill work, never decode work)."""
        if reused <= 0:
            return 0.0
        cold = self.predict_wh(n_prompt, max_new_tokens, 0, occupancy)
        warm = self.predict_wh(n_prompt, max_new_tokens, reused, occupancy)
        return max(cold - warm, 0.0)

    # -- calibration ---------------------------------------------------------

    def observe(self, n_prompt: int, n_out: int, max_new_tokens: int,
                reused: int, migrated: bool, occupancy: float,
                measured_wh: float,
                measured_prefill_wh: Optional[float] = None) -> None:
        """Fold one completion from the metered ledger into the residuals.
        Features are built at the *actual* decode length (training must
        not inherit the expectation's error); the decode-length EWMA then
        absorbs that expectation error separately."""
        if max_new_tokens > 0:
            r = min(max(n_out / max_new_tokens, 0.0), 1.0)
            self.out_ratio += self.out_alpha * (r - self.out_ratio)
        a_pre, a_dec = self.analytic_split_wh(n_prompt, n_out, reused,
                                              migrated=migrated)
        if self.split_phases and measured_prefill_wh is not None \
                and measured_prefill_wh > 0.0:
            self.residuals["prefill"].update(
                _phi(a_pre, n_prompt, occupancy), measured_prefill_wh)
            self.residuals["decode"].update(
                _phi(a_dec, n_out, occupancy),
                max(measured_wh - measured_prefill_wh, 0.0))
        elif self.split_phases:
            # no phase split in the measurement: train the decode bucket
            # on the whole-query residual against the summed prior
            self.residuals["decode"].update(
                _phi(a_pre + a_dec, n_out, occupancy),
                max(measured_wh - a_pre, 0.0))
        else:
            self.residuals["decode"].update(
                _phi(a_pre + a_dec, n_prompt + n_out, occupancy),
                measured_wh)
        self.n_obs += 1

    # -- persistence ---------------------------------------------------------

    def state_dict(self) -> dict:
        return {"out_ratio": np.float64(self.out_ratio),
                "n_obs": np.int64(self.n_obs),
                "disaggregated": np.bool_(self.disaggregated),
                "residuals": {ph: r.state_dict()
                              for ph, r in self.residuals.items()}}

    def load_state_dict(self, d: dict) -> None:
        self.out_ratio = float(d["out_ratio"])
        self.n_obs = int(d["n_obs"])
        self.disaggregated = bool(d["disaggregated"])
        for ph, rd in d.get("residuals", {}).items():
            if ph in self.residuals:
                self.residuals[ph].load_state_dict(rd)


class EnergyCostModel:
    """The pool-level facade the scheduler/router/governor talk to.

    Owns one ``EngineCostModel`` per pool member (registered through the
    scheduler's ``_configure_engine`` choke point, so late joiners and
    decode twins are covered), the uid-keyed map of outstanding
    predictions (admission → completion reconciliation), and a bounded
    history of (engine, predicted, measured) pairs for benches.
    """

    def __init__(self, forget: float = 0.99, out_alpha: float = 0.2,
                 history: int = 4096):
        self.forget = float(forget)
        self.out_alpha = float(out_alpha)
        self.engines: Dict[str, EngineCostModel] = {}
        # uid → admission note (engine, predicted Wh, shape, occupancy);
        # popped at completion (reconcile) or cancellation (forget)
        self._pending: Dict[int, dict] = {}
        self.history: Deque[dict] = collections.deque(maxlen=history)
        self.n_predicted = 0
        self.n_reconciled = 0
        self.abs_err_wh = 0.0
        self.measured_wh_sum = 0.0

    # -- pool membership -----------------------------------------------------

    def register_engine(self, name: str, engine=None) -> EngineCostModel:
        """Idempotent: first registration snapshots the engine's analytic
        shape knobs (``cost_params``, ``prefill_chunk``, chips, slot
        depth); re-registration refreshes the mutable ones (the scheduler
        may set ``prefill_chunk`` after construction)."""
        m = self.engines.get(name)
        if m is None:
            energy = getattr(engine, "energy", None)
            m = EngineCostModel(
                name,
                cost_params=getattr(engine, "cost_params", None),
                prefill_chunk=getattr(engine, "prefill_chunk", 1),
                chips=getattr(energy, "chips", 1),
                max_len=getattr(engine, "max_len", None),
                forget=self.forget, out_alpha=self.out_alpha)
            self.engines[name] = m
        elif engine is not None:
            m.prefill_chunk = max(int(getattr(engine, "prefill_chunk",
                                              m.prefill_chunk)), 1)
        return m

    def set_disaggregated(self, name: str, disaggregated: bool) -> None:
        """Mark a member as serving through a prefill/decode pair — its
        prior then includes the phase-boundary KV DMA."""
        self.register_engine(name).disaggregated = bool(disaggregated)

    # -- forecasts -----------------------------------------------------------

    def predict_wh(self, name: str, n_prompt: int, max_new_tokens: int,
                   reused: int = 0, occupancy: float = 0.0) -> float:
        m = self.engines.get(name)
        if m is None:
            return 0.0
        return m.predict_wh(n_prompt, max_new_tokens, reused, occupancy)

    def predict_matrix(self, names: Sequence[str],
                       token_lens: Sequence[int],
                       max_new: Sequence[int],
                       occupancy: Optional[Dict[str, float]] = None
                       ) -> np.ndarray:
        """(Q, M) cold (reused=0) predicted Wh per (query, arm) — the
        router's energy tilt input.  Prefix discounts are fed separately
        (``discount_wh``), so reuse is never double-counted."""
        occupancy = occupancy or {}
        out = np.zeros((len(token_lens), len(names)), np.float64)
        for j, name in enumerate(names):
            m = self.engines.get(name)
            if m is None:
                continue
            occ = float(occupancy.get(name, 0.0))
            for i, (n_p, mx) in enumerate(zip(token_lens, max_new)):
                out[i, j] = m.predict_wh(n_p, mx, 0, occ)
        return out

    def discount_wh(self, name: str, n_prompt: int, max_new_tokens: int,
                    reused: int, occupancy: float = 0.0) -> float:
        m = self.engines.get(name)
        if m is None:
            return 0.0
        return m.discount_wh(n_prompt, max_new_tokens, reused, occupancy)

    # -- admission / reconciliation ------------------------------------------

    def note_admission(self, uid: int, name: str, predicted_wh: float,
                       n_prompt: int, max_new_tokens: int, reused: int = 0,
                       occupancy: float = 0.0) -> None:
        self._pending[uid] = {
            "engine": name, "predicted_wh": float(predicted_wh),
            "n_prompt": int(n_prompt), "max_new": int(max_new_tokens),
            "reused": int(reused), "occupancy": float(occupancy)}
        self.n_predicted += 1

    def forget_query(self, uid: int) -> None:
        """Drop a prediction whose query will never complete (cancelled
        before any engine work) — no residual update, no error sample."""
        self._pending.pop(uid, None)

    def observe_response(self, resp, accuracy: float = 0.0
                         ) -> Optional[float]:
        """Reconcile a completion against its admission-time prediction
        and train the winning engine's residuals from the metered Wh.
        Returns the predicted Wh (None if this uid was never predicted).
        Hedge winners may complete on a different engine than predicted —
        the measurement trains the engine that actually served."""
        note = self._pending.pop(resp.uid, None)
        m = self.engines.get(resp.model_name)
        occupancy = note["occupancy"] if note is not None else 0.0
        max_new = (note["max_new"] if note is not None
                   else max(resp.output_tokens, 1))
        if m is not None:
            m.observe(
                n_prompt=resp.input_tokens, n_out=resp.output_tokens,
                max_new_tokens=max_new, reused=resp.prefix_reused,
                migrated=resp.kv_migrated > 0, occupancy=occupancy,
                measured_wh=resp.energy_wh,
                measured_prefill_wh=getattr(resp, "prefill_wh", 0.0))
        if note is None:
            return None
        self.n_reconciled += 1
        self.abs_err_wh += abs(resp.energy_wh - note["predicted_wh"])
        self.measured_wh_sum += resp.energy_wh
        self.history.append({"engine": resp.model_name,
                             "predicted_wh": note["predicted_wh"],
                             "measured_wh": resp.energy_wh})
        return note["predicted_wh"]

    # -- introspection / persistence -----------------------------------------

    @property
    def inflight_predicted(self) -> int:
        return len(self._pending)

    def mae_ratio(self) -> float:
        """Mean absolute prediction error as a fraction of metered Wh
        (the bench_energy_model acceptance metric)."""
        return self.abs_err_wh / max(self.measured_wh_sum, 1e-12)

    def mae_ratio_by_engine(self) -> Dict[str, float]:
        err: Dict[str, List[float]] = {}
        for h in self.history:
            err.setdefault(h["engine"], [0.0, 0.0])
            err[h["engine"]][0] += abs(h["measured_wh"] - h["predicted_wh"])
            err[h["engine"]][1] += h["measured_wh"]
        return {k: v[0] / max(v[1], 1e-12) for k, v in err.items()}

    def stats(self) -> dict:
        return {"n_predicted": self.n_predicted,
                "n_reconciled": self.n_reconciled,
                "inflight_predicted": self.inflight_predicted,
                "mae_ratio": self.mae_ratio(),
                "engines": {n: {"n_obs": m.n_obs,
                                "out_ratio": m.out_ratio,
                                "split_phases": m.split_phases,
                                "disaggregated": m.disaggregated}
                            for n, m in self.engines.items()}}

    def state_dict(self) -> dict:
        """Plain dict of numpy leaves — rides ``distributed.checkpoint``
        next to the router state."""
        return {"engines": {n: m.state_dict()
                            for n, m in self.engines.items()}}

    def load_state_dict(self, d: dict) -> None:
        """Learned state only: analytic knobs (cost_params, chunk, chips)
        always come from the live engines at registration, so a restored
        checkpoint can never carry stale shape constants."""
        for name, md in d.get("engines", {}).items():
            self.register_engine(name).load_state_dict(md)
