"""Recursive-least-squares residual calibrator for the energy cost model.

The analytic roofline prior (core.energy) predicts most of a query's
joules from shape alone; what it cannot know — constant per-query
overheads, occupancy interference, any drift between the modeled and the
metered ledger — is absorbed by a small linear residual fitted online
with exponentially-forgetting recursive least squares, one instance per
(engine, phase) bucket.

The feature vector leads with the analytic prediction itself and the
weight vector initializes to [1, 0, …, 0], so a cold (never-updated)
residual predicts exactly the analytic prior.  Each update is O(d²)
with d = 4 — microseconds per completion, nothing on the serving path
waits for it.
"""
from __future__ import annotations

import numpy as np

# diagonal clamp on the covariance: with forgetting < 1 and a stretch of
# poorly-exciting observations P grows without bound (covariance windup)
# and the next informative sample would swing the weights violently
_P_MAX = 1e6


class RLSResidual:
    """Exponentially-forgetting RLS over a fixed feature vector.

    predict(phi) = w·phi;  update(phi, y) performs the standard gain step

        k = P·phi / (forget + phiᵀ·P·phi)
        w += k · (y − w·phi)
        P  = (P − k·phiᵀ·P) / forget

    ``w0`` sets the cold-start prediction (the analytic-prior passthrough
    [1, 0, …, 0] here); ``p0`` the initial covariance scale (uncertainty
    of that prior).
    """

    def __init__(self, dim: int, forget: float = 0.99, p0: float = 1.0,
                 w0=None):
        if not (0.0 < forget <= 1.0):
            raise ValueError(f"forget must be in (0, 1], got {forget}")
        self.dim = int(dim)
        self.forget = float(forget)
        if w0 is None:
            self.w = np.zeros(self.dim, np.float64)
        else:
            self.w = np.asarray(w0, np.float64).copy()
            if self.w.shape != (self.dim,):
                raise ValueError(f"w0 shape {self.w.shape} != ({self.dim},)")
        self.P = np.eye(self.dim, dtype=np.float64) * float(p0)
        self.n_obs = 0

    def predict(self, phi) -> float:
        return float(np.dot(self.w, np.asarray(phi, np.float64)))

    def update(self, phi, y: float) -> float:
        """One RLS step toward target ``y``; returns the a-priori error."""
        phi = np.asarray(phi, np.float64)
        p_phi = self.P @ phi
        denom = self.forget + float(phi @ p_phi)
        k = p_phi / denom
        err = float(y) - float(self.w @ phi)
        self.w = self.w + k * err
        self.P = (self.P - np.outer(k, p_phi)) / self.forget
        # windup guard: bound the covariance so a long uninformative
        # stretch cannot make the next sample rewrite the weights
        diag = np.einsum("ii->i", self.P)
        np.clip(diag, None, _P_MAX, out=diag)
        self.n_obs += 1
        return err

    def state_dict(self) -> dict:
        return {"w": self.w.copy(), "P": self.P.copy(),
                "n_obs": self.n_obs, "forget": self.forget}

    def load_state_dict(self, d: dict) -> None:
        self.w = np.asarray(d["w"], np.float64).copy()
        self.P = np.asarray(d["P"], np.float64).copy()
        self.n_obs = int(d.get("n_obs", 0))
        self.forget = float(d.get("forget", self.forget))
