"""Predictive energy cost model: pre-dispatch joule forecasts
(docs/ENERGY.md).

``EnergyCostModel`` forecasts the Wh a query will meter on each candidate
engine *before* dispatch — an analytic roofline prior (mirroring the
engines' own charging rules in ``core.energy`` / ``serving.engine``) plus
an online RLS residual calibrated from the metered ledger.  Consumers:
the router's per-(query, arm) energy tilt, the cache's predicted prefix
discounts, the governor's in-flight predicted-Wh charge, and the
scheduler's energy-aware admission planner.
"""
from repro.costmodel.model import (FEATURE_DIM, PHASES, EngineCostModel,
                                   EnergyCostModel)
from repro.costmodel.residual import RLSResidual

__all__ = ["EnergyCostModel", "EngineCostModel", "RLSResidual",
           "FEATURE_DIM", "PHASES"]
