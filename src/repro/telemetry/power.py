"""Power traces: per-step energy deltas → watts time-series.

``EnergyMonitor`` (core.energy) accumulates joules; this module turns those
cumulative counters into the time-resolved signal the paper's GPU setup
gets from zeus/NVML for free: instantaneous watts per engine and pool-wide,
with running average/peak and total Wh derivable from the same samples.

The trace is sampled once per scheduler step with a caller-supplied clock
(wall time in live serving, virtual time in simulation) so power numbers
stay meaningful in both regimes.  Units throughout: timestamps in seconds,
rates in watts, cumulative counters in joules (divide by 3600 for Wh).

Besides per-engine series and the pool aggregate, two reserved phase
series (``PHASE_PREFILL`` / ``PHASE_DECODE``) split the pool's burn by
serving phase when engines report phase-tagged joules, and a reserved
``AVOIDED`` series tracks the GreenCache counterfactual — joules the pool
*would* have burned without prefix/semantic reuse ("avoided watts" reads
like any other source).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Iterable, List, NamedTuple, Optional, Tuple

from repro.core.energy import JOULES_PER_WH

POOL = "__pool__"           # reserved source name for the pool-wide series
PHASE_PREFILL = "__prefill__"   # pool-wide prefill-phase joules series
PHASE_DECODE = "__decode__"     # pool-wide decode-phase joules series
AVOIDED = "__avoided__"         # pool-wide GreenCache avoided-joules series
_RESERVED = (POOL, PHASE_PREFILL, PHASE_DECODE, AVOIDED)
PHASE_SOURCES = {"prefill": PHASE_PREFILL, "decode": PHASE_DECODE}


class PowerSample(NamedTuple):
    t_s: float                  # sample timestamp (clock supplied by caller)
    watts: float                # mean power over (prev sample, this sample]
    joules_cum: float           # cumulative joules at sample time


class PowerTrace:
    """Ring-buffered watts series per source plus pool-wide aggregate.

    ``sample(name, t_s, joules_cum)`` differentiates the cumulative joule
    counter against the previous sample.  Zero-dt samples fold into the
    next interval instead of dividing by zero.
    """

    def __init__(self, maxlen: int = 4096):
        self.maxlen = maxlen
        self._series: Dict[str, Deque[PowerSample]] = {}
        self._last: Dict[str, Tuple[float, float]] = {}   # name -> (t, J)
        self._peak: Dict[str, float] = {}
        self._joules: Dict[str, float] = {}
        self._t0: Dict[str, float] = {}
        self._t_last: Dict[str, float] = {}

    def sample(self, name: str, t_s: float, joules_cum: float) -> None:
        last = self._last.get(name)
        if last is None:
            # first observation anchors the series, no rate yet
            self._last[name] = (t_s, joules_cum)
            self._t0[name] = t_s
            self._t_last[name] = t_s
            self._series[name] = deque(maxlen=self.maxlen)
            self._peak[name] = 0.0
            self._joules[name] = 0.0
            return
        t_prev, j_prev = last
        dt = t_s - t_prev
        dj = joules_cum - j_prev
        if dt <= 0.0:
            # clock did not advance; accumulate into the next interval
            self._last[name] = (t_prev, j_prev)
            return
        watts = max(dj, 0.0) / dt
        self._series[name].append(PowerSample(t_s, watts, joules_cum))
        self._last[name] = (t_s, joules_cum)
        self._t_last[name] = t_s
        self._joules[name] += max(dj, 0.0)
        if watts > self._peak[name]:
            self._peak[name] = watts

    def sample_all(self, t_s: float, joules_by_source: Dict[str, float],
                   phase_joules: Optional[Dict[str, float]] = None) -> None:
        """One scheduler-step sample: every engine plus the pool total.

        ``phase_joules`` optionally carries pool-wide cumulative joules per
        serving phase ({"prefill": J, "decode": J}); each phase becomes its
        own reserved series (``PHASE_PREFILL`` / ``PHASE_DECODE``) so watts
        can be read per phase — prefill is compute-bound and decode
        bandwidth-bound, and lumping them hides which roofline is burning
        the budget."""
        for name, j in joules_by_source.items():
            self.sample(name, t_s, j)
        self.sample(POOL, t_s, sum(joules_by_source.values()))
        for phase, j in (phase_joules or {}).items():
            src = PHASE_SOURCES.get(phase)
            if src is not None:
                self.sample(src, t_s, j)

    # -- readers ------------------------------------------------------------

    @property
    def sources(self) -> List[str]:
        """Engine source names (reserved pool/phase aggregates excluded)."""
        return [n for n in self._series if n not in _RESERVED]

    def series(self, name: str = POOL) -> List[PowerSample]:
        return list(self._series.get(name, ()))

    def last_watts(self, name: str = POOL) -> float:
        s = self._series.get(name)
        return s[-1].watts if s else 0.0

    def peak_watts(self, name: str = POOL) -> float:
        return self._peak.get(name, 0.0)

    def avg_watts(self, name: str = POOL) -> float:
        dur = self._t_last.get(name, 0.0) - self._t0.get(name, 0.0)
        if dur <= 0.0:
            return 0.0
        return self._joules.get(name, 0.0) / dur

    def total_wh(self, name: str = POOL) -> float:
        return self._joules.get(name, 0.0) / JOULES_PER_WH

    def to_rows(self) -> Iterable[dict]:
        """Flat dict rows (for JSONL export)."""
        for name in sorted(self._series):
            for s in self._series[name]:
                yield {"source": name, "t_s": s.t_s, "watts": s.watts,
                       "joules_cum": s.joules_cum}
