"""Lightweight metrics primitives: counters, gauges, streaming histograms.

Designed for the serving hot path — every ``record`` is O(1):

  * ``Counter`` / ``Gauge``   — a float plus bookkeeping, nothing else;
  * ``Histogram``             — count/sum/min/max plus P² streaming quantile
    estimators (Jain & Chlamtáč 1985) for p50/p95/p99, so latency
    percentiles never require storing or sorting samples;
  * ``MetricsRegistry``       — get-or-create by (name, labels); iteration
    order is stable for deterministic export.

Energy-per-token is a first-class serving metric (Wilhelm et al.,
arXiv:2603.20224): the registry conventions below (``greenserv_*`` names,
``model`` label) are what ``telemetry.export`` turns into Prometheus text
exposition and JSONL traces.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

LabelItems = Tuple[Tuple[str, str], ...]

DEFAULT_QUANTILES = (0.5, 0.95, 0.99)


def _label_items(labels: Dict[str, str]) -> LabelItems:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


@dataclasses.dataclass
class Counter:
    """Monotonically increasing count (events, tokens, Wh·1e3, ...)."""

    name: str
    labels: LabelItems = ()
    help: str = ""
    value: float = 0.0

    kind = "counter"

    def inc(self, v: float = 1.0) -> None:
        self.value += v


@dataclasses.dataclass
class Gauge:
    """Point-in-time value (queue depth, current λ, watts)."""

    name: str
    labels: LabelItems = ()
    help: str = ""
    value: float = 0.0
    n_sets: int = 0

    kind = "gauge"

    def set(self, v: float) -> None:
        self.value = float(v)
        self.n_sets += 1


class P2Quantile:
    """P² single-quantile estimator: O(1) update, O(1) memory (5 markers).

    Tracks the running ``q``-quantile of a stream without storing it.  The
    first five observations seed the markers exactly; afterwards marker
    heights move by the piecewise-parabolic (P²) formula.  Accuracy is a
    few percent on smooth distributions — plenty for serving dashboards.
    """

    __slots__ = ("q", "n", "heights", "positions", "_d0", "_inc")

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = q
        self.n = 0
        self.heights: List[float] = []
        self.positions = [1.0, 2.0, 3.0, 4.0, 5.0]
        # desired marker positions are affine in the post-seed count, so
        # they are computed on demand instead of stored and incremented —
        # update() runs per request on the serving hot path
        self._d0 = (1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0)
        self._inc = (0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0)

    def update(self, x: float) -> None:
        x = float(x)
        h = self.heights
        self.n += 1
        if self.n <= 5:
            h.append(x)
            h.sort()
            return
        pos = self.positions
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        elif x < h[1]:
            k = 0
        elif x < h[2]:
            k = 1
        elif x < h[3]:
            k = 2
        else:
            k = 3
        for i in range(k + 1, 5):
            pos[i] += 1.0
        # adjust interior markers toward their desired positions
        m = float(self.n - 5)
        d0, inc = self._d0, self._inc
        for i in (1, 2, 3):
            d = d0[i] + inc[i] * m - pos[i]
            if ((d >= 1.0 and pos[i + 1] - pos[i] > 1.0)
                    or (d <= -1.0 and pos[i - 1] - pos[i] < -1.0)):
                d = 1.0 if d >= 1.0 else -1.0
                cand = self._parabolic(i, d)
                if not h[i - 1] < cand < h[i + 1]:
                    cand = self._linear(i, d)
                h[i] = cand
                pos[i] += d

    def _parabolic(self, i: int, d: float) -> float:
        h, p = self.heights, self.positions
        return h[i] + d / (p[i + 1] - p[i - 1]) * (
            (p[i] - p[i - 1] + d) * (h[i + 1] - h[i]) / (p[i + 1] - p[i])
            + (p[i + 1] - p[i] - d) * (h[i] - h[i - 1]) / (p[i] - p[i - 1]))

    def _linear(self, i: int, d: float) -> float:
        h, p = self.heights, self.positions
        j = i + int(d)
        return h[i] + d * (h[j] - h[i]) / (p[j] - p[i])

    @property
    def value(self) -> float:
        if not self.heights:
            return 0.0
        if self.n < 5:
            # exact small-sample quantile over the seeded markers
            idx = min(int(self.q * len(self.heights)), len(self.heights) - 1)
            return self.heights[idx]
        return self.heights[2]


class Histogram:
    """Streaming distribution summary: count/sum/min/max + P² quantiles."""

    kind = "histogram"

    def __init__(self, name: str, labels: LabelItems = (), help: str = "",
                 quantiles: Tuple[float, ...] = DEFAULT_QUANTILES):
        self.name = name
        self.labels = labels
        self.help = help
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._estimators = {q: P2Quantile(q) for q in quantiles}
        self._est_seq = tuple(self._estimators.values())

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        for est in self._est_seq:
            est.update(v)

    def quantile(self, q: float) -> float:
        return self._estimators[q].value

    @property
    def quantiles(self) -> Dict[float, float]:
        return {q: est.value for q, est in self._estimators.items()}

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Get-or-create metric store keyed on (name, sorted label items)."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, LabelItems], object] = {}

    def _get(self, cls, name: str, labels: Optional[Dict[str, str]],
             help: str, **kwargs):
        key = (name, _label_items(labels or {}))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name=name, labels=key[1], help=help, **kwargs)
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, labels: Optional[Dict[str, str]] = None,
                help: str = "") -> Counter:
        return self._get(Counter, name, labels, help)

    def gauge(self, name: str, labels: Optional[Dict[str, str]] = None,
              help: str = "") -> Gauge:
        return self._get(Gauge, name, labels, help)

    def histogram(self, name: str, labels: Optional[Dict[str, str]] = None,
                  help: str = "",
                  quantiles: Tuple[float, ...] = DEFAULT_QUANTILES
                  ) -> Histogram:
        return self._get(Histogram, name, labels, help, quantiles=quantiles)

    def __iter__(self) -> Iterable:
        # stable order: by name then labels, for deterministic export
        for key in sorted(self._metrics):
            yield self._metrics[key]

    def __len__(self) -> int:
        return len(self._metrics)

    def find(self, name: str, labels: Optional[Dict[str, str]] = None):
        return self._metrics.get((name, _label_items(labels or {})))
