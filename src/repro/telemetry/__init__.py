"""Telemetry & adaptive energy-budget governance for the serving stack.

Turns "routing that measures energy" into "serving that governs energy":

  * ``metrics``  — O(1) counters/gauges/streaming-quantile histograms;
  * ``power``    — per-engine and pool-wide watts time-series;
  * ``budget``   — ``EnergyBudgetGovernor``: a Wh token bucket that
    modulates the router's λ online (``GreenServRouter.set_lambda``);
  * ``events``   — bounded structured event log;
  * ``export``   — Prometheus text exposition + JSONL trace round-trip;
  * ``hub``      — the ``Telemetry`` facade ``PoolServer`` reports into.
"""
from repro.telemetry.budget import (EnergyBudgetGovernor,
                                    diurnal_carbon_intensity)
from repro.telemetry.events import Event, EventLog
from repro.telemetry.export import (dump_jsonl, load_jsonl, parse_prometheus,
                                    to_prometheus)
from repro.telemetry.hub import Telemetry
from repro.telemetry.metrics import (Counter, Gauge, Histogram,
                                     MetricsRegistry, P2Quantile)
from repro.telemetry.power import PowerSample, PowerTrace

__all__ = [
    "EnergyBudgetGovernor", "diurnal_carbon_intensity",
    "Event", "EventLog",
    "dump_jsonl", "load_jsonl", "parse_prometheus", "to_prometheus",
    "Telemetry",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "P2Quantile",
    "PowerSample", "PowerTrace",
]
