"""Structured serving-event log: admissions, completions, hedges, restarts.

A bounded ring buffer of (kind, t_s, payload) records with per-kind
counters.  Events complement the aggregated metrics: the registry answers
"what is p95 latency", the event log answers "what happened around the
restart at t=41.2s".
"""
from __future__ import annotations

from collections import Counter as KindCounter
from collections import deque
from typing import Deque, Dict, Iterable, List, NamedTuple


ADMIT = "admit"
COMPLETE = "complete"
HEDGE = "hedge"
RESTART = "restart"
LAMBDA = "lambda"           # governor changed the router's λ
CACHE_HIT = "cache_hit"     # GreenCache answered/shortened a query
ENGINE_ADDED = "engine_added"   # pool grew at runtime (add_engine)
MIGRATE = "migrate"         # prompt KV handed prefill→decode engine
DEFER = "defer"             # admission planner parked arrivals (no budget
                            # headroom for their predicted Wh this tick)
ATTEMPT_FAIL = "attempt_fail"   # one dispatch died (crash/garbage/stall)
RETRY = "retry"             # failed request re-routed away from its arm
TIMEOUT = "timeout"         # deadline passed; request terminal TIMED_OUT
BREAKER = "breaker"         # circuit breaker state transition on an arm


class Event(NamedTuple):
    """One discrete serving occurrence: ``kind`` (ADMIT/COMPLETE/HEDGE/
    RESTART/LAMBDA/CACHE_HIT/ENGINE_ADDED), ``t_s`` the caller-clock
    timestamp in seconds, and a flat ``payload`` (energies in Wh,
    latencies in ms, counts unitless)."""

    kind: str
    t_s: float
    payload: Dict[str, object]


class EventLog:
    """Ring-buffered event stream (newest ``maxlen`` kept) with O(1)
    per-kind counts; timestamps in seconds from the telemetry clock."""

    def __init__(self, maxlen: int = 8192):
        self._events: Deque[Event] = deque(maxlen=maxlen)
        self.counts: KindCounter = KindCounter()

    def emit(self, kind: str, t_s: float, **payload: object) -> Event:
        ev = Event(kind, t_s, payload)
        self._events.append(ev)
        self.counts[kind] += 1
        return ev

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self):
        return iter(self._events)

    def of_kind(self, kind: str) -> List[Event]:
        return [e for e in self._events if e.kind == kind]

    def to_rows(self) -> Iterable[dict]:
        for e in self._events:
            yield {"kind": e.kind, "t_s": e.t_s, **e.payload}
