"""The ``Telemetry`` facade: one object the serving stack reports into.

``PoolServer`` calls four hooks (admission, completion, hedge/restart,
per-step) and this hub fans the data out to the metrics registry, the
power trace, the event log, and — when governed — the
``EnergyBudgetGovernor``.  All hooks are O(1) per call with pre-bound
metric handles, so telemetry stays well under the 5 % step-overhead
budget asserted by ``benchmarks/bench_telemetry.py``.

Metric conventions (exported names):

  greenserv_admitted_total / greenserv_completed_total{model=}
  greenserv_hedges_total / greenserv_restarts_total{engine=}
  greenserv_tokens_total{model=, dir=in|out}
  greenserv_energy_mwh_total{model=}
  greenserv_latency_ms{model=} · greenserv_ttft_ms · greenserv_queue_wait_ms
  greenserv_energy_per_token_mwh{model=}
  greenserv_queue_depth{engine=} · greenserv_power_watts{source=}
  greenserv_energy_joules_total{phase=prefill|decode}
  greenserv_energy_joules_avoided_total{kind=prefix|semantic}
  greenserv_cache_hits_total{kind=prefix|semantic}
  greenserv_energy_prediction_error_ratio
  greenserv_lambda · greenserv_budget_pressure

Energy is phase-split: engines report cumulative joules tagged prefill
(prompt ingestion, compute-bound) vs decode (generation, bandwidth-bound);
the hub exports phase counters + phase watts gauges, samples phase series
into the PowerTrace, and feeds the governor's phase ledger.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

from repro.telemetry import events as ev
from repro.telemetry.budget import EnergyBudgetGovernor
from repro.telemetry.events import EventLog
from repro.telemetry.metrics import Counter, Histogram, MetricsRegistry
from repro.telemetry.power import (AVOIDED, PHASE_DECODE, PHASE_PREFILL,
                                   POOL, PowerTrace)
from repro.core.energy import JOULES_PER_WH


class Telemetry:
    def __init__(self,
                 registry: Optional[MetricsRegistry] = None,
                 power: Optional[PowerTrace] = None,
                 events: Optional[EventLog] = None,
                 governor: Optional[EnergyBudgetGovernor] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self.power = power if power is not None else PowerTrace()
        self.events = events if events is not None else EventLog()
        self.governor = governor
        self.clock = clock or time.monotonic
        r = self.registry
        self._admitted = r.counter(
            "greenserv_admitted_total", help="queries admitted to the pool")
        self._hedges = r.counter(
            "greenserv_hedges_total", help="straggler hedges fired")
        self._latency = r.histogram(
            "greenserv_latency_ms", help="end-to-end request latency (ms)")
        self._ttft = r.histogram(
            "greenserv_ttft_ms", help="time to first token (ms)")
        self._queue_wait = r.histogram(
            "greenserv_queue_wait_ms", help="time queued before execution")
        self._lambda = r.gauge(
            "greenserv_lambda", help="router accuracy-energy trade-off λ")
        self._pressure = r.gauge(
            "greenserv_budget_pressure", help="governor pressure in [0,1]")
        # cost-model reconciliation: |metered − predicted| / metered Wh,
        # one sample per completion that carried a pre-dispatch forecast
        self._pred_err = r.histogram(
            "greenserv_energy_prediction_error_ratio",
            help="abs(metered-predicted)/metered Wh per completion")
        # reliability layer (docs/RELIABILITY.md): pre-bound so the
        # counters export at zero on healthy runs
        self._retries = r.counter(
            "greenserv_retries_total",
            help="failed/expired dispatches re-routed to another arm")
        self._timeouts = r.counter(
            "greenserv_timeouts_total",
            help="requests terminal TIMED_OUT (deadline passed)")
        self._req_failed = r.counter(
            "greenserv_failed_total",
            help="requests terminal FAILED (attempts exhausted)")
        self._slo_violations = r.counter(
            "greenserv_slo_violations_total",
            help="deadline misses (timeouts + late completions)")
        self._breaker_transitions = r.counter(
            "greenserv_breaker_transitions_total",
            help="circuit-breaker state changes across all arms")
        # per-model/per-engine handles, bound lazily on first use
        self._completed: Dict[str, Counter] = {}
        self._energy_per_tok: Dict[str, Histogram] = {}
        self._energy_total: Dict[str, Counter] = {}
        self._tokens: Dict[tuple, Counter] = {}
        self._restarts: Dict[str, Counter] = {}
        self._queue_gauges: Dict[str, object] = {}
        self._power_gauges: Dict[str, object] = {}
        self._pool_power_gauge = r.gauge("greenserv_power_watts",
                                         {"source": "pool"})
        # phase-split energy: cumulative joules per serving phase across
        # the pool (prefill = prompt ingestion, decode = generation)
        self._phase_energy = {
            ph: r.counter("greenserv_energy_joules_total", {"phase": ph},
                          help="pool-wide metered joules by serving phase")
            for ph in ("prefill", "decode")}
        self._phase_power_gauges = {
            "prefill": r.gauge("greenserv_power_watts",
                               {"source": "prefill"}),
            "decode": r.gauge("greenserv_power_watts", {"source": "decode"})}
        self._phase_last: Dict[str, float] = {"prefill": 0.0, "decode": 0.0}
        # GreenCache avoided energy: joules never spent thanks to
        # prefix-KV reuse (engines meter it, diffed per step like phase
        # joules) and semantic answers (scheduler reports per hit)
        self._avoided_energy = {
            kind: r.counter("greenserv_energy_joules_avoided_total",
                            {"kind": kind},
                            help="modeled joules avoided by GreenCache")
            for kind in ("prefix", "semantic")}
        self._cache_hits = {
            kind: r.counter("greenserv_cache_hits_total", {"kind": kind},
                            help="GreenCache hits by layer")
            for kind in ("prefix", "semantic")}
        self._prefix_avoided_last = 0.0
        self._prefix_hits_last = 0
        self._avoided_cum_joules = 0.0
        # disaggregated serving: KV migrations (per prefill engine) and
        # per-engine cumulative joules for the role-attribution diff
        self._migrations: Dict[str, Counter] = {}
        # per-engine reliability handles (lazy, like restarts)
        self._attempt_failures: Dict[str, Counter] = {}
        self._breaker_open: Dict[str, object] = {}
        self._role_energy = {
            role: r.counter("greenserv_energy_joules_total", {"role": role},
                            help="pool-wide metered joules by engine role")
            for role in ("unified", "prefill", "decode")}
        self._engine_joules_last: Dict[str, float] = {}

    # -- scheduler hooks ----------------------------------------------------

    def on_admit(self, n: int, queue_depth: int,
                 expected_savings_wh: float = 0.0,
                 predicted=None) -> None:
        """``predicted``: optional ``[(uid, predicted_wh), …]`` from the
        energy cost model — forwarded to the governor's predict-then-
        reconcile charge (released at completion/cancellation)."""
        t = self.clock()
        self._admitted.inc(n)
        self.events.emit(ev.ADMIT, t, n=n, queue_depth=queue_depth)
        if self.governor is not None:
            self.governor.on_admission(
                n, t, expected_savings_wh=expected_savings_wh,
                predicted=predicted)

    def on_admission_deferred(self, n: int, predicted_wh: float,
                              headroom_wh: float) -> None:
        """The admission planner parked ``n`` arrivals whose predicted Wh
        would breach the governor's remaining budget this tick."""
        self.events.emit(ev.DEFER, self.clock(), n=n,
                         predicted_wh=predicted_wh,
                         headroom_wh=headroom_wh)

    def on_cancelled(self, uid: int) -> None:
        """A predicted query will never complete (cancelled before any
        engine work); release its in-flight predicted charge."""
        if self.governor is not None:
            self.governor.on_cancel(uid, self.clock())

    def on_cache_hit(self, kind: str, avoided_wh: float,
                     model: str = "") -> None:
        """A GreenCache layer short-circuited work: ``kind="semantic"``
        (whole query answered from cache — the scheduler calls this per
        hit) with the original completion's Wh as the avoided energy.
        Prefix-KV hits flow through the engines' avoided-joule meters and
        are diffed in ``on_step`` instead — call this only for hits no
        engine meters."""
        t = self.clock()
        self._cache_hits[kind].inc()
        joules = max(avoided_wh, 0.0) * JOULES_PER_WH
        self._avoided_energy[kind].inc(joules)
        self._avoided_cum_joules += joules
        self.events.emit(ev.CACHE_HIT, t, layer=kind,
                         avoided_wh=avoided_wh, model=model)
        if self.governor is not None:
            self.governor.on_avoided_energy(avoided_wh, kind, t)

    def on_engine_added(self, name: str, engine,
                        initial: bool = False) -> None:
        """Pool-membership hook (``PoolServer._configure_engine``):
        pre-bind the engine's queue/power gauges so a late joiner is
        visible in the export from its first step; runtime additions
        (``initial=False``) also log a pool-growth event."""
        if name not in self._queue_gauges:
            self._queue_gauges[name] = self.registry.gauge(
                "greenserv_queue_depth", {"engine": name})
            self._power_gauges[name] = self.registry.gauge(
                "greenserv_power_watts", {"source": name})
        if not initial:
            self.events.emit(ev.ENGINE_ADDED, self.clock(), engine=name)

    def on_completion(self, resp, accuracy: float,
                      predicted_wh: Optional[float] = None) -> None:
        """``predicted_wh``: the pre-dispatch forecast for this query, if
        the scheduler ran a cost model — recorded as a relative error
        sample against the metered ``resp.energy_wh``."""
        t = self.clock()
        model = resp.model_name
        c = self._completed.get(model)
        if c is None:
            r, lbl = self.registry, {"model": model}
            c = self._completed[model] = r.counter(
                "greenserv_completed_total", lbl)
            self._energy_per_tok[model] = r.histogram(
                "greenserv_energy_per_token_mwh", lbl)
            self._energy_total[model] = r.counter(
                "greenserv_energy_mwh_total", lbl)
            self._tokens[(model, "in")] = r.counter(
                "greenserv_tokens_total", {"model": model, "dir": "in"})
            self._tokens[(model, "out")] = r.counter(
                "greenserv_tokens_total", {"model": model, "dir": "out"})
        c.inc()
        self._latency.record(resp.latency_ms)
        ttft = resp.ttft_ms
        if ttft:
            self._ttft.record(ttft)
        self._queue_wait.record(resp.queue_ms)
        self._energy_total[model].inc(resp.energy_wh * 1e3)
        self._tokens[(model, "in")].inc(resp.input_tokens)
        self._tokens[(model, "out")].inc(resp.output_tokens)
        if resp.output_tokens > 0:
            self._energy_per_tok[model].record(
                resp.energy_wh * 1e3 / resp.output_tokens)
        self.events.emit(ev.COMPLETE, t, uid=resp.uid, model=model,
                         latency_ms=resp.latency_ms,
                         energy_wh=resp.energy_wh, accuracy=accuracy)
        if predicted_wh is not None and predicted_wh > 0.0:
            self._pred_err.record(abs(resp.energy_wh - predicted_wh)
                                  / max(resp.energy_wh, 1e-12))
        if self.governor is not None:
            self.governor.on_completion(resp.energy_wh, t, uid=resp.uid)

    def on_duplicate_work(self, energy_wh: float) -> None:
        """A hedged pair resolved: the losing duplicate burned energy that
        never produces a Response.  Charge the budget (winner's energy as
        a conservative proxy — the loser was cancelled partway, so this
        overcharges, which errs on the safe side of a Wh cap)."""
        if self.governor is not None:
            self.governor.on_extra_energy(energy_wh, self.clock())

    def on_hedge(self, uid: int, target: str) -> None:
        self._hedges.inc()
        self.events.emit(ev.HEDGE, self.clock(), uid=uid, target=target)

    def on_migration(self, engine: str, n_tokens: int) -> None:
        """A request's prompt KV was handed from ``engine`` (prefill role)
        to its decode twin at the phase boundary."""
        c = self._migrations.get(engine)
        if c is None:
            c = self._migrations[engine] = self.registry.counter(
                "greenserv_migrations_total", {"engine": engine})
        c.inc()
        self.events.emit(ev.MIGRATE, self.clock(), engine=engine,
                         kv_tokens=n_tokens)

    def on_restart(self, engine: str, n_requeued: int) -> None:
        c = self._restarts.get(engine)
        if c is None:
            c = self._restarts[engine] = self.registry.counter(
                "greenserv_restarts_total", {"engine": engine})
        c.inc()
        self.events.emit(ev.RESTART, self.clock(), engine=engine,
                         n_requeued=n_requeued)

    # -- reliability hooks (docs/RELIABILITY.md) ----------------------------

    def on_attempt_failure(self, uid: int, engine: str, reason: str,
                           energy_wh: float = 0.0) -> None:
        """One dispatch of a request died on ``engine`` (crash, garbage
        output, timeout…).  Any ``energy_wh`` it burned produced no
        completion, so the governor charges it as extra energy — exactly
        like a hedge loser's duplicate work."""
        c = self._attempt_failures.get(engine)
        if c is None:
            c = self._attempt_failures[engine] = self.registry.counter(
                "greenserv_attempt_failures_total", {"engine": engine})
        c.inc()
        self.events.emit(ev.ATTEMPT_FAIL, self.clock(), uid=uid,
                         engine=engine, reason=reason, energy_wh=energy_wh)
        if self.governor is not None and energy_wh > 0.0:
            self.governor.on_extra_energy(energy_wh, self.clock())

    def on_retry(self, uid: int, attempt: int, from_engine: str,
                 to_engine: str) -> None:
        self._retries.inc()
        self.events.emit(ev.RETRY, self.clock(), uid=uid, attempt=attempt,
                         from_engine=from_engine, to_engine=to_engine)

    def on_timeout(self, uid: int, waited_s: float) -> None:
        """Deadline passed before any completion: terminal TIMED_OUT.
        Every timeout is an SLO violation by definition.  The governor's
        in-flight charge is released by the scheduler's ``on_cancelled``
        call (its exactly-once bookkeeping), not here."""
        self._timeouts.inc()
        self._slo_violations.inc()
        self.events.emit(ev.TIMEOUT, self.clock(), uid=uid,
                         waited_s=waited_s)

    def on_request_failed(self, uid: int, reason: str) -> None:
        """Attempts exhausted: terminal FAILED (no response exists)."""
        self._req_failed.inc()

    def on_slo_violation(self, uid: int, latency_ms: float) -> None:
        """A completion arrived, but after its deadline (late answer —
        served, yet out of SLO).  Timeouts count through ``on_timeout``."""
        self._slo_violations.inc()

    def on_breaker(self, engine: str, old: str, new: str,
                   step: int) -> None:
        """A circuit breaker changed state on ``engine``'s arm."""
        self._breaker_transitions.inc()
        g = self._breaker_open.get(engine)
        if g is None:
            g = self._breaker_open[engine] = self.registry.gauge(
                "greenserv_breaker_open", {"engine": engine})
        g.set(0.0 if new == "closed" else 1.0)
        self.events.emit(ev.BREAKER, self.clock(), engine=engine,
                         old=old, new=new, step=step)

    def on_step(self, engines: Dict[str, object]) -> None:
        """Once per ``PoolServer.step``: power samples (per engine, pool,
        and per serving phase), queue depths, phase-tagged joule counters,
        and one governor control step."""
        t = self.clock()
        joules = {}
        phase_tot = {"prefill": 0.0, "decode": 0.0}
        prefix_avoided = 0.0
        prefix_hits = 0
        for name, eng in engines.items():
            phases = eng.cumulative_joules_by_phase()
            joules[name] = phases.get("prefill", 0.0) + phases.get(
                "decode", 0.0)
            phase_tot["prefill"] += phases.get("prefill", 0.0)
            phase_tot["decode"] += phases.get("decode", 0.0)
            # role attribution: the same joules, keyed by which *class* of
            # engine burned them (all-unified pools book under "unified")
            role = getattr(eng, "role", "unified")
            d_role = joules[name] - self._engine_joules_last.get(name, 0.0)
            if d_role > 0.0:
                self._role_energy.setdefault(
                    role, self.registry.counter(
                        "greenserv_energy_joules_total", {"role": role},
                        help="pool-wide metered joules by engine role")
                ).inc(d_role)
                if self.governor is not None:
                    self.governor.on_role_energy(role,
                                                 d_role / JOULES_PER_WH)
            self._engine_joules_last[name] = joules[name]
            # getattr: duck-typed engines (tests, adapters) may predate
            # the avoided-energy surface
            prefix_avoided += getattr(eng, "cumulative_joules_avoided",
                                      lambda: 0.0)()
            prefix_hits += getattr(eng, "prefix_hit_count", lambda: 0)()
            qg = self._queue_gauges.get(name)
            if qg is None:
                qg = self._queue_gauges[name] = self.registry.gauge(
                    "greenserv_queue_depth", {"engine": name})
                self._power_gauges[name] = self.registry.gauge(
                    "greenserv_power_watts", {"source": name})
            qg.set(eng.pending)
        self.power.sample_all(t, joules, phase_joules=phase_tot)
        # prefix-KV avoided energy is metered inside the engines; diff the
        # cumulative counters once per step (exactly like phase joules)
        d_avoided = max(prefix_avoided - self._prefix_avoided_last, 0.0)
        if d_avoided:
            self._avoided_energy["prefix"].inc(d_avoided)
            self._avoided_cum_joules += d_avoided
            if self.governor is not None:
                self.governor.on_avoided_energy(
                    d_avoided / JOULES_PER_WH, "prefix", t)
        self._prefix_avoided_last = prefix_avoided
        d_hits = prefix_hits - self._prefix_hits_last
        if d_hits > 0:
            self._cache_hits["prefix"].inc(d_hits)
        self._prefix_hits_last = prefix_hits
        self.power.sample(AVOIDED, t, self._avoided_cum_joules)
        deltas = {}
        for ph, cur in phase_tot.items():
            deltas[ph] = max(cur - self._phase_last[ph], 0.0)
            if deltas[ph]:
                self._phase_energy[ph].inc(deltas[ph])
            self._phase_last[ph] = cur
        for name, pg in self._power_gauges.items():
            pg.set(self.power.last_watts(name))
        self._pool_power_gauge.set(self.power.last_watts(POOL))
        self._phase_power_gauges["prefill"].set(
            self.power.last_watts(PHASE_PREFILL))
        self._phase_power_gauges["decode"].set(
            self.power.last_watts(PHASE_DECODE))
        if self.governor is not None and (deltas["prefill"]
                                          or deltas["decode"]):
            self.governor.on_phase_energy(deltas["prefill"] / JOULES_PER_WH,
                                          deltas["decode"] / JOULES_PER_WH)
        if self.governor is not None:
            before = self.governor.current_lambda
            lam = self.governor.step(t)
            self._lambda.set(lam)
            self._pressure.set(self.governor.pressure)
            if before is not None and lam != before:
                self.events.emit(ev.LAMBDA, t, value=lam,
                                 pressure=self.governor.pressure)

    # -- reporting ----------------------------------------------------------

    def summary(self) -> str:
        """Compact human-readable end-of-run summary (used by serve.py)."""
        lines = ["[telemetry]"]
        lat = self._latency
        if lat.count:
            lines.append(
                f"  latency   p50 {lat.quantile(0.5):8.1f} ms   "
                f"p95 {lat.quantile(0.95):8.1f} ms   "
                f"p99 {lat.quantile(0.99):8.1f} ms   (n={lat.count})")
        qw = self._queue_wait
        if qw.count:
            lines.append(
                f"  queue     p50 {qw.quantile(0.5):8.1f} ms   "
                f"p95 {qw.quantile(0.95):8.1f} ms")
        if self._ttft.count:
            lines.append(
                f"  ttft      p50 {self._ttft.quantile(0.5):8.1f} ms   "
                f"p95 {self._ttft.quantile(0.95):8.1f} ms")
        if self.power.series(POOL):
            lines.append(
                f"  power     avg {self.power.avg_watts():10.1f} W   "
                f"peak {self.power.peak_watts():10.1f} W   "
                f"total {self.power.total_wh():.4f} Wh")
        pre_wh = self.power.total_wh(PHASE_PREFILL)
        dec_wh = self.power.total_wh(PHASE_DECODE)
        if pre_wh or dec_wh:
            frac = pre_wh / max(pre_wh + dec_wh, 1e-12)
            lines.append(
                f"  phases    prefill {pre_wh:.4f} Wh ({frac:5.1%})   "
                f"decode {dec_wh:.4f} Wh")
        if self._avoided_cum_joules > 0.0:
            mwh = 1e3 / JOULES_PER_WH
            pj = self._avoided_energy["prefix"].value
            sj = self._avoided_energy["semantic"].value
            lines.append(
                f"  cache     avoided {self._avoided_cum_joules * mwh:.4g} mWh "
                f"(prefix {pj * mwh:.4g} / semantic {sj * mwh:.4g})   "
                f"hits prefix {int(self._cache_hits['prefix'].value)} / "
                f"semantic {int(self._cache_hits['semantic'].value)}")
        for model in sorted(self._energy_per_tok):
            h = self._energy_per_tok[model]
            if h.count:
                lines.append(
                    f"    {model:20s} {h.mean:8.3f} mWh/token "
                    f"({int(self._completed[model].value)} completions)")
        if self.governor is not None:
            g = self.governor.stats()
            lines.append(
                f"  budget    {g['cumulative_wh']:.3f} / "
                f"{g['budget_wh']:.3f} Wh spent   pressure "
                f"{g['pressure']:.2f}   λ now {g['lambda']:.3f}   "
                f"({g['lambda_changes']} adjustments)")
            avoided = g["avoided_prefix_wh"] + g["avoided_semantic_wh"]
            if avoided > 0.0:
                lines.append(
                    f"            avoided {avoided * 1e3:.4g} mWh credited "
                    f"(prefix {g['avoided_prefix_wh'] * 1e3:.4g} / semantic "
                    f"{g['avoided_semantic_wh'] * 1e3:.4g})")
        return "\n".join(lines)
