"""Metric export: Prometheus text exposition + JSONL trace dump.

Two consumers, two formats:

  * ``to_prometheus`` renders the registry in the text exposition format
    (counters/gauges verbatim, histograms as summaries with ``quantile``
    labels) — scrapeable by any Prometheus-compatible stack;
  * ``dump_jsonl`` / ``load_jsonl`` write and re-read the full recorded
    state (metrics, power series, events) as one JSON object per line —
    the per-run artifact CI uploads so the perf trajectory is inspectable
    per-PR.

Both directions are lossless for the quantities they carry;
``tests/test_telemetry.py`` asserts the round-trips.
"""
from __future__ import annotations

import json
import re
from typing import Dict, Iterable, List, Optional, Tuple

from repro.telemetry.events import EventLog
from repro.telemetry.metrics import (Counter, Gauge, Histogram,
                                     MetricsRegistry)
from repro.telemetry.power import PowerTrace

_LABEL_RE = re.compile(r'(\w+)="((?:[^"\\]|\\.)*)"')
_LINE_RE = re.compile(r'^([a-zA-Z_:][\w:]*)(?:\{(.*)\})?\s+(\S+)$')


def _fmt_labels(items: Tuple[Tuple[str, str], ...],
                extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    all_items = tuple(items) + tuple(extra)
    if not all_items:
        return ""
    body = ",".join(f'{k}="{v}"' for k, v in all_items)
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    return repr(float(v))


def to_prometheus(registry: MetricsRegistry) -> str:
    """Render the registry in Prometheus text exposition format."""
    lines: List[str] = []
    seen_header = set()
    for m in registry:
        if m.name not in seen_header:
            seen_header.add(m.name)
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            ptype = {"counter": "counter", "gauge": "gauge",
                     "histogram": "summary"}[m.kind]
            lines.append(f"# TYPE {m.name} {ptype}")
        if isinstance(m, (Counter, Gauge)):
            lines.append(
                f"{m.name}{_fmt_labels(m.labels)} {_fmt_value(m.value)}")
        elif isinstance(m, Histogram):
            for q, v in m.quantiles.items():
                lines.append(
                    f"{m.name}"
                    f"{_fmt_labels(m.labels, (('quantile', repr(q)),))}"
                    f" {_fmt_value(v)}")
            lines.append(
                f"{m.name}_sum{_fmt_labels(m.labels)} {_fmt_value(m.sum)}")
            lines.append(
                f"{m.name}_count{_fmt_labels(m.labels)} {m.count}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[Tuple[str, Tuple[Tuple[str, str], ...]],
                                        float]:
    """Parse exposition text back to {(name, label items): value} — enough
    to verify a scrape round-trips the recorded series."""
    out: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _LINE_RE.match(line)
        if not m:
            continue
        name, labels_body, value = m.groups()
        items = tuple(sorted(_LABEL_RE.findall(labels_body or "")))
        out[(name, items)] = float(value)
    return out


# ---------------------------------------------------------------------------
# JSONL traces
# ---------------------------------------------------------------------------


def to_jsonl_rows(registry: Optional[MetricsRegistry] = None,
                  power: Optional[PowerTrace] = None,
                  events: Optional[EventLog] = None,
                  meta: Optional[dict] = None) -> Iterable[dict]:
    if meta:
        yield {"type": "meta", **meta}
    if registry is not None:
        for m in registry:
            row = {"type": m.kind, "name": m.name, "labels": dict(m.labels)}
            if isinstance(m, (Counter, Gauge)):
                row["value"] = m.value
            elif isinstance(m, Histogram):
                row.update(count=m.count, sum=m.sum,
                           min=(None if m.count == 0 else m.min),
                           max=(None if m.count == 0 else m.max),
                           quantiles={repr(q): v
                                      for q, v in m.quantiles.items()})
            yield row
    if power is not None:
        for row in power.to_rows():
            yield {"type": "power", **row}
    if events is not None:
        for row in events.to_rows():
            yield {"type": "event", **row}


def dump_jsonl(path: str, registry: Optional[MetricsRegistry] = None,
               power: Optional[PowerTrace] = None,
               events: Optional[EventLog] = None,
               meta: Optional[dict] = None) -> int:
    n = 0
    with open(path, "w") as f:
        for row in to_jsonl_rows(registry, power, events, meta):
            f.write(json.dumps(row, sort_keys=True) + "\n")
            n += 1
    return n


def load_jsonl(path: str) -> Dict[str, List[dict]]:
    """Re-read a trace dump, grouped by row type."""
    out: Dict[str, List[dict]] = {"meta": [], "counter": [], "gauge": [],
                                  "histogram": [], "power": [], "event": []}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            out.setdefault(row.get("type", "unknown"), []).append(row)
    return out
