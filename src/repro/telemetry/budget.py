"""Adaptive energy-budget governance: a closed loop from joules to λ.

GreenServ's λ fixes the accuracy–energy trade-off at launch; the
``EnergyBudgetGovernor`` makes it a control variable.  A token bucket holds
Wh credit: completions drain it by their measured energy, a refill stream
adds credit at the budget's sustainable rate (per completed query against a
known horizon, or per second against a wall-clock window).  Bucket
depletion maps to *pressure* in [0, 1], and pressure interpolates λ from
its launch value toward ``lambda_max`` — so the bandit shifts toward
cheaper arms exactly when the budget tightens and relaxes when headroom
returns (workload-conditioned budget-optimal serving in the sense of
Wilkins et al., arXiv:2407.04014).

An optional carbon-intensity signal scales the refill rate: when the grid
is dirty the same Wh budget buys fewer tokens *now*, deferring energy
spend to cleaner hours (diurnal model below; plug a live signal in via
``carbon_fn``).

λ changes propagate through ``GreenServRouter.set_lambda``, which
re-scalarizes the bandit's sufficient statistics under the new λ — the
posterior reacts to the new trade-off immediately instead of waiting for
thousands of fresh observations to wash out the old one.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Tuple


def diurnal_carbon_intensity(t_s: float, amplitude: float = 0.3,
                             period_s: float = 86_400.0,
                             phase_s: float = 0.0) -> float:
    """Relative grid carbon intensity (≈1.0 mean): a smooth daily cycle
    peaking mid-period (evening demand) and bottoming out off-peak."""
    return 1.0 + amplitude * math.sin(
        2.0 * math.pi * (t_s + phase_s) / period_s)


class EnergyBudgetGovernor:
    """Token-bucket Wh governor driving the router's λ online.

    Exactly one of ``horizon_queries`` (refill per completion — offline
    runs over a known stream) or ``horizon_s`` (refill per second — live
    serving against a wall-clock window) must be given.

    The bucket holds at most ``burst_frac · budget_wh`` and may run the
    same amount into debt; pressure is the depletion fraction of that
    span.  Two extra brakes keep cumulative spend under the cap rather
    than merely near it:

      * *projection*: once the recent per-query burn rate projects the run
        past the budget, pressure is floored at the overshoot fraction;
      * *exhaustion*: past ``hard_frac`` of the budget λ pins to
        ``lambda_max`` until headroom returns.
    """

    def __init__(self, budget_wh: float,
                 horizon_queries: Optional[int] = None,
                 horizon_s: Optional[float] = None,
                 router=None,
                 lambda_max: float = 0.8,
                 burst_frac: float = 0.08,
                 hard_frac: float = 0.95,
                 safety: float = 0.95,
                 carbon_fn: Optional[Callable[[float], float]] = None,
                 min_delta: float = 5e-3,
                 ewma_alpha: float = 0.05,
                 gain: float = 0.01,
                 initial_lambda: Optional[float] = None,
                 control_on_completion: bool = True):
        if budget_wh <= 0:
            raise ValueError("budget_wh must be positive")
        if (horizon_queries is None) == (horizon_s is None):
            raise ValueError(
                "exactly one of horizon_queries / horizon_s is required")
        self.budget_wh = float(budget_wh)
        self.horizon_queries = horizon_queries
        self.horizon_s = horizon_s
        self.router = router
        self.lambda_max = float(lambda_max)
        self.base_lambda: Optional[float] = (
            float(router.config.lam) if router is not None else None)
        self.capacity_wh = burst_frac * self.budget_wh
        self.bucket_wh = self.capacity_wh
        self.hard_frac = hard_frac
        self.safety = safety        # refill toward safety·budget, not budget
        self.carbon_fn = carbon_fn
        self.min_delta = min_delta
        self.ewma_alpha = ewma_alpha
        self.gain = gain            # integral gain: Δλ per control tick
        self.control_on_completion = control_on_completion
        # opening stance: default to the router's launch λ.  Opening at
        # λ_max ("budget-first") banks headroom but biases the early
        # posterior cheap — the bandit under-explores accurate arms and
        # that conservatism persists after λ relaxes; operators with very
        # tight caps can still opt in via initial_lambda.
        self.initial_lambda = initial_lambda
        # state
        self.cumulative_wh = 0.0
        self.completed = 0
        self.admitted = 0
        self.wh_per_query_ewma: Optional[float] = None
        self.pressure = 0.0
        self.current_lambda: Optional[float] = self.base_lambda
        self._lam_target: Optional[float] = (
            self.initial_lambda if self.initial_lambda is not None
            else self.base_lambda)
        self.lambda_history: List[Tuple[float, float]] = []
        self._last_refill_s: Optional[float] = None
        self.exhausted = False
        # phase ledger (Wh): attribution of the metered burn to prefill vs
        # decode, fed per step by Telemetry.on_step from the engines'
        # phase-tagged joule counters
        self.phase_wh = {"prefill": 0.0, "decode": 0.0}
        # role ledger (Wh): the same burn keyed by *engine role* (unified /
        # prefill / decode).  Under disaggregated serving this is the
        # per-role energy attribution the bench reads; an all-unified pool
        # books everything under "unified"
        self.role_wh = {"unified": 0.0, "prefill": 0.0, "decode": 0.0}
        # GreenCache credit ledger (Wh): energy the cache *avoided*
        # spending (prefix-KV splices, semantic answers).  Avoided energy
        # earns bucket credit — work the budget no longer has to fund —
        # but never counts as negative spend against the hard cap.
        self.avoided_wh = {"prefix": 0.0, "semantic": 0.0}
        # expected Wh savings of queries routed-but-not-completed (prefix
        # hits known at admission); discounts the in-flight commitment so
        # a warm-cache burst doesn't tighten λ for energy it won't spend
        self.inflight_savings_wh = 0.0
        # predict-then-reconcile (docs/ENERGY.md): per-query predicted Wh
        # charged at admission (uid-keyed so a completion releases exactly
        # its own charge — hedge winners share the primary's uid), plus
        # the running ledger of prediction-vs-metered error ratios
        self.inflight_pred: Dict[int, float] = {}
        self.inflight_predicted_wh = 0.0
        self.prediction_error = {"n": 0, "abs_ratio_sum": 0.0,
                                 "ratio_sum": 0.0, "max_abs_ratio": 0.0}

    def attach(self, router) -> None:
        self.router = router
        if self.base_lambda is None:
            self.base_lambda = float(router.config.lam)
            self.current_lambda = self.base_lambda

    # -- accounting ---------------------------------------------------------

    def _refill_rate_scale(self, t_s: float) -> float:
        if self.carbon_fn is None:
            return 1.0
        # dirty grid → each wall-clock unit earns proportionally less credit
        return 1.0 / max(self.carbon_fn(t_s), 1e-6)

    def on_admission(self, n: int, t_s: float = 0.0,
                     expected_savings_wh: float = 0.0,
                     predicted=None) -> None:
        """Note routed-but-not-yet-completed queries.  Routing commits
        energy long before completion meters it; the projection charges
        each in-flight query its expected (EWMA) cost so admission bursts
        tighten λ *before* their bill arrives, not a pipeline-delay later.

        ``expected_savings_wh``: Wh the batch is expected *not* to spend
        (prefix-KV hits known at routing time); it discounts the in-flight
        commitment until the corresponding completions retire it.

        ``predicted``: iterable of ``(uid, predicted_wh)`` from the energy
        cost model — each query's *own* pre-dispatch forecast replaces the
        pool-average EWMA in the in-flight commitment, and the charge is
        released (and reconciled against the metered Wh) when that uid
        completes or is cancelled."""
        self.admitted += n
        self.inflight_savings_wh += max(expected_savings_wh, 0.0)
        if predicted is not None:
            for uid, wh in predicted:
                wh = max(float(wh), 0.0)
                prev = self.inflight_pred.pop(uid, None)
                if prev is not None:       # re-admission (restart re-route)
                    self.inflight_predicted_wh -= prev
                self.inflight_pred[uid] = wh
                self.inflight_predicted_wh += wh
        if self.control_on_completion:
            self._control(t_s)

    def on_avoided_energy(self, energy_wh: float, kind: str,
                          t_s: float = 0.0) -> None:
        """Credit energy a cache hit avoided spending (``kind`` is
        ``"prefix"`` or ``"semantic"``).  Ledger entry + bucket refill
        credit: the avoided Wh is headroom the budget gets back, so
        pressure relaxes in the same step — the closed-loop face of
        "the cheapest token is the one never computed".  Cumulative spend
        (the hard cap) is untouched: avoided energy is not negative
        consumption."""
        wh = max(energy_wh, 0.0)
        self.avoided_wh[kind] = self.avoided_wh.get(kind, 0.0) + wh
        self.bucket_wh = min(self.bucket_wh + wh, self.capacity_wh)
        if self.control_on_completion:
            self._control(t_s)

    def on_extra_energy(self, energy_wh: float, t_s: float = 0.0) -> None:
        """Charge energy that produced no completion of its own — e.g. a
        hedge duplicate's discarded work.  Drains the bucket and counts
        toward the cap without perturbing the per-query burn statistics."""
        self.cumulative_wh += energy_wh
        self.bucket_wh = max(self.bucket_wh - energy_wh, -self.capacity_wh)
        if self.control_on_completion:
            self._control(t_s)

    def on_phase_energy(self, prefill_wh: float, decode_wh: float) -> None:
        """Attribute a step's metered energy (Wh deltas) to the prefill /
        decode phases.  This is a *ledger*, not a second drain: the bucket
        is still drained by per-completion Wh (which already includes both
        phases) — the split tells operators (and ``stats()``) which phase
        is consuming the budget, e.g. whether tightening λ should shed
        long-prompt traffic or long generations."""
        self.phase_wh["prefill"] += max(prefill_wh, 0.0)
        self.phase_wh["decode"] += max(decode_wh, 0.0)

    def on_role_energy(self, role: str, energy_wh: float) -> None:
        """Attribute a step's metered energy (Wh delta) to the reporting
        engine's *role* — a second ledger view next to the phase split.
        Phases say what kind of work burned the joules; roles say which
        class of engine did, which is what disaggregated serving needs to
        size its prefill vs decode fleets."""
        self.role_wh[role] = self.role_wh.get(role, 0.0) \
            + max(energy_wh, 0.0)

    def on_completion(self, energy_wh: float, t_s: float = 0.0,
                      uid: Optional[int] = None) -> None:
        """Drain the bucket by a completion's measured energy; in query-
        horizon mode also earn this completion's refill credit.  With a
        ``uid`` the completion releases its admission-time predicted
        charge (exactly once — the map is popped) and the prediction is
        reconciled against the metered Wh into the ``prediction_error``
        ledger."""
        if uid is not None:
            pred = self.inflight_pred.pop(uid, None)
            if pred is not None:
                self.inflight_predicted_wh = max(
                    self.inflight_predicted_wh - pred, 0.0)
                self._record_prediction_error(pred, energy_wh)
        # the completing query carries away its (average) share of the
        # expected in-flight cache savings — realized savings now show up
        # in the measured energy itself
        inflight = max(self.admitted - self.completed, 1)
        self.inflight_savings_wh *= 1.0 - 1.0 / inflight
        self.cumulative_wh += energy_wh
        self.completed += 1
        self.bucket_wh -= energy_wh
        if self.horizon_queries is not None:
            rate = self.safety * self.budget_wh / max(self.horizon_queries, 1)
            self.bucket_wh += rate * self._refill_rate_scale(t_s)
        a = self.ewma_alpha
        if self.wh_per_query_ewma is None:
            self.wh_per_query_ewma = energy_wh
        else:
            self.wh_per_query_ewma = (1 - a) * self.wh_per_query_ewma \
                + a * energy_wh
        self.bucket_wh = min(max(self.bucket_wh, -self.capacity_wh),
                             self.capacity_wh)
        if self.control_on_completion:
            self._control(t_s)

    def on_cancel(self, uid: int, t_s: float = 0.0) -> None:
        """Release a cancelled query's predicted in-flight charge without
        reconciling (no completion ever meters it).  Idempotent — a uid
        already released (or never predicted) is a no-op, so the bucket
        can never be credited twice."""
        pred = self.inflight_pred.pop(uid, None)
        if pred is not None:
            self.inflight_predicted_wh = max(
                self.inflight_predicted_wh - pred, 0.0)
        if self.control_on_completion:
            self._control(t_s)

    def _record_prediction_error(self, predicted_wh: float,
                                 measured_wh: float) -> None:
        ratio = (measured_wh - predicted_wh) / max(measured_wh, 1e-12)
        e = self.prediction_error
        e["n"] += 1
        e["abs_ratio_sum"] += abs(ratio)
        e["ratio_sum"] += ratio
        e["max_abs_ratio"] = max(e["max_abs_ratio"], abs(ratio))

    def admission_headroom_wh(self) -> float:
        """Wh of *new* predicted work the budget can absorb right now —
        the admission planner's gate.  The bucket may legally run
        ``capacity_wh`` into debt, so the spendable span is
        ``bucket + capacity``; predicted in-flight work has already
        claimed its share, and the hard cap bounds everything."""
        if self.exhausted:
            return 0.0
        soft = self.bucket_wh + self.capacity_wh - self.inflight_predicted_wh
        hard = (self.hard_frac * self.budget_wh - self.cumulative_wh
                - self.inflight_predicted_wh)
        return max(min(soft, hard), 0.0)

    def _rate_error(self) -> Optional[float]:
        """Dimensionless burn-rate error: 0 = on the sustainable rate,
        positive = burning hot (tighten λ), negative = headroom (relax).

        Query-horizon mode compares the EWMA per-query burn against the
        rate the remaining (safety-margined) headroom supports, charging
        in-flight queries their expected cost — routing commits energy
        long before completion meters it.  Wall-clock mode reads the
        token bucket level (its drift *is* the integrated rate error).
        """
        if self.horizon_queries is not None:
            if self.wh_per_query_ewma is None or self.completed == 0:
                return None
            inflight = max(self.admitted - self.completed, 0)
            # queries with a cost-model forecast are charged their own
            # predicted Wh; only the unpredicted remainder falls back to
            # the pool-average EWMA
            unpredicted = max(inflight - len(self.inflight_pred), 0)
            expected_inflight_wh = (self.inflight_predicted_wh
                                    + unpredicted * self.wh_per_query_ewma)
            # prefix-KV hits known at admission won't spend their full
            # EWMA cost; the discount never exceeds the commitment itself
            expected_inflight_wh -= min(self.inflight_savings_wh,
                                        expected_inflight_wh)
            committed = self.cumulative_wh + expected_inflight_wh
            remaining_q = self.horizon_queries - max(self.admitted,
                                                     self.completed)
            if remaining_q <= 0:
                return None                       # no routing authority left
            headroom = self.safety * self.budget_wh - committed
            if headroom <= 0.0:
                return 1.0
            target = headroom / remaining_q
            # symmetric clip: per-task energy spans an order of magnitude,
            # and letting hot spikes integrate twice as fast as cool ones
            # skews the time-average λ into cheap-arm saturation
            return min(max(self.wh_per_query_ewma / target - 1.0, -1.0), 1.0)
        # wall-clock mode: half-full bucket = on rate
        half = max(self.capacity_wh, 1e-12)
        return min(max(1.0 - 2.0 * self.bucket_wh / half, -1.0), 1.0)

    # -- the control step ---------------------------------------------------

    def _control(self, t_s: float) -> float:
        """Integrate the burn-rate error into λ and push it to the router.

        Integral action (Δλ = gain·err per tick) finds the *equilibrium*
        λ where the bandit's spending matches the sustainable rate and
        settles there — a proportional map from error to λ either leaves
        a steady-state overburn or slams between base and λ_max.
        """
        base = self.base_lambda if self.base_lambda is not None else 0.4
        lo, hi = min(base, self.lambda_max), self.lambda_max
        if self._lam_target is None:
            self._lam_target = base
        err = self._rate_error()
        if err is not None:
            self._lam_target += self.gain * err
        self.exhausted = self.cumulative_wh >= self.hard_frac * self.budget_wh
        if self.exhausted:
            self._lam_target = hi                 # out of budget: pin to max
        self._lam_target = min(max(self._lam_target, lo), hi)
        self.pressure = (self._lam_target - lo) / max(hi - lo, 1e-9)
        lam = self._lam_target

        if (self.router is not None and self.current_lambda is not None
                and abs(lam - self.current_lambda) > self.min_delta):
            self.router.set_lambda(lam)
            self.lambda_history.append((t_s, lam))
            self.current_lambda = lam
        elif self.current_lambda is None:
            self.current_lambda = lam
        return self.current_lambda if self.current_lambda is not None else lam

    def step(self, t_s: float) -> float:
        """The per-scheduler-step control point; returns the λ in force.

        In wall-clock mode this is also where refill credit accrues.
        (With ``control_on_completion`` the λ recompute additionally runs
        at every completion — scheduler steps that complete whole admission
        batches would otherwise give the loop too few control points.)
        """
        if self.horizon_s is not None:
            if self._last_refill_s is not None:
                dt = max(t_s - self._last_refill_s, 0.0)
                rate = self.safety * self.budget_wh / self.horizon_s
                self.bucket_wh = min(
                    self.bucket_wh
                    + rate * dt * self._refill_rate_scale(t_s),
                    self.capacity_wh)
            self._last_refill_s = t_s
        return self._control(t_s)

    # -- introspection ------------------------------------------------------

    @property
    def remaining_wh(self) -> float:
        return max(self.budget_wh - self.cumulative_wh, 0.0)

    def stats(self) -> dict:
        return {
            "budget_wh": self.budget_wh,
            "cumulative_wh": self.cumulative_wh,
            "remaining_wh": self.remaining_wh,
            "bucket_wh": self.bucket_wh,
            "pressure": self.pressure,
            "lambda": self.current_lambda,
            "lambda_changes": len(self.lambda_history),
            "completed": self.completed,
            "exhausted": self.exhausted,
            "prefill_wh": self.phase_wh["prefill"],
            "decode_wh": self.phase_wh["decode"],
            "role_wh": dict(self.role_wh),
            "avoided_prefix_wh": self.avoided_wh["prefix"],
            "avoided_semantic_wh": self.avoided_wh["semantic"],
            "inflight_predicted_wh": self.inflight_predicted_wh,
            "prediction_error": {
                "n": self.prediction_error["n"],
                "mae_ratio": (self.prediction_error["abs_ratio_sum"]
                              / max(self.prediction_error["n"], 1)),
                "mean_ratio": (self.prediction_error["ratio_sum"]
                               / max(self.prediction_error["n"], 1)),
                "max_abs_ratio": self.prediction_error["max_abs_ratio"],
            },
        }
