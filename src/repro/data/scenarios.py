"""Composable traffic scenarios for the paper-scale closed-loop benches.

A :class:`Scenario` bundles everything a closed-loop drive needs to replay
a traffic shape deterministically: the query stream, per-query arrival
times on the virtual clock, mid-run pool events (engine kills, runtime
model additions), and an optional carbon-intensity signal for the
governor.  Generators compose the shapes the GreenServ evaluation cares
about:

  * :func:`steady`          — Poisson arrivals, optional diurnal carbon;
  * :func:`flash_crowd`     — two-state MMPP arrivals (calm stretches
    punctuated by flash crowds, the geometry of ``bench_disagg``) under a
    diurnal carbon cycle scaled to the run's span;
  * :func:`duplicate_flood` — adversarial near-duplicate bursts aimed at
    the semantic cache: hot queries replayed with small textual
    perturbations that should (and, with the cluster guard, safely can)
    hit the embedding-similarity lookup;
  * :func:`pool_churn`      — an engine killed mid-run (fault-tolerance
    path) plus the paper's §6.2.4 held-out model joining via
    ``add_engine`` (zero-calibration adaptability).

Everything is seeded — the same seed replays the identical scenario, byte
for byte (``Scenario.fingerprint`` hashes the whole thing for tests).
Arrival timescales are *modeled* seconds: ``SimEngine`` ticks advance the
virtual clock by per-query latency shares (hundreds of ms to seconds), so
the default rates put calm load near a 16-model pool's service rate and
bursts well past it.
"""
from __future__ import annotations

import dataclasses
import hashlib
import random
from typing import Callable, List, Optional

import numpy as np

from repro.core.types import Query
from repro.data.stream import make_stream
from repro.telemetry.budget import diurnal_carbon_intensity


@dataclasses.dataclass(frozen=True)
class PoolEvent:
    """A mid-run pool-membership change, applied when the virtual clock
    passes ``t_s``: ``kind="kill"`` injects a failure into the named
    engine (the scheduler's heartbeat/restart path recovers it);
    ``kind="add"`` registers the named paper-pool model as a fresh engine
    + bandit arm via ``PoolServer.add_engine``."""

    t_s: float
    kind: str          # "kill" | "add"
    model: str


@dataclasses.dataclass
class Scenario:
    """One replayable traffic shape for the closed-loop harness."""

    name: str
    queries: List[Query]
    arrivals_s: List[float]
    events: List[PoolEvent] = dataclasses.field(default_factory=list)
    carbon_fn: Optional[Callable[[float], float]] = None
    # models held out of the *starting* pool (an "add" event brings them in)
    exclude: Optional[List[str]] = None
    # chaos plan: engine name -> FaultSpec list (serving.faults), wrapped
    # around the matching engine by the harness before the drive starts
    faults: Optional[dict] = None

    @property
    def n_queries(self) -> int:
        return len(self.queries)

    @property
    def span_s(self) -> float:
        return self.arrivals_s[-1] if self.arrivals_s else 0.0

    def fingerprint(self) -> str:
        """Deterministic digest of the whole scenario (stream texts,
        arrival times, events) — the determinism tests compare these."""
        h = hashlib.sha256()
        for q, t in zip(self.queries, self.arrivals_s):
            h.update(f"{q.uid}|{q.task}|{t:.9f}|{q.text}".encode())
        for e in self.events:
            h.update(f"{e.t_s:.9f}|{e.kind}|{e.model}".encode())
        for name in sorted(self.faults or {}):
            for f in self.faults[name]:
                h.update(f"{name}|{f.kind}|{f.t_s:.9f}|{f.duration_s:.9f}"
                         f"|{f.magnitude:.9f}".encode())
        return h.hexdigest()


# -- arrival processes --------------------------------------------------------


def poisson_arrivals(n: int, rate_qps: float, seed: int = 0) -> List[float]:
    """``n`` Poisson arrival times at ``rate_qps`` queries per (modeled)
    second, starting at t=0+."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate_qps, 1e-9), size=n)
    return list(np.cumsum(gaps))


def mmpp_arrivals(n: int, seed: int = 0, calm_qps: float = 12.0,
                  burst_qps: float = 120.0, mean_calm_run: int = 40,
                  mean_burst_run: int = 60) -> List[float]:
    """Two-state Markov-modulated Poisson arrivals: calm stretches at
    ``calm_qps`` alternate with flash crowds at ``burst_qps``; run lengths
    (in queries) are geometric with the given means.  Fully seeded."""
    rng = np.random.default_rng(seed)
    times: List[float] = []
    t = 0.0
    in_burst, remaining = False, 0
    for _ in range(n):
        if remaining <= 0:
            in_burst = not in_burst
            mean_run = mean_burst_run if in_burst else mean_calm_run
            remaining = int(rng.geometric(1.0 / max(mean_run, 1)))
        remaining -= 1
        rate = burst_qps if in_burst else calm_qps
        t += float(rng.exponential(1.0 / rate))
        times.append(t)
    return times


def _diurnal_fn(span_s: float, amplitude: float,
                days: float = 1.5) -> Callable[[float], float]:
    """A diurnal carbon signal whose period packs ``days`` full cycles
    into the run's expected span — paper-scale runs last modeled minutes,
    not actual days, so the cycle is compressed to stay observable."""
    period = max(span_s / max(days, 1e-6), 1e-6)
    return lambda t_s: diurnal_carbon_intensity(t_s, amplitude=amplitude,
                                                period_s=period)


# -- scenario generators ------------------------------------------------------


def steady(per_task: int = 100, seed: int = 0, rate_qps: float = 24.0,
           carbon_amplitude: float = 0.0) -> Scenario:
    """Steady Poisson traffic over the paper's 5-task stream; the neutral
    baseline shape (``bench_baselines`` runs its headline here)."""
    queries = make_stream(per_task=per_task, seed=seed)
    arrivals = poisson_arrivals(len(queries), rate_qps, seed=seed + 1)
    carbon = (_diurnal_fn(arrivals[-1], carbon_amplitude)
              if carbon_amplitude > 0 else None)
    return Scenario(name="steady", queries=queries, arrivals_s=arrivals,
                    carbon_fn=carbon)


def flash_crowd(per_task: int = 100, seed: int = 0, calm_qps: float = 12.0,
                burst_qps: float = 120.0, carbon_amplitude: float = 0.3
                ) -> Scenario:
    """Diurnal carbon + MMPP flash crowds: calm load near the pool's
    service rate, bursts ~10x past it — the regime where admission
    planning and budget governance (not raw capacity) set the outcome."""
    queries = make_stream(per_task=per_task, seed=seed)
    arrivals = mmpp_arrivals(len(queries), seed=seed + 1, calm_qps=calm_qps,
                             burst_qps=burst_qps)
    return Scenario(name="flash_crowd", queries=queries, arrivals_s=arrivals,
                    carbon_fn=_diurnal_fn(arrivals[-1], carbon_amplitude))


def duplicate_flood(per_task: int = 60, seed: int = 0, n_hot: int = 8,
                    dup_factor: int = 6, rate_qps: float = 30.0) -> Scenario:
    """Adversarial near-duplicate flood against the semantic cache: a base
    stream plus ``n_hot`` hot queries each replayed ``dup_factor`` times
    with small textual perturbations (politeness suffixes, whitespace) —
    close enough in embedding space to hit the similarity lookup, so the
    cache serves the flood at zero engine work.  Duplicates inherit the
    hot query's task and reference (the cached answer is genuinely
    correct for them)."""
    rng = random.Random(seed + 2)
    base = make_stream(per_task=per_task, seed=seed)
    hot = rng.sample(base, min(n_hot, len(base)))
    suffixes = [" Thanks!", " Please answer.", "  ", " (urgent)",
                " Appreciate it.", " Respond concisely."]
    flood: List[Query] = []
    uid = len(base)
    for q in hot:
        for _ in range(dup_factor):
            flood.append(Query(uid=uid, text=q.text + rng.choice(suffixes),
                               task=q.task, reference=q.reference,
                               max_new_tokens=q.max_new_tokens))
            uid += 1
    queries = list(base)
    # splice the flood in as bursts right after the mid-point, so the hot
    # originals have completed (and been inserted) before their duplicates
    mid = len(base) // 2
    queries[mid:mid] = flood
    queries = [dataclasses.replace(q, uid=i) if q.uid != i else q
               for i, q in enumerate(queries)]
    arrivals = poisson_arrivals(len(queries), rate_qps, seed=seed + 3)
    return Scenario(name="duplicate_flood", queries=queries,
                    arrivals_s=arrivals)


def pool_churn(per_task: int = 60, seed: int = 0, rate_qps: float = 24.0,
               kill_model: str = "qwen2.5-14b", kill_frac: float = 0.3,
               add_model: str = "gemma-3-12b",
               add_frac: float = 0.5) -> Scenario:
    """Model-pool churn mid-run: ``kill_model`` fails at ``kill_frac`` of
    the arrival span (its in-flight requests must be re-routed, nothing
    lost) and the held-out ``add_model`` (paper §6.2.4) joins at
    ``add_frac`` via ``add_engine`` — a fresh bandit arm with zero
    offline calibration."""
    queries = make_stream(per_task=per_task, seed=seed)
    arrivals = poisson_arrivals(len(queries), rate_qps, seed=seed + 1)
    span = arrivals[-1]
    events = [PoolEvent(t_s=kill_frac * span, kind="kill", model=kill_model),
              PoolEvent(t_s=add_frac * span, kind="add", model=add_model)]
    return Scenario(name="pool_churn", queries=queries, arrivals_s=arrivals,
                    events=events, exclude=[add_model])


def chaos(per_task: int = 60, seed: int = 0, rate_qps: float = 12.0,
          targets: tuple = ("qwen2.5-14b", "mistral-7b"),
          background_models: tuple = ("llama-3.1-8b", "gemma-3-4b"),
          background_rate: float = 0.5, frac_start: float = 0.35,
          frac_end: float = 0.85, n_crashes: int = 4) -> Scenario:
    """Chaos drill: steady Poisson traffic while a seeded fault storm
    (``serving.faults.fault_storm``) batters the pool — each ``targets``
    engine serves garbage (NaN-grade, zero-accuracy) output through the
    same mid-run window and crashes twice inside it, while
    ``background_models`` pick up Poisson-placed stalls and slow-step
    episodes.  The default targets are the arms the converged bandit
    leans on hardest (one big, one small), so the storm hits real
    traffic — the regime where deadlines, retries, and per-arm circuit
    breakers (not raw capacity) set the outcome: with the reliability
    layer off, the router keeps feeding the poisoned arms until their
    zero-accuracy completions wash back through the bandit."""
    from repro.serving.faults import fault_storm  # avoid an import cycle
    queries = make_stream(per_task=per_task, seed=seed)
    arrivals = poisson_arrivals(len(queries), rate_qps, seed=seed + 1)
    faults: dict = {}
    for i, target in enumerate(targets):
        storm = fault_storm(
            span_s=arrivals[-1], target=target,
            # background faults ride along with the first storm only
            others=list(background_models) if i == 0 else [],
            seed=seed + 2 + i, frac_start=frac_start, frac_end=frac_end,
            n_crashes=n_crashes, background_rate=background_rate)
        for name, specs in storm.items():
            faults.setdefault(name, []).extend(specs)
    return Scenario(name="chaos", queries=queries, arrivals_s=arrivals,
                    faults=faults)


__all__ = ["PoolEvent", "Scenario", "poisson_arrivals", "mmpp_arrivals",
           "steady", "flash_crowd", "duplicate_flood", "pool_churn",
           "chaos"]
