"""Data substrate: query streams, behaviour profiles, tokenizer, RouterBench."""
from repro.data import profiles, routerbench, stream, tokenizer
from repro.data.profiles import (ENERGY_SCALE_WH, OutcomeSimulator,
                                 mean_accuracy, mean_energy_mwh)
from repro.data.stream import labeled_sample, make_query, make_stream

__all__ = ["profiles", "routerbench", "stream", "tokenizer",
           "ENERGY_SCALE_WH", "OutcomeSimulator", "mean_accuracy",
           "mean_energy_mwh", "labeled_sample", "make_query", "make_stream"]
