"""Synthetic 5-task query stream mirroring the paper's benchmark mix.

500 instances per dataset family (MMLU / HellaSwag / Winogrande / GSM8K /
CNN-DailyMail), shuffled into a T=2,500 stream with a fixed seed (paper
§6.1.2).  Templates are designed so the three context features carry real
signal: instruction lines differ per task (task classifier), topical
vocabulary differs (semantic clusters), and sentence structure differs
(Flesch complexity — math word problems read easy, news summaries read
hard).
"""
from __future__ import annotations

import random
from typing import List, Optional

from repro.core.types import Query, TaskType

_TOPICS = {
    "science": ["photosynthesis", "entropy", "mitochondria", "quantum field",
                "tectonic plates", "neural synapse", "catalyst", "osmosis"],
    "history": ["the industrial revolution", "the treaty of versailles",
                "the roman senate", "the silk road", "the cold war",
                "the printing press", "the french revolution"],
    "finance": ["compound interest", "market liquidity", "federal reserve",
                "inflation target", "sovereign bond", "hedge fund",
                "quarterly earnings", "exchange rate"],
}

_QA_TMPL = (
    "Answer the following multiple choice question.\n"
    "Question: Which statement about {topic} is correct?\n"
    "A) {topic} only occurs in {noun}.\nB) {topic} is unrelated to {noun2}.\n"
    "C) {topic} fundamentally involves {noun2}.\nD) none of the above.\n"
    "Answer:")
_HELLA_TMPL = (
    "Choose the most plausible continuation.\n"
    "Context: A person studying {topic} opened their notes and {verb}.\n"
    "Options: 1) {cont1} 2) {cont2} 3) {cont3} 4) {cont4}\nBest option:")
_WINO_TMPL = (
    "Resolve the pronoun in the sentence.\n"
    "Sentence: The teacher explained {topic} to the student because _ "
    "prepared a lesson about {noun}.\nWho does the blank refer to?")
_GSM_TMPL = (
    "Solve the math word problem step by step.\n"
    "Problem: Sam buys {a} pens for {b} dollars each and {c} notebooks for "
    "{d} dollars each. He pays with a {e} dollar bill. How much change does "
    "Sam get back?\nAnswer:")
_SUMM_TMPL = (
    "Summarize the following article in three sentences.\n"
    "Article: Authorities announced on {day} that the committee overseeing "
    "{topic} had concluded its preliminary investigation into {noun}, "
    "citing considerable uncertainty surrounding implementation timelines; "
    "nevertheless, representatives emphasised that infrastructure "
    "modernisation, regulatory harmonisation, and institutional "
    "accountability remain indispensable prerequisites. {filler}\nSummary:")

_NOUNS = ["plants", "markets", "archives", "laboratories", "parliaments",
          "networks", "institutions", "reactors"]
_VERBS = ["began reviewing the diagrams", "recited the definitions",
          "sketched the process", "quizzed a classmate"]
_CONTS = ["they summarized each section aloud",
          "the notebook transformed into a bird",
          "they rehearsed the key formulas",
          "the desk started a conversation"]
_DAYS = ["Monday", "Tuesday", "Wednesday", "Thursday", "Friday"]
_FILLER = ("Observers characterised the deliberations as unprecedented, "
           "noting that comprehensive documentation would be disseminated "
           "to stakeholders following additional consultation.")


def make_query(uid: int, task: TaskType, rng: random.Random) -> Query:
    domain = rng.choice(list(_TOPICS))
    topic = rng.choice(_TOPICS[domain])
    noun = rng.choice(_NOUNS)
    noun2 = rng.choice([n for n in _NOUNS if n != noun])
    if task == TaskType.QA:
        text = _QA_TMPL.format(topic=topic, noun=noun, noun2=noun2)
        ref = "C"
    elif task == TaskType.COMPLETION:
        conts = rng.sample(_CONTS, 4)
        text = _HELLA_TMPL.format(topic=topic, verb=rng.choice(_VERBS),
                                  cont1=conts[0], cont2=conts[1],
                                  cont3=conts[2], cont4=conts[3])
        ref = "1"
    elif task == TaskType.REASONING:
        text = _WINO_TMPL.format(topic=topic, noun=noun)
        ref = "the teacher"
    elif task == TaskType.MATH:
        a, b, c, d = (rng.randint(2, 9) for _ in range(4))
        total = a * b + c * d
        e = ((total // 10) + 1) * 10
        text = _GSM_TMPL.format(a=a, b=b, c=c, d=d, e=e)
        ref = str(e - total)
    else:
        text = _SUMM_TMPL.format(day=rng.choice(_DAYS), topic=topic,
                                 noun=noun, filler=_FILLER)
        ref = f"The committee on {topic} concluded its investigation."
    max_new = {TaskType.QA: 8, TaskType.COMPLETION: 8, TaskType.REASONING: 4,
               TaskType.MATH: 96, TaskType.SUMMARIZATION: 128}[task]
    return Query(uid=uid, text=text, task=task, reference=ref,
                 max_new_tokens=max_new)


def make_stream(per_task: int = 500, seed: int = 0,
                tasks: Optional[List[TaskType]] = None) -> List[Query]:
    """The paper's evaluation stream: per_task instances of each family,
    shuffled with a fixed seed (T = 5 × per_task = 2,500 by default)."""
    rng = random.Random(seed)
    tasks = tasks or list(TaskType)
    queries: List[Query] = []
    uid = 0
    for task in tasks:
        for _ in range(per_task):
            queries.append(make_query(uid, task, rng))
            uid += 1
    rng.shuffle(queries)
    return queries


def labeled_sample(n_per_task: int = 40, seed: int = 1):
    """Small labeled sample for the task classifier's offline fit (§4.2.1:
    'we sample a small portion of our evaluation dataset')."""
    rng = random.Random(seed)
    texts, labels = [], []
    uid = 0
    for task in TaskType:
        for _ in range(n_per_task):
            texts.append(make_query(uid, task, rng).text)
            labels.append(int(task))
            uid += 1
    return texts, labels
