"""Byte-level tokenizer (vocab 256 + specials) for the real-model examples."""
from __future__ import annotations

from typing import List

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
_OFFSET = 3
VOCAB_SIZE = 256 + _OFFSET


def encode(text: str, add_bos: bool = True) -> List[int]:
    ids = [b + _OFFSET for b in text.encode("utf-8", errors="replace")]
    return ([BOS_ID] + ids) if add_bos else ids


def decode(ids: List[int]) -> str:
    return bytes(max(i - _OFFSET, 0) for i in ids
                 if i >= _OFFSET).decode("utf-8", errors="replace")
