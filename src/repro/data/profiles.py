"""Per-(model, task) behaviour tables calibrated to the paper's aggregates.

The paper measures 16 open-weight models on 5 benchmarks with an A100 power
meter; this container has neither the weights nor the GPU, so the decision
problem is reproduced from calibrated tables: mean normalized accuracy per
(model, task) and an analytic energy model per query.  Calibration targets
(paper §6.3, Fig. 2–3): random ≈ 0.51 acc / ~96 mWh/query; smallest
(qwen-0.5b) ≈ 0.33; largest (yi-34b) ≈ 0.39; best (gemma-3-27b) ≈ 0.74 at
the highest energy; GreenServ at λ=0.4 reaches ≈ 0.65 at ~66 mWh/query.
``tests/test_paper_claims.py`` asserts these aggregates.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.types import Query, TaskType

# --- mean normalized accuracy per (model, task) ------------------------------
# columns: QA(mmlu)  COMPLETION(hellaswag)  REASONING(winogrande)  MATH(gsm8k)
#          SUMMARIZATION(cnn/dm rouge, min-max normalized)
ACCURACY: Dict[str, Tuple[float, float, float, float, float]] = {
    "qwen2.5-0.5b":  (0.28, 0.36, 0.48, 0.12, 0.40),
    "qwen2.5-1.5b":  (0.35, 0.42, 0.52, 0.22, 0.42),
    "qwen2.5-3b":    (0.45, 0.52, 0.58, 0.38, 0.48),
    "qwen2.5-7b":    (0.55, 0.62, 0.64, 0.52, 0.55),
    "qwen2.5-14b":   (0.63, 0.70, 0.70, 0.64, 0.60),
    "mistral-7b":    (0.50, 0.60, 0.62, 0.40, 0.55),
    "gemma-3-1b":    (0.30, 0.38, 0.48, 0.18, 0.45),
    "gemma-3-4b":    (0.52, 0.58, 0.62, 0.46, 0.58),
    "gemma-3-12b":   (0.66, 0.72, 0.72, 0.66, 0.66),
    "gemma-3-27b":   (0.74, 0.78, 0.76, 0.74, 0.70),
    "llama-3.1-1b":  (0.28, 0.36, 0.46, 0.14, 0.40),
    "llama-3.2-3b":  (0.46, 0.54, 0.60, 0.40, 0.52),
    "llama-3.1-8b":  (0.58, 0.66, 0.68, 0.56, 0.60),
    "phi-4-mini-4b": (0.56, 0.60, 0.62, 0.60, 0.50),
    "phi-4-14b":     (0.68, 0.70, 0.70, 0.72, 0.58),
    "yi-34b":        (0.36, 0.44, 0.50, 0.22, 0.42),
}

PARAMS_B: Dict[str, float] = {
    "qwen2.5-0.5b": 0.5, "qwen2.5-1.5b": 1.5, "qwen2.5-3b": 3.0,
    "qwen2.5-7b": 7.0, "qwen2.5-14b": 14.0, "mistral-7b": 7.0,
    "gemma-3-1b": 1.0, "gemma-3-4b": 4.0, "gemma-3-12b": 12.0,
    "gemma-3-27b": 27.0, "llama-3.1-1b": 1.0, "llama-3.2-3b": 3.0,
    "llama-3.1-8b": 8.0, "phi-4-mini-4b": 4.0, "phi-4-14b": 14.0,
    "yi-34b": 34.0,
}

# per-model energy multiplier (gemma's 262k-vocab head and long outputs make
# it the most expensive family in the paper's Table 3; yi is comparatively
# efficient per parameter)
ENERGY_MULT: Dict[str, float] = {
    "gemma-3-1b": 1.35, "gemma-3-4b": 1.35, "gemma-3-12b": 1.35,
    "gemma-3-27b": 1.33, "yi-34b": 0.68,
}

# (input_tokens, output_tokens) per task family
TASK_TOKENS: Dict[TaskType, Tuple[int, int]] = {
    TaskType.QA: (256, 8),
    TaskType.COMPLETION: (128, 8),
    TaskType.REASONING: (64, 4),
    TaskType.MATH: (128, 96),
    TaskType.SUMMARIZATION: (1024, 128),
}

MWH_PER_B_PER_OUT_TOKEN = 0.15
MWH_PER_B_PER_IN_TOKEN = 0.002
MWH_FIXED_OVERHEAD = 8.0


def mean_energy_mwh(model: str, task: TaskType) -> float:
    p = PARAMS_B[model]
    tin, tout = TASK_TOKENS[task]
    e = (MWH_FIXED_OVERHEAD + p * tout * MWH_PER_B_PER_OUT_TOKEN +
         p * tin * MWH_PER_B_PER_IN_TOKEN)
    return e * ENERGY_MULT.get(model, 1.0)


def mean_accuracy(model: str, task: TaskType) -> float:
    return ACCURACY[model][int(task)]


class OutcomeSimulator:
    """Stochastic per-query outcomes: Bernoulli EM accuracy (clipped-normal
    ROUGE for summarization), jittered energy and latency."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def __call__(self, query: Query, model: str):
        task = query.task if query.task is not None else TaskType.QA
        mean_acc = mean_accuracy(model, task)
        if task == TaskType.SUMMARIZATION:
            acc = float(np.clip(self.rng.normal(mean_acc, 0.12), 0.0, 1.0))
        else:
            acc = float(self.rng.random() < mean_acc)
        e = mean_energy_mwh(model, task) * self.rng.uniform(0.85, 1.2) / 1e3
        tin, tout = TASK_TOKENS[task]
        latency_ms = (18.0 + 4.5 * PARAMS_B[model]
                      + (0.9 * PARAMS_B[model] + 0.5) * tout
                      ) * self.rng.uniform(0.9, 1.3)
        return acc, e, latency_ms, tout

    def oracle_tables(self, pool_names, task: TaskType):
        """(acc_by_model, energy_by_model) mean tables for regret oracles."""
        acc = np.array([mean_accuracy(m, task) for m in pool_names])
        energy = np.array([mean_energy_mwh(m, task) / 1e3
                           for m in pool_names])
        return acc, energy


# recommended reward normalization so λ interpolates meaningfully between
# [0,1]-accuracy and Wh-energy (paper normalizes accuracy only).  0.45 puts
# the λ=0.4 oracle at acc≈0.68 / ≈155 Wh per 2,500 queries — the paper's
# GreenServ operating point (Fig. 2a).
ENERGY_SCALE_WH = 0.45
