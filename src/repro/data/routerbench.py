"""RouterBench-style offline evaluation (paper §6.3.6, Table 1).

RouterBench distributes a pre-computed (query × model) matrix of accuracy
and cost — routers are evaluated offline by lookup, no inference.  The real
matrices aren't redistributable here, so we synthesize matrices with the
same structure: 9 task families × 11 router-pool models, per-(task, model)
mean accuracies in the public benchmark's range (best single model ≈ 0.75,
worst ≈ 0.35), per-query Bernoulli draws, and per-1k-token cost proxies.

AIQ (Average Improvement in Quality) follows the benchmark's definition:
the area under the non-decreasing quality-vs-cost envelope swept by the
willingness-to-pay parameter, normalized by the cost span.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

RB_TASKS = ["mmlu", "hellaswag", "winogrande", "gsm8k", "arc", "mbpp",
            "mtbench", "rag", "commonsense"]

# (mean accuracy per task row below, cost $/1k tokens)
RB_MODELS: Dict[str, Tuple[float, float]] = {
    "rb-frontier-a": (0.78, 30.0),    # gpt-4-class
    "rb-frontier-b": (0.74, 24.0),
    "rb-mid-a": (0.70, 8.0),
    "rb-mid-b": (0.68, 6.0),
    "rb-mid-c": (0.64, 3.0),
    "rb-open-13b": (0.58, 1.2),
    "rb-open-7b-a": (0.52, 0.6),
    "rb-open-7b-b": (0.50, 0.6),
    "rb-small-a": (0.44, 0.3),
    "rb-small-b": (0.40, 0.25),
    "rb-tiny": (0.33, 0.1),
}


@dataclasses.dataclass
class RouterBenchTable:
    tasks: List[str]
    models: List[str]
    accuracy: np.ndarray        # (Q, M) binary outcomes
    cost: np.ndarray            # (Q, M) $ per query
    task_of: np.ndarray         # (Q,) task index
    mean_acc: np.ndarray        # (T, M) the latent means

    @property
    def n_queries(self) -> int:
        return self.accuracy.shape[0]


def build_table(n_per_task: int = 400, seed: int = 0) -> RouterBenchTable:
    rng = np.random.default_rng(seed)
    models = list(RB_MODELS)
    t, m = len(RB_TASKS), len(models)
    base = np.array([RB_MODELS[name][0] for name in models])
    # per-task specialization: ±0.08, deterministic per (task, model)
    spec = rng.uniform(-0.08, 0.08, size=(t, m))
    mean_acc = np.clip(base[None, :] + spec, 0.05, 0.95)
    cost_1k = np.array([RB_MODELS[name][1] for name in models])
    tokens_per_query = rng.uniform(0.4, 2.0, size=(t,))   # k-tokens per task

    q = n_per_task * t
    task_of = np.repeat(np.arange(t), n_per_task)
    rng.shuffle(task_of)
    acc = (rng.random((q, m)) < mean_acc[task_of]).astype(np.float64)
    cost = (cost_1k[None, :] * tokens_per_query[task_of][:, None]
            * rng.uniform(0.8, 1.25, size=(q, m)))
    return RouterBenchTable(tasks=list(RB_TASKS), models=models,
                            accuracy=acc, cost=cost, task_of=task_of,
                            mean_acc=mean_acc)


def query_text(table: RouterBenchTable, i: int) -> str:
    """Synthetic text whose instruction line identifies the task family —
    the same signal the real benchmark's prompts carry."""
    t = table.tasks[table.task_of[i]]
    return (f"Complete the {t} benchmark item.\n"
            f"Task {t} instance {i}: choose or produce the correct answer.")


def aiq(points: Sequence[Tuple[float, float]]) -> float:
    """Area under the non-decreasing quality/cost envelope, cost-normalized.

    ``points``: (mean_cost, mean_quality) per willingness-to-pay setting.
    """
    pts = sorted(points)
    if len(pts) < 2:
        return 0.0
    costs = np.array([p[0] for p in pts])
    quals = np.maximum.accumulate(np.array([p[1] for p in pts]))
    span = costs[-1] - costs[0]
    if span <= 0:
        return float(quals.max())
    area = np.trapezoid(quals, costs)
    return float(area / span)
