"""Core datatypes shared across the GreenServ framework.

These are deliberately plain dataclasses (no flax deps): router state that
must cross the jit boundary lives in explicit pytrees in ``bandits.py``.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Any, Mapping, Optional, Sequence


class TaskType(enum.IntEnum):
    """The five benchmark task families used in the paper (§6.1.2)."""

    QA = 0            # MMLU-style multiple-choice question answering
    COMPLETION = 1    # HellaSwag-style situation completion
    REASONING = 2     # Winogrande-style commonsense reasoning
    MATH = 3          # GSM8K-style math word problems
    SUMMARIZATION = 4 # CNN/DailyMail-style summarization

    @classmethod
    def names(cls) -> Sequence[str]:
        return [t.name.lower() for t in cls]


N_TASKS = len(TaskType)


@dataclasses.dataclass(frozen=True)
class Query:
    """A single inference request in the stream {q_t}."""

    uid: int
    text: str
    task: Optional[TaskType] = None       # ground-truth task label (hidden from router)
    reference: Optional[str] = None       # ground-truth answer for accuracy eval
    max_new_tokens: int = 64
    latency_budget_ms: float = float("inf")
    arrival_s: float = 0.0

    def __post_init__(self) -> None:
        if not self.text:
            raise ValueError("Query.text must be non-empty")


@dataclasses.dataclass(frozen=True)
class ContextVector:
    """The structured context x_t = [task, cluster, complexity] (paper §4.2.4)."""

    task_label: int
    cluster: int
    complexity_bin: int
    complexity_score: float
    vector: Any  # np.ndarray one-hot + intercept, shape (d,)

    @property
    def dim(self) -> int:
        return int(self.vector.shape[0])


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Outcome of one routing step: which arm, with what expected scores."""

    query_uid: int
    model_index: int
    model_name: str
    context: ContextVector
    ucb_scores: Any            # per-arm scores at decision time (masked arms = -inf)
    feasible_mask: Any         # bool per arm
    overhead_ms: float         # feature extraction + bandit decision time
    timestamp_s: float = dataclasses.field(default_factory=time.time)


@dataclasses.dataclass(frozen=True)
class Feedback:
    """Observed partial feedback for the selected arm only (paper §3.2.2)."""

    query_uid: int
    model_index: int
    accuracy: float            # normalized to [0, 1]
    energy_wh: float           # measured/modeled energy for this query
    latency_ms: float
    input_tokens: int = 0
    output_tokens: int = 0


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Static metadata for a pool member (the router never peeks at accuracy)."""

    name: str
    family: str
    params_b: float                       # billions of parameters
    arch_config: Optional[Any] = None     # models.ModelConfig when backed by a real model
    # conservative latency estimate used by the feasibility filter (paper §4.3:
    # MaxNewTokens-based estimate); ms per generated token + fixed prefill cost.
    ms_per_token: float = 10.0
    prefill_ms: float = 50.0
    placement: Optional[str] = None       # mesh slice label for pool placement

    def latency_estimate_ms(self, max_new_tokens: int) -> float:
        return self.prefill_ms + self.ms_per_token * max_new_tokens


@dataclasses.dataclass
class RouterConfig:
    """Hyperparameters (paper §6.1.5 defaults)."""

    lam: float = 0.4                  # λ accuracy/energy trade-off
    alpha_ucb: float = 0.1            # LinUCB exploration coefficient
    lambda_reg: float = 0.05          # ridge prior on A_m
    epsilon0: float = 1.0             # ε-greedy initial exploration
    epsilon_decay: float = 0.98
    epsilon_min: float = 0.01
    cts_sigma: float = 0.01           # Thompson sampling posterior scale
    n_clusters: int = 3               # K for online k-means
    n_complexity_bins: int = 3        # N_bins for Flesch binning
    n_tasks: int = N_TASKS
    max_arms: int = 64                # static capacity for jit-stable shapes
    energy_scale_wh: float = 1.0      # energy normalization divisor in reward
    algorithm: str = "linucb"         # linucb | cts | eps_greedy | eps_greedy_ctx
    solve_mode: str = "sherman_morrison"  # paper-faithful alternative: "cholesky"
    # featurization placement: "device" runs the fused featurize→score
    # pipeline (kernels/featurize + kernels/linucb in one jitted call),
    # "host" is the reference numpy path, "auto" picks device only where
    # the Pallas kernels have a compiled fast path (TPU — elsewhere they
    # run in interpret mode, a correctness tool, not a fast path).  Both
    # paths agree (parity suite: tests/test_featurize_parity.py);
    # stochastic bandit algorithms and the cholesky solve mode always
    # fall back to host.
    featurize: str = "auto"           # auto | host | device
    seed: int = 0

    def resolve_featurize_device(self) -> bool:
        """True when the device featurize→score pipeline should run."""
        if self.featurize == "host":
            return False
        if self.featurize == "device":
            return True
        if self.featurize != "auto":
            raise ValueError(
                f"featurize must be auto|host|device, got {self.featurize!r}")
        import jax
        return jax.default_backend() == "tpu"

    @property
    def context_dim(self) -> int:
        # one-hot task + one-hot cluster + one-hot complexity bin + intercept
        return self.n_tasks + self.n_clusters + self.n_complexity_bins + 1


def validate_unit_interval(x: float, name: str) -> float:
    if not (0.0 <= x <= 1.0):
        raise ValueError(f"{name} must be in [0, 1], got {x}")
    return x
