"""Heterogeneous model pool registry with runtime addition (paper §4.4)."""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.types import ModelProfile, Query


class ModelPool:
    """Ordered registry of pool members; index == bandit arm index.

    Thread-safe: the serving scheduler adds models from a control thread
    while the router reads the pool on the request path.
    """

    def __init__(self, profiles: Optional[List[ModelProfile]] = None):
        self._lock = threading.RLock()
        self._profiles: List[ModelProfile] = []
        self._by_name: Dict[str, int] = {}
        self._listeners: List[Callable[[ModelProfile, int], None]] = []
        for p in profiles or []:
            self.add(p)

    def __len__(self) -> int:
        with self._lock:
            return len(self._profiles)

    def __getitem__(self, idx: int) -> ModelProfile:
        with self._lock:
            return self._profiles[idx]

    def index_of(self, name: str) -> int:
        with self._lock:
            return self._by_name[name]

    @property
    def names(self) -> List[str]:
        with self._lock:
            return [p.name for p in self._profiles]

    def on_add(self, fn: Callable[[ModelProfile, int], None]) -> None:
        """Register a callback fired when a model joins (router adds an arm)."""
        self._listeners.append(fn)

    def add(self, profile: ModelProfile) -> int:
        with self._lock:
            if profile.name in self._by_name:
                raise ValueError(f"duplicate model {profile.name!r}")
            idx = len(self._profiles)
            self._profiles.append(profile)
            self._by_name[profile.name] = idx
        for fn in self._listeners:
            fn(profile, idx)
        return idx

    def feasible_mask(self, query: Query) -> np.ndarray:
        """Eq. 4: M_t* = {m : L_m(q_t) <= L_max}. Conservative latency estimate
        uses MaxNewTokens for the query's task (paper §4.3 State Extractor)."""
        with self._lock:
            mask = np.array(
                [p.latency_estimate_ms(query.max_new_tokens) <= query.latency_budget_ms
                 for p in self._profiles], dtype=bool)
        if mask.size and not mask.any():
            # If nothing is feasible the paper discards the query; serving
            # systems must answer — degrade to the fastest model instead.
            with self._lock:
                fastest = int(np.argmin(
                    [p.latency_estimate_ms(query.max_new_tokens) for p in self._profiles]))
            mask[fastest] = True
        return mask
