"""Reward scalarization + regret accounting (paper §3.2.1–3.2.2, Eq. 5–8, 12)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.types import RouterConfig


def scalarize(accuracy: float, energy_wh: float, lam: float,
              energy_scale_wh: float = 1.0) -> float:
    """Eq. 5: r = α·Acc − β·C  with α = 1−λ, β = λ.

    Energy is normalized by ``energy_scale_wh`` so both objectives share the
    [0, 1] scale before weighting (the paper normalizes accuracy and measures
    energy in Wh; a fixed divisor keeps the trade-off λ interpretable).
    """
    alpha, beta = 1.0 - lam, lam
    return alpha * float(accuracy) - beta * float(energy_wh) / energy_scale_wh


@dataclasses.dataclass
class RegretTracker:
    """Cumulative + instantaneous regret against the per-step oracle (Eq. 6–8).

    The oracle reward must be supplied by the evaluation harness (it requires
    counterfactual knowledge of every arm — available in simulation and in
    RouterBench-style offline matrices, unavailable in live serving).
    """

    cumulative: float = 0.0
    history: List[float] = dataclasses.field(default_factory=list)

    def step(self, chosen_reward: float, oracle_reward: float) -> float:
        inst = max(oracle_reward - chosen_reward, 0.0)
        self.cumulative += inst
        self.history.append(inst)
        return inst

    def moving_average(self, window: int = 50) -> np.ndarray:
        h = np.asarray(self.history, dtype=np.float64)
        if h.size == 0:
            return h
        kernel = np.ones(min(window, h.size)) / min(window, h.size)
        return np.convolve(h, kernel, mode="valid")

    def cumulative_curve(self) -> np.ndarray:
        return np.cumsum(np.asarray(self.history, dtype=np.float64))


class RewardManager:
    """Computes scalarized rewards and tracks running statistics."""

    def __init__(self, config: RouterConfig):
        self.config = config
        self.total_accuracy = 0.0
        self.total_energy_wh = 0.0
        self.n = 0

    def reward(self, accuracy: float, energy_wh: float) -> float:
        self.total_accuracy += accuracy
        self.total_energy_wh += energy_wh
        self.n += 1
        return scalarize(accuracy, energy_wh, self.config.lam,
                         self.config.energy_scale_wh)

    @property
    def mean_accuracy(self) -> float:
        return self.total_accuracy / max(self.n, 1)
