"""Query Context Generator (paper §4.2).

Three feature extractors feed the context vector x_t = [l_t, c_t, p_t]:

  * TaskClassifier   — logistic regression over instruction embeddings (§4.2.1)
  * OnlineKMeans     — cosine-assignment online k-means over full-query
                       embeddings with incremental centroid updates (§4.2.2,
                       Eq. 9–10)
  * FleschComplexity — Flesch Reading Ease (Eq. 11) + equal-width binning
                       (§4.2.3)

Categorical features are one-hot encoded with an intercept appended (§4.2.4):
d = N_tasks + K + N_bins + 1.

Two featurization placements share this module (``RouterConfig.featurize``):
the host numpy path (``ContextGenerator.batch`` — the reference
implementation) and the device path, whose pieces live here —
``kmeans_update_scan`` replays the Eq. 10 sequential centroid updates as a
``lax.scan`` in arrival order, ``kmeans_assign_batch`` is the read-only
probe assignment, and ``_probe_pipeline`` fuses featurize+classify+assign
for the scheduler's cache probe.  The router composes them with the
``kernels/featurize`` and ``kernels/linucb`` Pallas kernels into one jitted
decision program; the two placements agree exactly
(tests/test_featurize_parity.py).
"""
from __future__ import annotations

import functools
import re
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.embedding import EmbeddingModel, tokenize
from repro.core.residency import TransferLedger
from repro.core.types import ContextVector, N_TASKS, RouterConfig


def _sync(x):
    """Block until device values are materialized — every ``timings_ms`` /
    overhead timestamp must sit *after* a sync, or JAX's async dispatch
    makes the reported per-query featurization overhead optimistic (the
    clock would stop at enqueue, not completion).  Tolerates numpy/python
    leaves; syncs only where timestamps are taken, never mid-pipeline."""
    return jax.block_until_ready(x)

# ---------------------------------------------------------------------------
# Task classifier: LR over embeddings, trained with full-batch Adam in JAX.
# ---------------------------------------------------------------------------


def _lr_loss(params, x, y, n_classes, l2=1e-4):
    w, b = params
    logits = x @ w + b
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return nll + l2 * jnp.sum(w * w)


@jax.jit
def _lr_predict_logits(w, b, x):
    return x @ w + b


class TaskClassifier:
    """Lightweight LR task-type classifier (paper §4.2.1).

    The instruction text is taken from the first lines of the prompt,
    embedded, and classified into one of N_TASKS labels.
    """

    def __init__(self, embedder: EmbeddingModel, n_classes: int = N_TASKS,
                 instr_lines: int = 2, seed: int = 0):
        self.embedder = embedder
        self.n_classes = n_classes
        self.instr_lines = instr_lines
        rng = np.random.default_rng(seed)
        self.w = jnp.asarray(rng.standard_normal((embedder.dim, n_classes)) * 0.01,
                             dtype=jnp.float32)
        self.b = jnp.zeros((n_classes,), dtype=jnp.float32)
        self._trained = False

    def instruction_text(self, text: str) -> str:
        lines = [ln for ln in text.splitlines() if ln.strip()]
        return " ".join(lines[: self.instr_lines]) if lines else text

    def fit(self, texts: Sequence[str], labels: Sequence[int],
            steps: int = 300, lr: float = 0.05) -> float:
        """Train with full-batch Adam; returns final training accuracy."""
        x = jnp.asarray(self.embedder.encode_batch(
            [self.instruction_text(t) for t in texts]))
        y = jnp.asarray(np.asarray(labels, dtype=np.int32))
        params = (self.w, self.b)
        m = jax.tree.map(jnp.zeros_like, params)
        v = jax.tree.map(jnp.zeros_like, params)
        loss_grad = jax.jit(jax.value_and_grad(
            lambda p: _lr_loss(p, x, y, self.n_classes)))

        @jax.jit
        def adam_step(params, m, v, t):
            loss, g = loss_grad(params)
            m = jax.tree.map(lambda a, b_: 0.9 * a + 0.1 * b_, m, g)
            v = jax.tree.map(lambda a, b_: 0.999 * a + 0.001 * b_ * b_, v, g)
            mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
            vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
            params = jax.tree.map(
                lambda p, a, b_: p - lr * a / (jnp.sqrt(b_) + 1e-8), params, mh, vh)
            return params, m, v, loss

        for t in range(1, steps + 1):
            params, m, v, _ = adam_step(params, m, v, jnp.float32(t))
        self.w, self.b = params
        self._trained = True
        pred = np.argmax(np.asarray(_lr_predict_logits(self.w, self.b, x)), axis=1)
        return float(np.mean(pred == np.asarray(y)))

    def predict(self, text: str) -> int:
        return int(self.predict_batch([text])[0])

    def predict_batch(self, texts: Sequence[str]) -> np.ndarray:
        """Classify a batch in one embed + one matmul; (len(texts),) labels."""
        e = jnp.asarray(self.embedder.encode_batch(
            [self.instruction_text(t) for t in texts]))
        return np.argmax(np.asarray(_lr_predict_logits(self.w, self.b, e)),
                         axis=1)

    def state_dict(self) -> dict:
        return {"w": np.asarray(self.w), "b": np.asarray(self.b)}

    def load_state_dict(self, d: dict) -> None:
        self.w = jnp.asarray(d["w"]); self.b = jnp.asarray(d["b"])
        self._trained = True


# ---------------------------------------------------------------------------
# Online k-means (paper Eq. 9-10): cosine assignment, incremental update.
# ---------------------------------------------------------------------------


class OnlineKMeans:
    """Online k-means with cosine assignment and decaying-rate updates.

    Holds TWO synchronized copies of (centroids, counts, initialized):

      * the host numpy mirror — the Eq. 9–10 reference implementation
        (``assign``/``update``) and what ``state_dict`` serializes;
      * a cached device tuple — what the router's fused decision program
        reads and writes.  ``load_device_state`` just swaps the cached
        tuple (no download), so steady-state device routing moves *no*
        k-means state across the host↔device boundary; the host mirror is
        refreshed lazily (``_sync_host``) only when something reads it.

    ``transfers`` counts every actual upload/download of this state —
    the fleet residency convention's audit trail (core/residency.py).
    """

    def __init__(self, k: int, dim: int):
        if k < 1:
            raise ValueError("k must be >= 1")
        self.k = k
        self.dim = dim
        self._h_centroids = np.zeros((k, dim), dtype=np.float32)
        self._h_counts = np.zeros((k,), dtype=np.int64)
        self._h_init = 0  # first K distinct embeddings seed the centroids
        # device residency: cached (centroids f32, counts f32, init i32)
        # tuple, or None when the host mirror is newer / nothing uploaded
        self._dev: Optional[tuple] = None
        self._host_stale = False     # device copy has updates host lacks
        self.transfers = TransferLedger()

    # -- host/device mirror plumbing ----------------------------------------

    def _sync_host(self) -> None:
        """Refresh the host mirror from the device copy (one download)."""
        if self._host_stale:
            cent, cnt, ini = self._dev
            self._h_centroids = np.asarray(cent, dtype=np.float32).copy()
            self._h_counts = np.asarray(np.rint(np.asarray(cnt)),
                                        dtype=np.int64)
            self._h_init = int(ini)
            self._host_stale = False
            self.transfers.count_d2h()

    def _invalidate_device(self) -> None:
        """Host-side mutation: drop the (now stale) device copy."""
        self._dev = None

    @property
    def centroids(self) -> np.ndarray:
        self._sync_host()
        return self._h_centroids

    @property
    def counts(self) -> np.ndarray:
        self._sync_host()
        return self._h_counts

    @property
    def _initialized(self) -> int:
        self._sync_host()
        return self._h_init

    def assign(self, e: np.ndarray) -> int:
        """Eq. 9: argmax_c cos(e, mu_c) over initialized centroids."""
        self._sync_host()
        live = max(self._h_init, 1)
        c = self._h_centroids[:live]
        norms = np.linalg.norm(c, axis=1) * max(np.linalg.norm(e), 1e-12)
        sims = (c @ e) / np.maximum(norms, 1e-12)
        return int(np.argmax(sims))

    def update(self, e: np.ndarray) -> int:
        """Assign, then apply the Eq. 10 incremental centroid update."""
        self._sync_host()
        self._invalidate_device()
        e = np.asarray(e, dtype=np.float32)
        if self._h_init < self.k:
            # seed from the first K distinct embeddings (paper §4.2.2)
            for i in range(self._h_init):
                if np.allclose(self._h_centroids[i], e, atol=1e-6):
                    break
            else:
                idx = self._h_init
                self._h_centroids[idx] = e
                self._h_counts[idx] = 1
                self._h_init += 1
                return idx
        c = self.assign(e)
        n = self._h_counts[c]
        self._h_centroids[c] += (e - self._h_centroids[c]) / (n + 1)
        self._h_counts[c] += 1
        return c

    def state_dict(self) -> dict:
        self._sync_host()
        return {"centroids": self._h_centroids.copy(),
                "counts": self._h_counts.copy(),
                "initialized": self._h_init}

    def load_state_dict(self, d: dict) -> None:
        self._host_stale = False
        self._invalidate_device()
        self._h_centroids = np.asarray(d["centroids"], dtype=np.float32).copy()
        self._h_counts = np.asarray(d["counts"], dtype=np.int64).copy()
        self._h_init = int(d["initialized"])

    # -- device path (fused featurize→score pipeline) -----------------------

    def device_state(self) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """(centroids, counts, initialized) as device arrays for the jitted
        Eq. 9–10 replay (counts as float32: exact for any realistic stream,
        and the Eq. 10 step divides by them).  Cached: uploads once after a
        host-side mutation, then returns the resident tuple for free."""
        if self._dev is None:
            self._dev = (jnp.asarray(self._h_centroids),
                         jnp.asarray(self._h_counts, jnp.float32),
                         jnp.int32(self._h_init))
            self.transfers.count_h2d()
        return self._dev

    def load_device_state(self, centroids, counts, initialized) -> None:
        """Adopt a jitted update's output arrays as the resident state.
        No download happens here — the host mirror is marked stale and
        refreshed lazily on its next read."""
        self._dev = (centroids, counts, initialized)
        self._host_stale = True

    def update_batch_device(self, embs: np.ndarray) -> np.ndarray:
        """Assign + update a whole batch on device (one jitted scan),
        keeping the state device-resident; returns (Q,) cluster ids.
        Semantically identical to Q sequential ``update`` calls — the
        scan replays the Eq. 10 centroid shifts in arrival order."""
        cent, cnt, ini = self.device_state()
        cent, cnt, ini, clusters = _kmeans_scan_jit(
            cent, cnt, ini, jnp.asarray(embs, jnp.float32))
        self.load_device_state(cent, cnt, ini)
        return np.asarray(clusters, dtype=np.int64)


def kmeans_update_scan(centroids, counts, initialized, embs, valid=None):
    """Eq. 9–10 over a batch as a ``lax.scan`` in arrival order.

    Each step replays exactly what ``OnlineKMeans.update`` does on host:
    seed the next free centroid when fewer than K *distinct* embeddings
    have been seen (distinctness = np.allclose's |c−e| ≤ atol + rtol·|e|
    with atol=1e-6, rtol=1e-5), otherwise cosine-assign over the live
    centroids and apply the incremental update μ_c += (e−μ_c)/(N_c+1).
    The sequential dependency is intrinsic — each update shifts the
    centroid the next assignment sees — which is why this is a scan and
    not a vmap; batched and sequential featurization therefore agree.

    centroids: (K, D) f32; counts: (K,) f32; initialized: () i32;
    embs: (Q, D) f32 → (centroids', counts', initialized', clusters (Q,)).
    ``valid`` (Q,) bool marks real rows — padding rows (callers pad Q to a
    power of two for jit-cache stability) leave the state untouched and
    get cluster 0.
    """
    k = centroids.shape[0]
    idx = jnp.arange(k)
    if valid is None:
        valid = jnp.ones(embs.shape[0], bool)

    def step(carry, xs):
        cent, cnt, ini = carry
        e, v = xs
        close = jnp.all(
            jnp.abs(cent - e[None, :]) <= 1e-6 + 1e-5 * jnp.abs(e)[None, :],
            axis=1)
        is_dup = jnp.any(close & (idx < ini))
        can_seed = (ini < k) & ~is_dup
        # Eq. 9 assignment over the live centroids (at least one)
        live = jnp.maximum(ini, 1)
        norms = jnp.linalg.norm(cent, axis=1) \
            * jnp.maximum(jnp.linalg.norm(e), 1e-12)
        sims = (cent @ e) / jnp.maximum(norms, 1e-12)
        c = jnp.argmax(jnp.where(idx < live, sims, -jnp.inf)).astype(jnp.int32)
        upd_cent = cent.at[c].add((e - cent[c]) / (cnt[c] + 1.0))
        upd_cnt = cnt.at[c].add(1.0)
        seed_at = jnp.minimum(ini, k - 1)        # clamped; unused when full
        seed_cent = cent.at[seed_at].set(e)
        seed_cnt = cnt.at[seed_at].set(1.0)
        new_cent = jnp.where(can_seed, seed_cent, upd_cent)
        new_cnt = jnp.where(can_seed, seed_cnt, upd_cnt)
        cluster = jnp.where(v, jnp.where(can_seed, ini, c), 0)
        return ((jnp.where(v, new_cent, cent),
                 jnp.where(v, new_cnt, cnt),
                 ini + (v & can_seed).astype(ini.dtype)),
                cluster)

    (cent, cnt, ini), clusters = jax.lax.scan(
        step, (centroids, counts, initialized), (embs, valid))
    return cent, cnt, ini, clusters


def kmeans_assign_batch(centroids, initialized, embs):
    """Read-only Eq. 9 assignment for a batch (the cache probe): no state
    change, so the rows vectorize — identical to Q independent ``assign``
    calls on the same centroids."""
    k = centroids.shape[0]
    live = jnp.maximum(initialized, 1)
    cnorm = jnp.linalg.norm(centroids, axis=1)                   # (K,)
    enorm = jnp.maximum(jnp.linalg.norm(embs, axis=1), 1e-12)    # (Q,)
    sims = (embs @ centroids.T) / jnp.maximum(cnorm[None, :] * enorm[:, None],
                                              1e-12)
    sims = jnp.where(jnp.arange(k)[None, :] < live, sims, -jnp.inf)
    return jnp.argmax(sims, axis=1).astype(jnp.int32)


# the resident (centroids, counts, initialized) tuple threads through and
# is immediately replaced by the outputs, so the buffers are donated where
# the backend supports it (no-op copy on CPU — compat.donation_kwargs)
_kmeans_scan_jit = jax.jit(kmeans_update_scan,
                           **compat.donation_kwargs(0, 1, 2))


def _pad_cols(a: np.ndarray, width: int, fill) -> np.ndarray:
    """Right-pad a (Q, L) feature tensor to L=width (stacking full-text and
    instruction rows into one kernel call needs a common L)."""
    if a.shape[1] == width:
        return a
    return np.pad(a, ((0, 0), (0, width - a.shape[1])),
                  constant_values=fill)


def _pad_rows(a: np.ndarray, q_pad: int, fill) -> np.ndarray:
    """Bottom-pad an (n, L) tensor to q_pad rows (padding the batch axis
    to a power of two bounds the compiled jit variants)."""
    if q_pad == a.shape[0]:
        return a
    out = np.full((q_pad, a.shape[1]), fill, dtype=a.dtype)
    out[: a.shape[0]] = a
    return out


@functools.partial(jax.jit, static_argnames=("n", "use_task", "use_cluster"))
def _probe_pipeline(ids, weights, proj, w, b, centroids, initialized, *,
                    n: int, use_task: bool, use_cluster: bool):
    """Fused read-only probe: featurize (full texts, plus instruction
    slices when the task feature is on, stacked into one kernel call) →
    classifier logits → Eq. 9 assignment.  No state is written."""
    from repro.kernels.featurize import hashed_embed
    e = hashed_embed(ids, weights, proj)
    emb, emb_i = e[:n], e[n:]
    if use_task:
        labels = jnp.argmax(emb_i @ w + b, axis=1).astype(jnp.int32)
    else:
        labels = jnp.zeros((n,), jnp.int32)
    if use_cluster:
        clusters = kmeans_assign_batch(centroids, initialized, emb)
    else:
        clusters = jnp.zeros((n,), jnp.int32)
    return labels, clusters, emb


# ---------------------------------------------------------------------------
# Flesch Reading Ease (Eq. 11) + equal-width binning.
# ---------------------------------------------------------------------------

_SENT_SPLIT = re.compile(r"[.!?]+")
_VOWEL_GROUPS = re.compile(r"[aeiouy]+")


def count_syllables(word: str) -> int:
    w = word.lower().strip("'")
    if not w:
        return 0
    groups = _VOWEL_GROUPS.findall(w)
    n = len(groups)
    if w.endswith("e") and n > 1 and not w.endswith(("le", "ee", "ye")):
        n -= 1  # silent final e
    return max(n, 1)


def flesch_counts(text: str) -> Tuple[int, int, int]:
    """(words, sentences, syllables) — the integer sufficient statistics
    of Eq. 11.  The string/regex work stays on host; the (cheap) score
    arithmetic can then run wherever the bins are consumed — the fused
    device pipeline computes score+bin from these counts with the exact
    float32 op order of ``flesch_score_from_counts``.  Sentences are
    clamped to >= 1 so the ratio is always defined."""
    words = tokenize(text)
    sentences = max(len([s for s in _SENT_SPLIT.split(text) if s.strip()]),
                    1)
    syllables = sum(count_syllables(w) for w in words)
    return len(words), sentences, syllables


def flesch_score_from_counts(n_words: int, n_sentences: int,
                             n_syllables: int) -> float:
    """Eq. 11 from the counts, in float32 with a fixed op order — the
    single arithmetic spec both the host reference path and the device
    pipeline implement, so host/device bins agree bitwise.  Zero words
    (empty/punctuation-only text) scores 100.0 (trivially simple)."""
    if n_words == 0:
        return 100.0
    ws = np.float32(n_words) / np.float32(n_sentences)
    sw = np.float32(n_syllables) / np.float32(n_words)
    score = (np.float32(206.835) - np.float32(1.015) * ws
             - np.float32(84.6) * sw)
    return float(np.clip(score, np.float32(0.0), np.float32(100.0)))


def flesch_reading_ease(text: str) -> float:
    """Eq. 11; clamped to [0, 100] as the paper bins in that range."""
    return flesch_score_from_counts(*flesch_counts(text))


class FleschComplexity:
    """Score + equal-width binning into N_bins categories (paper §4.2.3)."""

    def __init__(self, n_bins: int, lo: float = 0.0, hi: float = 100.0):
        if n_bins < 1:
            raise ValueError("n_bins must be >= 1")
        self.n_bins = n_bins
        self.lo, self.hi = lo, hi

    @property
    def bin_width32(self) -> np.float32:
        """Equal-bin width in float32 — the scalar the device binning
        stage consumes (must match ``bin``'s arithmetic exactly)."""
        return np.float32(self.hi - self.lo) / np.float32(self.n_bins)

    def score(self, text: str) -> float:
        return flesch_reading_ease(text)

    def bin(self, score: float) -> int:
        # float32 with int truncation toward zero — mirrored by the device
        # pipeline's (score - lo) / width → int32 cast
        b = int((np.float32(score) - np.float32(self.lo)) / self.bin_width32)
        return int(np.clip(b, 0, self.n_bins - 1))

    def __call__(self, text: str) -> Tuple[float, int]:
        s = self.score(text)
        return s, self.bin(s)


# ---------------------------------------------------------------------------
# Context vectorizer: one-hot + intercept (paper §4.2.4).
# ---------------------------------------------------------------------------


class ContextGenerator:
    """Combines the three extractors into x_t ∈ R^d (d = N_tasks+K+N_bins+1)."""

    def __init__(self, config: RouterConfig, embedder: Optional[EmbeddingModel] = None):
        self.config = config
        self.embedder = embedder or EmbeddingModel()
        self.task_classifier = TaskClassifier(self.embedder, n_classes=config.n_tasks,
                                              seed=config.seed)
        self.kmeans = OnlineKMeans(config.n_clusters, self.embedder.dim)
        self.complexity = FleschComplexity(config.n_complexity_bins)
        # feature toggles for the ablation study (paper §6.2.3)
        self.use_task = True
        self.use_cluster = True
        self.use_complexity = True
        # "featurize" is the device path's host hashing pass; on the host
        # path it stays 0 (hashing is inside the task/cluster stages there)
        self.timings_ms = {"task": 0.0, "cluster": 0.0, "complexity": 0.0,
                           "featurize": 0.0, "n": 0}

    def set_features(self, task: bool = True, cluster: bool = True,
                     complexity: bool = True) -> None:
        self.use_task, self.use_cluster, self.use_complexity = task, cluster, complexity

    @property
    def dim(self) -> int:
        return self.config.context_dim

    def encode(self, task_label: int, cluster: int, comp_bin: int) -> np.ndarray:
        cfg = self.config
        x = np.zeros(cfg.context_dim, dtype=np.float32)
        if self.use_task:
            x[task_label] = 1.0
        if self.use_cluster:
            x[cfg.n_tasks + cluster] = 1.0
        if self.use_complexity:
            x[cfg.n_tasks + cfg.n_clusters + comp_bin] = 1.0
        x[-1] = 1.0  # intercept
        return x

    def __call__(self, text: str) -> ContextVector:
        # the batch-of-one: keeps the sequential and batched featurization
        # paths structurally identical (route_batch's equivalence guarantee)
        return self.batch([text])[0]

    def batch(self, texts: Sequence[str],
              embeddings: Optional[np.ndarray] = None,
              task_labels: Optional[np.ndarray] = None) -> list:
        """Featurize a query batch: List[ContextVector], index-aligned.

        Embedding + task classification are vectorized; the k-means
        centroid updates (Eq. 10) stay sequential in arrival order because
        each update shifts the centroid the next assignment sees — this is
        exactly what Q successive ``__call__``s would compute, so batched
        and sequential featurization agree bitwise.

        ``embeddings`` (n, dim) / ``task_labels`` (n,), optional: reuse
        feature work a caller already did on the same texts (the serving
        scheduler's cache probe embeds and classifies every query before
        routing).  The embedder and classifier are deterministic, so
        passing their own outputs back is bitwise identical to recomputing
        — the k-means *updates* still happen here, in arrival order.
        """
        if not texts:
            return []
        n = len(texts)
        t0 = time.perf_counter()
        if not self.use_task:
            task_labels = np.zeros(n, dtype=np.int64)
        elif task_labels is None:
            task_labels = self.task_classifier.predict_batch(texts)
        _sync(task_labels)                    # timing boundary, not pipeline
        t1 = time.perf_counter()
        if self.use_cluster:
            embs = (embeddings if embeddings is not None
                    else self.embedder.encode_batch(texts))
            clusters = [self.kmeans.update(e) for e in embs]
        else:
            clusters = [0] * n
        _sync(clusters)
        t2 = time.perf_counter()
        comp = ([self.complexity(t) for t in texts] if self.use_complexity
                else [(100.0, 0)] * n)
        t3 = time.perf_counter()
        self.timings_ms["task"] += (t1 - t0) * 1e3
        self.timings_ms["cluster"] += (t2 - t1) * 1e3
        self.timings_ms["complexity"] += (t3 - t2) * 1e3
        self.timings_ms["n"] += n
        return self.make_contexts(task_labels, clusters, comp)

    def make_contexts(self, task_labels, clusters, comp) -> List[ContextVector]:
        """Index-aligned ContextVectors from per-query (label, cluster,
        (score, bin)) triples — shared by the host and device paths (the
        one-hot layout is ``encode``'s, identically 0/1 on both)."""
        return [ContextVector(
            task_label=int(task_labels[i]), cluster=int(clusters[i]),
            complexity_bin=comp[i][1], complexity_score=comp[i][0],
            vector=self.encode(int(task_labels[i]), int(clusters[i]),
                               comp[i][1]))
            for i in range(len(task_labels))]

    # -- device featurization (the fused featurize→score pipeline) ----------

    @property
    def device_active(self) -> bool:
        """True when featurization should run through the Pallas pipeline
        (``RouterConfig.featurize`` toggle; "auto" = accelerator only)."""
        return self.config.resolve_featurize_device()

    def complexity_counts_batch(self, texts: Sequence[str]) -> np.ndarray:
        """Host half of the Flesch stage: the (Q, 3) int32
        (words, sentences, syllables) count matrix.  Only the
        string/regex tokenization stays on host — the Eq. 11 score and
        equal-width binning run inside the fused device pipeline from
        these counts (float32, op order of ``flesch_score_from_counts``).
        With complexity ablated the counts are all-zero (sentences
        clamped to 1), which the device maps to score 100.0 / bin 0 —
        the same sentinel the host path uses."""
        if self.use_complexity:
            counts = [flesch_counts(t) for t in texts]
        else:
            counts = [(0, 1, 0)] * len(texts)
        return np.asarray(counts, dtype=np.int32).reshape(len(texts), 3)

    def instruction_features(self, texts: Sequence[str]
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """Hashed (ids, weights) for the classifier's instruction slices."""
        return self.embedder.hashed_features(
            [self.task_classifier.instruction_text(t) for t in texts])

    def padded_feature_tensors(self, texts: Sequence[str], want_full: bool,
                               want_instr: bool, q_pad: int
                               ) -> Tuple[np.ndarray, np.ndarray]:
        """Stacked, padded (ids, weights) for the fused device pipelines:
        the full-text half (when ``want_full``) followed by the
        instruction half (when ``want_instr``), each column-padded to one
        power-of-two L (floor 128, fill id −1 / weight 0) and row-padded
        to ``q_pad`` — the single owner of the layout both
        ``_probe_pipeline`` and the router's ``_fused_decide`` slice at
        the padded boundary (``e[:q_pad]`` / ``e[q_pad:]``)."""
        from repro.kernels.featurize.ops import pad_pow2
        halves = []
        if want_full:
            halves.append(self.embedder.hashed_features(texts))
        if want_instr:
            halves.append(self.instruction_features(texts))
        width = pad_pow2(max(h[0].shape[1] for h in halves), floor=128)
        ids = np.concatenate(
            [_pad_rows(_pad_cols(i, width, -1), q_pad, -1)
             for i, _ in halves])
        weights = np.concatenate(
            [_pad_rows(_pad_cols(w, width, 0.0), q_pad, 0.0)
             for _, w in halves])
        return ids, weights

    def classifier_params(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self.task_classifier.w, self.task_classifier.b

    def record_device_batch(self, n: int, featurize_ms: float,
                            complexity_ms: float) -> None:
        """Account a device-path batch in ``timings_ms`` (the fused
        task+cluster+score time lives in the router's decision clock —
        stages inside one jitted call cannot be timed separately without
        syncing mid-pipeline)."""
        self.timings_ms["featurize"] += featurize_ms
        self.timings_ms["complexity"] += complexity_ms
        self.timings_ms["n"] += n

    def probe_batch(self, texts: Sequence[str]
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Read-only batched features for cache probes: (task labels,
        cluster assignments, unit embeddings), one featurization pass for
        the whole batch.  Mutates nothing — classifier ``predict`` and
        k-means ``assign`` semantics; Eq. 10 updates stay exclusive to
        routing.  On the device path this is one fused jitted call whose
        embeddings the router then reuses (the query is embedded once per
        lifecycle)."""
        n = len(texts)
        if n == 0:
            z = np.zeros(0, dtype=np.int64)
            return z, z.copy(), np.zeros((0, self.embedder.dim), np.float32)
        if not self.device_active:
            embs = self.embedder.encode_batch(texts)
            labels = (self.task_classifier.predict_batch(texts)
                      if self.use_task else np.zeros(n, dtype=np.int64))
            clusters = (np.asarray([self.kmeans.assign(e) for e in embs],
                                   dtype=np.int64) if self.use_cluster
                        else np.zeros(n, dtype=np.int64))
            return labels, clusters, embs
        from repro.kernels.featurize.ops import pad_pow2
        # Q and L padded to powers of two so the compiled probe variants
        # stay bounded (padding rows are read-only garbage, sliced off)
        q_pad = pad_pow2(n)
        ids, weights = self.padded_feature_tensors(
            texts, want_full=True, want_instr=self.use_task, q_pad=q_pad)
        cent, _, ini = self.kmeans.device_state()
        w, b = self.classifier_params()
        labels, clusters, emb = _probe_pipeline(
            jnp.asarray(ids), jnp.asarray(weights), self.embedder.proj_device,
            w, b, cent, ini, n=q_pad, use_task=self.use_task,
            use_cluster=self.use_cluster)
        return (np.asarray(labels, dtype=np.int64)[:n],
                np.asarray(clusters, dtype=np.int64)[:n],
                np.asarray(emb)[:n])

    def mean_overhead_ms(self) -> dict:
        n = max(self.timings_ms["n"], 1)
        return {k: v / n for k, v in self.timings_ms.items() if k != "n"}

    def state_dict(self) -> dict:
        return {"task": self.task_classifier.state_dict(),
                "kmeans": self.kmeans.state_dict()}

    def load_state_dict(self, d: dict) -> None:
        self.task_classifier.load_state_dict(d["task"])
        self.kmeans.load_state_dict(d["kmeans"])
