"""Query Context Generator (paper §4.2).

Three feature extractors feed the context vector x_t = [l_t, c_t, p_t]:

  * TaskClassifier   — logistic regression over instruction embeddings (§4.2.1)
  * OnlineKMeans     — cosine-assignment online k-means over full-query
                       embeddings with incremental centroid updates (§4.2.2,
                       Eq. 9–10)
  * FleschComplexity — Flesch Reading Ease (Eq. 11) + equal-width binning
                       (§4.2.3)

Categorical features are one-hot encoded with an intercept appended (§4.2.4):
d = N_tasks + K + N_bins + 1.
"""
from __future__ import annotations

import re
import time
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import EmbeddingModel, tokenize
from repro.core.types import ContextVector, N_TASKS, RouterConfig

# ---------------------------------------------------------------------------
# Task classifier: LR over embeddings, trained with full-batch Adam in JAX.
# ---------------------------------------------------------------------------


def _lr_loss(params, x, y, n_classes, l2=1e-4):
    w, b = params
    logits = x @ w + b
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return nll + l2 * jnp.sum(w * w)


@jax.jit
def _lr_predict_logits(w, b, x):
    return x @ w + b


class TaskClassifier:
    """Lightweight LR task-type classifier (paper §4.2.1).

    The instruction text is taken from the first lines of the prompt,
    embedded, and classified into one of N_TASKS labels.
    """

    def __init__(self, embedder: EmbeddingModel, n_classes: int = N_TASKS,
                 instr_lines: int = 2, seed: int = 0):
        self.embedder = embedder
        self.n_classes = n_classes
        self.instr_lines = instr_lines
        rng = np.random.default_rng(seed)
        self.w = jnp.asarray(rng.standard_normal((embedder.dim, n_classes)) * 0.01,
                             dtype=jnp.float32)
        self.b = jnp.zeros((n_classes,), dtype=jnp.float32)
        self._trained = False

    def instruction_text(self, text: str) -> str:
        lines = [ln for ln in text.splitlines() if ln.strip()]
        return " ".join(lines[: self.instr_lines]) if lines else text

    def fit(self, texts: Sequence[str], labels: Sequence[int],
            steps: int = 300, lr: float = 0.05) -> float:
        """Train with full-batch Adam; returns final training accuracy."""
        x = jnp.asarray(self.embedder.encode_batch(
            [self.instruction_text(t) for t in texts]))
        y = jnp.asarray(np.asarray(labels, dtype=np.int32))
        params = (self.w, self.b)
        m = jax.tree.map(jnp.zeros_like, params)
        v = jax.tree.map(jnp.zeros_like, params)
        loss_grad = jax.jit(jax.value_and_grad(
            lambda p: _lr_loss(p, x, y, self.n_classes)))

        @jax.jit
        def adam_step(params, m, v, t):
            loss, g = loss_grad(params)
            m = jax.tree.map(lambda a, b_: 0.9 * a + 0.1 * b_, m, g)
            v = jax.tree.map(lambda a, b_: 0.999 * a + 0.001 * b_ * b_, v, g)
            mh = jax.tree.map(lambda a: a / (1 - 0.9 ** t), m)
            vh = jax.tree.map(lambda a: a / (1 - 0.999 ** t), v)
            params = jax.tree.map(
                lambda p, a, b_: p - lr * a / (jnp.sqrt(b_) + 1e-8), params, mh, vh)
            return params, m, v, loss

        for t in range(1, steps + 1):
            params, m, v, _ = adam_step(params, m, v, jnp.float32(t))
        self.w, self.b = params
        self._trained = True
        pred = np.argmax(np.asarray(_lr_predict_logits(self.w, self.b, x)), axis=1)
        return float(np.mean(pred == np.asarray(y)))

    def predict(self, text: str) -> int:
        return int(self.predict_batch([text])[0])

    def predict_batch(self, texts: Sequence[str]) -> np.ndarray:
        """Classify a batch in one embed + one matmul; (len(texts),) labels."""
        e = jnp.asarray(self.embedder.encode_batch(
            [self.instruction_text(t) for t in texts]))
        return np.argmax(np.asarray(_lr_predict_logits(self.w, self.b, e)),
                         axis=1)

    def state_dict(self) -> dict:
        return {"w": np.asarray(self.w), "b": np.asarray(self.b)}

    def load_state_dict(self, d: dict) -> None:
        self.w = jnp.asarray(d["w"]); self.b = jnp.asarray(d["b"])
        self._trained = True


# ---------------------------------------------------------------------------
# Online k-means (paper Eq. 9-10): cosine assignment, incremental update.
# ---------------------------------------------------------------------------


class OnlineKMeans:
    """Online k-means with cosine assignment and decaying-rate updates."""

    def __init__(self, k: int, dim: int):
        if k < 1:
            raise ValueError("k must be >= 1")
        self.k = k
        self.dim = dim
        self.centroids = np.zeros((k, dim), dtype=np.float32)
        self.counts = np.zeros((k,), dtype=np.int64)
        self._initialized = 0  # first K distinct embeddings seed the centroids

    def assign(self, e: np.ndarray) -> int:
        """Eq. 9: argmax_c cos(e, mu_c) over initialized centroids."""
        live = max(self._initialized, 1)
        c = self.centroids[:live]
        norms = np.linalg.norm(c, axis=1) * max(np.linalg.norm(e), 1e-12)
        sims = (c @ e) / np.maximum(norms, 1e-12)
        return int(np.argmax(sims))

    def update(self, e: np.ndarray) -> int:
        """Assign, then apply the Eq. 10 incremental centroid update."""
        e = np.asarray(e, dtype=np.float32)
        if self._initialized < self.k:
            # seed from the first K distinct embeddings (paper §4.2.2)
            for i in range(self._initialized):
                if np.allclose(self.centroids[i], e, atol=1e-6):
                    break
            else:
                idx = self._initialized
                self.centroids[idx] = e
                self.counts[idx] = 1
                self._initialized += 1
                return idx
        c = self.assign(e)
        n = self.counts[c]
        self.centroids[c] += (e - self.centroids[c]) / (n + 1)
        self.counts[c] += 1
        return c

    def state_dict(self) -> dict:
        return {"centroids": self.centroids.copy(), "counts": self.counts.copy(),
                "initialized": self._initialized}

    def load_state_dict(self, d: dict) -> None:
        self.centroids = np.asarray(d["centroids"], dtype=np.float32).copy()
        self.counts = np.asarray(d["counts"], dtype=np.int64).copy()
        self._initialized = int(d["initialized"])


# ---------------------------------------------------------------------------
# Flesch Reading Ease (Eq. 11) + equal-width binning.
# ---------------------------------------------------------------------------

_SENT_SPLIT = re.compile(r"[.!?]+")
_VOWEL_GROUPS = re.compile(r"[aeiouy]+")


def count_syllables(word: str) -> int:
    w = word.lower().strip("'")
    if not w:
        return 0
    groups = _VOWEL_GROUPS.findall(w)
    n = len(groups)
    if w.endswith("e") and n > 1 and not w.endswith(("le", "ee", "ye")):
        n -= 1  # silent final e
    return max(n, 1)


def flesch_reading_ease(text: str) -> float:
    """Eq. 11; clamped to [0, 100] as the paper bins in that range."""
    words = tokenize(text)
    if not words:
        return 100.0
    sentences = max(len([s for s in _SENT_SPLIT.split(text) if s.strip()]), 1)
    syllables = sum(count_syllables(w) for w in words)
    score = 206.835 - 1.015 * (len(words) / sentences) - 84.6 * (syllables / len(words))
    return float(np.clip(score, 0.0, 100.0))


class FleschComplexity:
    """Score + equal-width binning into N_bins categories (paper §4.2.3)."""

    def __init__(self, n_bins: int, lo: float = 0.0, hi: float = 100.0):
        if n_bins < 1:
            raise ValueError("n_bins must be >= 1")
        self.n_bins = n_bins
        self.lo, self.hi = lo, hi

    def score(self, text: str) -> float:
        return flesch_reading_ease(text)

    def bin(self, score: float) -> int:
        width = (self.hi - self.lo) / self.n_bins
        b = int((score - self.lo) / width)
        return int(np.clip(b, 0, self.n_bins - 1))

    def __call__(self, text: str) -> Tuple[float, int]:
        s = self.score(text)
        return s, self.bin(s)


# ---------------------------------------------------------------------------
# Context vectorizer: one-hot + intercept (paper §4.2.4).
# ---------------------------------------------------------------------------


class ContextGenerator:
    """Combines the three extractors into x_t ∈ R^d (d = N_tasks+K+N_bins+1)."""

    def __init__(self, config: RouterConfig, embedder: Optional[EmbeddingModel] = None):
        self.config = config
        self.embedder = embedder or EmbeddingModel()
        self.task_classifier = TaskClassifier(self.embedder, n_classes=config.n_tasks,
                                              seed=config.seed)
        self.kmeans = OnlineKMeans(config.n_clusters, self.embedder.dim)
        self.complexity = FleschComplexity(config.n_complexity_bins)
        # feature toggles for the ablation study (paper §6.2.3)
        self.use_task = True
        self.use_cluster = True
        self.use_complexity = True
        self.timings_ms = {"task": 0.0, "cluster": 0.0, "complexity": 0.0, "n": 0}

    def set_features(self, task: bool = True, cluster: bool = True,
                     complexity: bool = True) -> None:
        self.use_task, self.use_cluster, self.use_complexity = task, cluster, complexity

    @property
    def dim(self) -> int:
        return self.config.context_dim

    def encode(self, task_label: int, cluster: int, comp_bin: int) -> np.ndarray:
        cfg = self.config
        x = np.zeros(cfg.context_dim, dtype=np.float32)
        if self.use_task:
            x[task_label] = 1.0
        if self.use_cluster:
            x[cfg.n_tasks + cluster] = 1.0
        if self.use_complexity:
            x[cfg.n_tasks + cfg.n_clusters + comp_bin] = 1.0
        x[-1] = 1.0  # intercept
        return x

    def __call__(self, text: str) -> ContextVector:
        # the batch-of-one: keeps the sequential and batched featurization
        # paths structurally identical (route_batch's equivalence guarantee)
        return self.batch([text])[0]

    def batch(self, texts: Sequence[str],
              embeddings: Optional[np.ndarray] = None,
              task_labels: Optional[np.ndarray] = None) -> list:
        """Featurize a query batch: List[ContextVector], index-aligned.

        Embedding + task classification are vectorized; the k-means
        centroid updates (Eq. 10) stay sequential in arrival order because
        each update shifts the centroid the next assignment sees — this is
        exactly what Q successive ``__call__``s would compute, so batched
        and sequential featurization agree bitwise.

        ``embeddings`` (n, dim) / ``task_labels`` (n,), optional: reuse
        feature work a caller already did on the same texts (the serving
        scheduler's cache probe embeds and classifies every query before
        routing).  The embedder and classifier are deterministic, so
        passing their own outputs back is bitwise identical to recomputing
        — the k-means *updates* still happen here, in arrival order.
        """
        if not texts:
            return []
        n = len(texts)
        t0 = time.perf_counter()
        if not self.use_task:
            task_labels = np.zeros(n, dtype=np.int64)
        elif task_labels is None:
            task_labels = self.task_classifier.predict_batch(texts)
        t1 = time.perf_counter()
        if self.use_cluster:
            embs = (embeddings if embeddings is not None
                    else self.embedder.encode_batch(texts))
            clusters = [self.kmeans.update(e) for e in embs]
        else:
            clusters = [0] * n
        t2 = time.perf_counter()
        comp = ([self.complexity(t) for t in texts] if self.use_complexity
                else [(100.0, 0)] * n)
        t3 = time.perf_counter()
        self.timings_ms["task"] += (t1 - t0) * 1e3
        self.timings_ms["cluster"] += (t2 - t1) * 1e3
        self.timings_ms["complexity"] += (t3 - t2) * 1e3
        self.timings_ms["n"] += n
        return [ContextVector(
            task_label=int(task_labels[i]), cluster=clusters[i],
            complexity_bin=comp[i][1], complexity_score=comp[i][0],
            vector=self.encode(int(task_labels[i]), clusters[i], comp[i][1]))
            for i in range(n)]

    def mean_overhead_ms(self) -> dict:
        n = max(self.timings_ms["n"], 1)
        return {k: v / n for k, v in self.timings_ms.items() if k != "n"}

    def state_dict(self) -> dict:
        return {"task": self.task_classifier.state_dict(),
                "kmeans": self.kmeans.state_dict()}

    def load_state_dict(self, d: dict) -> None:
        self.task_classifier.load_state_dict(d["task"])
        self.kmeans.load_state_dict(d["kmeans"])
