"""GreenServ router: context → feasibility → bandit → reward → update.

Implements Algorithm 1 of the paper as a long-lived service object:

    for each query q_t:
        x_t  = GenerateContext(q_t)                 (ContextGenerator)
        m_t  = SelectModel(x_t, M_t*, A)            (BanditPolicy over pool)
        resp = InferenceExecution(m_t, q_t)         (caller / serving engine)
        acc, energy, latency = Monitor(resp)        (caller feeds back)
        r_t  = (1-λ)·acc − λ·energy                 (RewardManager)
        UpdateMAB(A_m, b_m, x_t, r_t)               (BanditPolicy.update)

The router is deliberately decoupled from inference execution: ``route()``
returns a decision, the engine executes it, and ``feedback()`` closes the
loop.  This matches the paper's partial-feedback structure and lets the
serving runtime batch/queue independently.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.bandits import BanditPolicy
from repro.core.context import ContextGenerator
from repro.core.pool import ModelPool
from repro.core.rewards import RegretTracker, RewardManager, scalarize
from repro.core.types import (ContextVector, Feedback, ModelProfile, Query,
                              RouteDecision, RouterConfig)


class GreenServRouter:
    """The paper's contribution as a composable module."""

    def __init__(self, config: RouterConfig, pool: ModelPool,
                 context: Optional[ContextGenerator] = None):
        self.config = config
        self.pool = pool
        self.context = context or ContextGenerator(config)
        self.policy = BanditPolicy(config, n_arms=len(pool))
        self.rewards = RewardManager(config)
        self.regret = RegretTracker()
        self._pending: Dict[int, RouteDecision] = {}
        self.decision_ms_total = 0.0
        self.n_routed = 0
        # zero-calibration model addition: pool insert → fresh bandit arm
        pool.on_add(self._on_model_added)

    # -- pool growth ---------------------------------------------------------

    def _on_model_added(self, profile: ModelProfile, idx: int) -> None:
        arm = self.policy.add_arm()
        if arm != idx:
            raise RuntimeError(
                f"pool/bandit index skew: pool={idx} arm={arm}")

    # -- Algorithm 1 ---------------------------------------------------------

    def route(self, query: Query) -> RouteDecision:
        # the batch-of-one: keeps the sequential and batched decision paths
        # structurally identical (see route_batch's equivalence guarantee)
        return self.route_batch([query])[0]

    def route_batch(self, queries: Sequence[Query]) -> List[RouteDecision]:
        """Route an admitted batch in one shot (the serving hot path).

        Featurization is vectorized (one embed + one classifier matmul for
        the whole batch) and LinUCB scoring runs as a single fused (Q, M)
        kernel call, so per-query decision overhead amortizes to the
        batched cost.  Arm choices are identical to calling ``route`` on
        each query in order (k-means updates are applied in arrival order,
        and LinUCB selection is deterministic given the bandit state).
        """
        if not queries:
            return []
        ctxs = self.context.batch([q.text for q in queries])
        t0 = time.perf_counter()
        masks = [self.pool.feasible_mask(q) for q in queries]
        # a concurrent pool.add() mid-batch yields ragged rows; pad earlier
        # rows with False (those queries were routed before the new model
        # existed, matching sequential semantics)
        width = max(m.shape[0] for m in masks)
        feasible = np.zeros((len(masks), width), dtype=bool)
        for i, m in enumerate(masks):
            feasible[i, : m.shape[0]] = m
        x = np.stack([c.vector for c in ctxs])
        arms, scores = self.policy.select_batch(x, feasible)
        batch_ms = (time.perf_counter() - t0) * 1e3
        per_query_ms = batch_ms / len(queries)
        self.decision_ms_total += batch_ms
        self.n_routed += len(queries)
        decisions: List[RouteDecision] = []
        for q, ctx, arm, score_row, feas_row in zip(queries, ctxs, arms,
                                                    scores, feasible):
            decision = RouteDecision(
                query_uid=q.uid, model_index=int(arm),
                model_name=self.pool[int(arm)].name, context=ctx,
                ucb_scores=score_row, feasible_mask=feas_row,
                overhead_ms=per_query_ms)
            self._pending[q.uid] = decision
            decisions.append(decision)
        return decisions

    def feedback(self, fb: Feedback,
                 oracle_reward: Optional[float] = None) -> float:
        """Close the loop for a routed query; returns the scalarized reward.

        ``oracle_reward`` (counterfactual best reward, Eq. 6) is only
        available in simulation/offline evaluation; when given, regret is
        tracked (Eq. 8).
        """
        decision = self._pending.pop(fb.query_uid, None)
        if decision is None:
            raise KeyError(f"no pending decision for query {fb.query_uid}")
        if fb.model_index != decision.model_index:
            raise ValueError("feedback model does not match routed model")
        r_t = self.rewards.reward(fb.accuracy, fb.energy_wh)
        self.policy.update(decision.model_index, decision.context.vector, r_t)
        if oracle_reward is not None:
            self.regret.step(r_t, oracle_reward)
        return r_t

    def feedback_batch(self, fbs: Sequence[Feedback],
                       oracle_rewards: Optional[Sequence[float]] = None,
                       strict: bool = True) -> List[Optional[float]]:
        """Close the loop for a batch of completions, in the given order.

        Bandit updates to *different* arms commute exactly (each arm owns
        its own sufficient statistics), so completion order across arms
        does not change the posterior; same-arm updates are applied in
        sequence.  With ``strict=False`` a feedback whose query was never
        routed here, or whose model does not match the routed arm (a hedge
        duplicate that won on a non-routed engine), is skipped and its slot
        in the returned reward list is None.
        """
        rewards: List[Optional[float]] = []
        for i, fb in enumerate(fbs):
            oracle = (oracle_rewards[i] if oracle_rewards is not None
                      else None)
            try:
                rewards.append(self.feedback(fb, oracle))
            except (KeyError, ValueError):
                if strict:
                    raise
                rewards.append(None)
        return rewards

    def oracle_reward(self, acc_by_model: np.ndarray,
                      energy_by_model: np.ndarray,
                      feasible: Optional[np.ndarray] = None) -> float:
        """Eq. 6 helper for simulators holding full counterfactual tables."""
        r = np.array([scalarize(a, e, self.config.lam, self.config.energy_scale_wh)
                      for a, e in zip(acc_by_model, energy_by_model)])
        if feasible is not None:
            r = np.where(feasible, r, -np.inf)
        return float(np.max(r))

    # -- introspection / persistence ------------------------------------------

    @property
    def mean_decision_ms(self) -> float:
        return self.decision_ms_total / max(self.n_routed, 1)

    def selection_counts(self) -> np.ndarray:
        return np.asarray(self.policy.state.counts)[: len(self.pool)]

    def state_dict(self) -> dict:
        return {"bandit": self.policy.state_dict(),
                "context": self.context.state_dict(),
                "n_routed": self.n_routed}

    def load_state_dict(self, d: dict) -> None:
        self.policy.load_state_dict(d["bandit"])
        self.context.load_state_dict(d["context"])
        self.n_routed = int(d.get("n_routed", 0))
