"""GreenServ router: context → feasibility → bandit → reward → update.

Implements Algorithm 1 of the paper as a long-lived service object:

    for each query q_t:
        x_t  = GenerateContext(q_t)                 (ContextGenerator)
        m_t  = SelectModel(x_t, M_t*, A)            (BanditPolicy over pool)
        resp = InferenceExecution(m_t, q_t)         (caller / serving engine)
        acc, energy, latency = Monitor(resp)        (caller feeds back)
        r_t  = (1-λ)·acc − λ·energy                 (RewardManager)
        UpdateMAB(A_m, b_m, x_t, r_t)               (BanditPolicy.update)

The router is deliberately decoupled from inference execution: ``route()``
returns a decision, the engine executes it, and ``feedback()`` closes the
loop.  This matches the paper's partial-feedback structure and lets the
serving runtime batch/queue independently.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.bandits import NEG_INF, BanditPolicy
from repro.core.context import (ContextGenerator, _sync,
                                kmeans_update_scan)
from repro.core.pool import ModelPool
from repro.core.rewards import RegretTracker, RewardManager, scalarize
from repro.core.types import (ContextVector, Feedback, ModelProfile, Query,
                              RouteDecision, RouterConfig)
from repro.kernels.featurize import hashed_embed
from repro.kernels.featurize.ops import pad_pow2
from repro.kernels.linucb import linucb_scores


# EWMA step for the per-arm baseline of offered cost-model predictions
# (route_batch's energy_costs_wh tilt); slow enough that one odd batch
# does not swing the baseline, fast enough to track load shifts
_PRED_COST_BETA = 0.1


# args 7-9 are the resident k-means tuple: the call returns the updated
# centroids/counts/init which the caller adopts via load_device_state, so
# the input buffers are donated where the backend implements it (with
# clustering ablated the caller passes placeholder zeros — a donated
# buffer is dead after the call, and the live tuple must survive)
@functools.partial(jax.jit, static_argnames=(
    "mode", "use_task", "use_cluster", "use_complexity", "n_tasks",
    "n_clusters", "n_bins", "alpha"),
    **compat.donation_kwargs(7, 8, 9))
def _fused_decide(ids, weights, emb_in, labels_in, proj, w_clf, b_clf,
                  centroids, kcounts, kinit, comp_counts, comp_lo,
                  comp_width, feasible, valid, a_inv, theta, active, *,
                  mode: str, use_task: bool, use_cluster: bool,
                  use_complexity: bool, n_tasks: int, n_clusters: int,
                  n_bins: int, alpha: float):
    """The whole routing decision as one jitted device program.

    featurize (Pallas hashed-embedding kernel over the padded id/weight
    tensors) → task-classifier logits → Eq. 9–10 k-means scan in arrival
    order → Flesch score+bin from the host-tokenized counts → one-hot
    context encoding → fused Pallas LinUCB scoring → feasibility-masked
    argmax.  One host→device transfer in (feature ids, complexity
    counts, feasibility), one device→host transfer out (arms, scores,
    labels, clusters, complexity, k-means state).

    ``comp_counts`` is the (Q, 3) int32 (words, sentences, syllables)
    matrix from ``ContextGenerator.complexity_counts_batch``; the Eq. 11
    arithmetic below is the float32 op-order mirror of the host
    reference ``flesch_score_from_counts`` + ``FleschComplexity.bin``
    (``comp_lo``/``comp_width`` are the binner's float32 scalars), so
    host and device produce identical bins.

    ``mode`` says what the stacked id tensor holds: "both" = full texts
    then instruction slices, "full"/"instr" = one of them, "none" = the
    caller forwarded embeddings/labels and no featurization is needed.
    Every (Q,)-shaped input is padded to a power of two by the caller
    (bounding the compiled variants); ``valid`` marks the real rows —
    padding rows must not touch the k-means state and are sliced off on
    the host.
    """
    q = comp_counts.shape[0]
    emb, emb_i = emb_in, None
    if mode == "both":
        e2 = hashed_embed(ids, weights, proj)
        emb, emb_i = e2[:q], e2[q:]
    elif mode == "full":
        emb = hashed_embed(ids, weights, proj)
    elif mode == "instr":
        emb_i = hashed_embed(ids, weights, proj)
    if use_task:
        labels = (jnp.argmax(emb_i @ w_clf + b_clf, axis=1).astype(jnp.int32)
                  if labels_in is None else labels_in)
    else:
        labels = jnp.zeros((q,), jnp.int32)
    if use_cluster:
        centroids, kcounts, kinit, clusters = kmeans_update_scan(
            centroids, kcounts, kinit, emb, valid=valid)
    else:
        clusters = jnp.zeros((q,), jnp.int32)
    if use_complexity:
        w_ = comp_counts[:, 0].astype(jnp.float32)
        s_ = comp_counts[:, 1].astype(jnp.float32)
        sy = comp_counts[:, 2].astype(jnp.float32)
        # W >= 1 on selected rows, so max(W, 1) == W there; it only guards
        # the masked-off W == 0 branch from dividing by zero
        ws = w_ / s_
        sw = sy / jnp.maximum(w_, jnp.float32(1.0))
        raw = (jnp.float32(206.835) - jnp.float32(1.015) * ws
               - jnp.float32(84.6) * sw)
        comp_scores = jnp.where(
            w_ > 0, jnp.clip(raw, jnp.float32(0.0), jnp.float32(100.0)),
            jnp.float32(100.0))
        comp_bins = jnp.clip(
            ((comp_scores - comp_lo) / comp_width).astype(jnp.int32),
            0, n_bins - 1)
    else:
        comp_scores = jnp.full((q,), 100.0, jnp.float32)
        comp_bins = jnp.zeros((q,), jnp.int32)
    parts = [
        (jax.nn.one_hot(labels, n_tasks) if use_task
         else jnp.zeros((q, n_tasks))),
        (jax.nn.one_hot(clusters, n_clusters) if use_cluster
         else jnp.zeros((q, n_clusters))),
        (jax.nn.one_hot(comp_bins, n_bins) if use_complexity
         else jnp.zeros((q, n_bins))),
        jnp.ones((q, 1)),
    ]
    x = jnp.concatenate(parts, axis=1).astype(jnp.float32)
    scores = linucb_scores(a_inv, theta, x, alpha)
    masked = jnp.where(active[None, :] & feasible, scores, NEG_INF)
    arms = jnp.argmax(masked, axis=1)
    return (arms, masked, labels, clusters, centroids, kcounts, kinit,
            comp_scores, comp_bins)


class GreenServRouter:
    """The paper's contribution as a composable module."""

    def __init__(self, config: RouterConfig, pool: ModelPool,
                 context: Optional[ContextGenerator] = None):
        self.config = config
        self.pool = pool
        self.context = context or ContextGenerator(config)
        self.policy = BanditPolicy(config, n_arms=len(pool))
        self.rewards = RewardManager(config)
        self.regret = RegretTracker()
        self._pending: Dict[int, RouteDecision] = {}
        self.decision_ms_total = 0.0
        self.n_routed = 0
        # λ-decomposed sufficient statistics: b_m = (1-λ)·Σ acc·x −
        # λ·Σ (e/scale)·x, so set_lambda can re-scalarize the bandit
        # exactly instead of waiting for fresh pulls to wash out old λ
        m, d = config.max_arms, config.context_dim
        self._b_acc = np.zeros((m, d), np.float64)
        self._b_cost = np.zeros((m, d), np.float64)
        self._acc_sum = np.zeros(m, np.float64)
        self._cost_sum = np.zeros(m, np.float64)
        self._decomposed_complete = True
        # predictive-cost tilt baseline (route_batch's energy_costs_wh):
        # per-arm EWMA of the predictions *offered* to this router, so each
        # arm's forecast is scored relative to its own norm — a constant
        # prediction (or any per-arm-constant matrix) tilts nothing and
        # decisions match the cost-model-off path exactly
        self._pred_cost_mean = np.zeros(m, np.float64)
        self._pred_cost_seen = np.zeros(m, bool)
        # failure-aware routing (docs/RELIABILITY.md): an optional health
        # provider — () -> (n_models,) bool, True = routable — ANDed into
        # the feasibility matrix each decision.  PoolServer wires this to
        # its per-arm circuit breakers; None = every arm healthy.
        self._arm_health: Optional[Callable[[], Optional[np.ndarray]]] = None
        # zero-calibration model addition: pool insert → fresh bandit arm
        pool.on_add(self._on_model_added)

    def set_arm_health(self,
                       provider: Optional[Callable[[], Optional[np.ndarray]]]
                       ) -> None:
        """Install (or clear, with None) the per-arm health provider.
        The provider is polled once per ``route_batch`` call; a short or
        None result means "no opinion" for the uncovered arms."""
        self._arm_health = provider

    # -- pool growth ---------------------------------------------------------

    def _on_model_added(self, profile: ModelProfile, idx: int) -> None:
        arm = self.policy.add_arm()
        if arm != idx:
            raise RuntimeError(
                f"pool/bandit index skew: pool={idx} arm={arm}")
        self._b_acc[arm] = 0.0
        self._b_cost[arm] = 0.0
        self._acc_sum[arm] = 0.0
        self._cost_sum[arm] = 0.0
        self._pred_cost_mean[arm] = 0.0
        self._pred_cost_seen[arm] = False

    # -- online λ control (telemetry.budget drives this) -----------------------

    def set_lambda(self, lam: float, rescalarize: bool = True) -> None:
        """Retune the accuracy–energy trade-off online (governor hook).

        Future rewards scalarize under the new λ immediately (RewardManager
        shares this config).  With ``rescalarize`` the bandit's reward
        statistics are also rebuilt from the decomposed accuracy/energy
        sums, so the *posterior* shifts toward cheaper arms in the same
        step — A/A⁻¹ are context-only and stay untouched.
        """
        if not (0.0 <= lam <= 1.0):
            raise ValueError(f"lam must be in [0, 1], got {lam}")
        if lam == self.config.lam:
            return
        self.config.lam = lam
        # a checkpoint from before decomposed stats existed cannot be
        # rescalarized: the sums would be partial (or zero) and rebuilding
        # b/θ from them would silently wipe the restored posterior
        if rescalarize and self._decomposed_complete:
            scale = self.config.energy_scale_wh
            b = (1.0 - lam) * self._b_acc - lam * self._b_cost / scale
            rsum = (1.0 - lam) * self._acc_sum - lam * self._cost_sum / scale
            self.policy.rescalarize(b, rsum)

    # -- Algorithm 1 ---------------------------------------------------------

    def route(self, query: Query) -> RouteDecision:
        # the batch-of-one: keeps the sequential and batched decision paths
        # structurally identical (see route_batch's equivalence guarantee)
        return self.route_batch([query])[0]

    def route_batch(self, queries: Sequence[Query],
                    energy_discounts_wh: Optional[np.ndarray] = None,
                    energy_costs_wh: Optional[np.ndarray] = None,
                    embeddings: Optional[np.ndarray] = None,
                    task_labels: Optional[np.ndarray] = None,
                    blocked: Optional[np.ndarray] = None
                    ) -> List[RouteDecision]:
        """Route an admitted batch in one shot (the serving hot path).

        Featurization is vectorized (one embed + one classifier matmul for
        the whole batch) and LinUCB scoring runs as a single fused (Q, M)
        kernel call, so per-query decision overhead amortizes to the
        batched cost.  Arm choices are identical to calling ``route`` on
        each query in order (k-means updates are applied in arrival order,
        and LinUCB selection is deterministic given the bandit state).

        ``energy_discounts_wh`` (Q, n_models), optional: expected Wh each
        arm would *save* on each query — e.g. a prefix-KV cache hit whose
        spliced tokens skip prefill (``PoolServer`` fills this from the
        engines' prefix indexes).  The discount enters the decision as the
        energy term of the reward it cancels, ``λ·ΔWh/energy_scale`` added
        to the arm's score, so a warm-cache arm can win over a nominally
        cheaper cold one.  The bandit's *posterior* is untouched: the
        realized saving arrives through feedback (cheap completions), the
        discount only tilts this decision.  Only rows with a nonzero
        discount are re-picked — undiscounted queries keep their original
        arm, so a stochastic policy's exploration draws survive except on
        the queries the tilt is actually about (where the discounted
        greedy choice deliberately wins).

        ``energy_costs_wh`` (Q, n_models), optional: the cost model's
        *predicted* Wh for running each query on each arm (``PoolServer``
        fills this from ``EnergyCostModel.predict_matrix``).  Predictions
        replace the bandit's coarse per-arm energy statistics for *this*
        decision: each arm's forecast is centred on its own running EWMA
        baseline of offered predictions, and the centred excess enters as
        an energy penalty ``−λ·(pred − baseline)/energy_scale`` before
        the argmax.  Centring makes the tilt shape-sensitive rather than
        level-sensitive — a per-arm-constant matrix tilts nothing, so an
        uncalibrated cost model cannot perturb decisions, and systematic
        arm-level cost differences stay the posterior's job (learned from
        realized feedback, not forecasts).

        ``embeddings`` (Q, dim) / ``task_labels`` (Q,) forward feature
        work the caller already did on these texts (the scheduler's cache
        probe) into ``ContextGenerator.batch`` — bitwise identical to
        recomputing, since embedder and classifier are deterministic.

        ``blocked`` (Q, n_models) bool, optional: per-(query, arm) veto
        ANDed (inverted) into feasibility — e.g. a retry must not land
        back on the arm that just failed it.  Both the per-row veto and
        the pool-wide arm-health provider (``set_arm_health``; the
        scheduler's circuit breakers) enter through ``_feasible_matrix``,
        so host and device scoring see identical masks.  Masking never
        strands a query: a row left with no arm falls back to its
        unmasked feasible row — serving degrades, it does not refuse.

        With ``RouterConfig.featurize`` resolving to "device" (and the
        deterministic LinUCB/Sherman–Morrison policy), featurize→score
        runs as one fused jitted pipeline (``_fused_decide``): the host
        contributes one vectorized hashing pass + Flesch word/sentence/
        syllable counts, the device does everything else (including the
        Eq. 11 score + binning arithmetic).  The host path below stays the reference
        implementation; both agree (tests/test_featurize_parity.py).
        """
        if not queries:
            return []
        if self._device_featurize_active():
            ctxs, arms, scores, feasible, t0 = self._featurize_score_device(
                queries, embeddings, task_labels, blocked)
        else:
            ctxs, arms, scores, feasible, t0 = self._featurize_score_host(
                queries, embeddings, task_labels, blocked)
        if energy_costs_wh is not None:
            c = np.asarray(energy_costs_wh, np.float64)
            if c.shape[0] != len(queries):
                raise ValueError(
                    f"energy_costs_wh rows {c.shape[0]} != batch "
                    f"{len(queries)}")
            w = min(c.shape[1], scores.shape[1])
            cols = c[:, :w]
            batch_mean = cols.mean(axis=0)
            seen = self._pred_cost_seen[:w]
            self._pred_cost_mean[:w] = np.where(
                seen,
                (1.0 - _PRED_COST_BETA) * self._pred_cost_mean[:w]
                + _PRED_COST_BETA * batch_mean,
                batch_mean)
            self._pred_cost_seen[:w] = True
            tilt = np.zeros_like(scores)
            tilt[:, :w] = (-self.config.lam
                           * (cols - self._pred_cost_mean[:w])
                           / self.config.energy_scale_wh)
            if np.any(tilt):
                # NEG_INF (infeasible/inactive) scores survive any finite
                # tilt, so a plain re-argmax is safe
                scores = scores + tilt
                arms = np.argmax(scores, axis=1).astype(arms.dtype)
        if energy_discounts_wh is not None:
            d = np.asarray(energy_discounts_wh, np.float32)
            if d.shape[0] != len(queries):
                raise ValueError(
                    f"energy_discounts_wh rows {d.shape[0]} != batch "
                    f"{len(queries)}")
            rows = np.flatnonzero(d.any(axis=1))
            if rows.size:
                bonus = np.zeros_like(scores)
                w = min(d.shape[1], bonus.shape[1])
                bonus[:, :w] = (self.config.lam * d[:, :w]
                                / self.config.energy_scale_wh)
                scores = scores + bonus
                # infeasible arms carry NEG_INF scores; a finite bonus
                # cannot resurrect them, so an argmax re-pick of the
                # discounted rows suffices
                arms = arms.copy()
                arms[rows] = np.argmax(scores[rows], axis=1).astype(
                    arms.dtype)
        batch_ms = (time.perf_counter() - t0) * 1e3
        per_query_ms = batch_ms / len(queries)
        self.decision_ms_total += batch_ms
        self.n_routed += len(queries)
        decisions: List[RouteDecision] = []
        for q, ctx, arm, score_row, feas_row in zip(queries, ctxs, arms,
                                                    scores, feasible):
            decision = RouteDecision(
                query_uid=q.uid, model_index=int(arm),
                model_name=self.pool[int(arm)].name, context=ctx,
                ucb_scores=score_row, feasible_mask=feas_row,
                overhead_ms=per_query_ms)
            self._pending[q.uid] = decision
            decisions.append(decision)
        return decisions

    # -- featurize→score backends (route_batch dispatches between them) -------

    def _device_featurize_active(self) -> bool:
        """Device pipeline gate: the ``featurize`` toggle must resolve to
        device AND the policy must be deterministic batched LinUCB
        (stochastic policies and the per-decision Cholesky mode need
        sequential per-query semantics, so they stay on the host path)."""
        return (self.config.resolve_featurize_device()
                and self.config.algorithm == "linucb"
                and self.config.solve_mode == "sherman_morrison")

    def _feasible_matrix(self, queries: Sequence[Query],
                         blocked: Optional[np.ndarray] = None) -> np.ndarray:
        masks = [self.pool.feasible_mask(q) for q in queries]
        # a concurrent pool.add() mid-batch yields ragged rows; pad earlier
        # rows with False (those queries were routed before the new model
        # existed, matching sequential semantics)
        width = max(m.shape[0] for m in masks)
        feasible = np.zeros((len(masks), width), dtype=bool)
        for i, m in enumerate(masks):
            feasible[i, : m.shape[0]] = m
        # reliability masks ride the same matrix both scoring backends
        # consume, so breaker state can never break host/device parity
        masked = feasible
        if self._arm_health is not None:
            health = self._arm_health()
            if health is not None:
                health = np.asarray(health, bool)
                w = min(health.shape[0], width)
                masked = masked.copy()
                masked[:, :w] &= health[:w]
        if blocked is not None:
            b = np.asarray(blocked, bool)
            if b.shape[0] != len(masks):
                raise ValueError(
                    f"blocked rows {b.shape[0]} != batch {len(masks)}")
            w = min(b.shape[1], width)
            if masked is feasible:
                masked = feasible.copy()
            masked[:, :w] &= ~b[:, :w]
        if masked is not feasible:
            # serve-anyway guarantee: a query every arm of which is vetoed
            # keeps its plain feasibility row (a fully-open pool must
            # still answer; the breakers' probe trickle needs traffic)
            dead = ~masked.any(axis=1)
            if dead.any():
                masked[dead] = feasible[dead]
            feasible = masked
        return feasible

    def _featurize_score_host(self, queries: Sequence[Query],
                              embeddings: Optional[np.ndarray],
                              task_labels: Optional[np.ndarray],
                              blocked: Optional[np.ndarray] = None
                              ) -> Tuple[list, np.ndarray, np.ndarray,
                                         np.ndarray, float]:
        """Reference path: host featurization, then the batched selector."""
        ctxs = self.context.batch([q.text for q in queries],
                                  embeddings=embeddings,
                                  task_labels=task_labels)
        t0 = time.perf_counter()
        feasible = self._feasible_matrix(queries, blocked)
        x = np.stack([c.vector for c in ctxs])
        arms, scores = self.policy.select_batch(x, feasible)
        _sync(scores)                 # timing boundary (route_batch's clock)
        return ctxs, arms, scores, feasible, t0

    def _featurize_score_device(self, queries: Sequence[Query],
                                embeddings: Optional[np.ndarray],
                                task_labels: Optional[np.ndarray],
                                blocked: Optional[np.ndarray] = None
                                ) -> Tuple[list, np.ndarray, np.ndarray,
                                           np.ndarray, float]:
        """Fused path: one host hashing pass, then ``_fused_decide``."""
        ctx = self.context
        texts = [q.text for q in queries]
        n = len(texts)
        tc0 = time.perf_counter()
        comp_counts = ctx.complexity_counts_batch(texts)
        tc1 = time.perf_counter()
        need_emb = ctx.use_cluster and embeddings is None
        need_instr = ctx.use_task and task_labels is None
        emb_in = labels_in = None
        if embeddings is not None and ctx.use_cluster:
            emb_in = jnp.asarray(np.asarray(embeddings, np.float32))
        if task_labels is not None and ctx.use_task:
            labels_in = jnp.asarray(np.asarray(task_labels), dtype=jnp.int32)
        mode = {(True, True): "both", (True, False): "full",
                (False, True): "instr", (False, False): "none"}[
            (need_emb, need_instr)]
        # Q (and L, inside padded_feature_tensors) padded to powers of two:
        # the fused program compiles once per padded shape, so arbitrary
        # serving batch sizes reuse log2-many variants instead of
        # retracing per (Q, L)
        q_pad = pad_pow2(n)
        if mode == "none":            # jit still wants a (placeholder) leaf
            ids = np.zeros((q_pad, 1), np.int32)
            weights = np.zeros((q_pad, 1), np.float32)
        else:
            ids, weights = ctx.padded_feature_tensors(
                texts, want_full=need_emb, want_instr=need_instr,
                q_pad=q_pad)
        if emb_in is not None:
            emb_in = jnp.pad(emb_in, ((0, q_pad - n), (0, 0)))
        if labels_in is not None:
            labels_in = jnp.pad(labels_in, (0, q_pad - n))
        pad_rows = np.zeros((q_pad - n, 3), np.int32)
        pad_rows[:, 1] = 1            # sentences >= 1: padding rows never 0/0
        comp_counts = np.concatenate([comp_counts, pad_rows])
        valid = np.arange(q_pad) < n
        ctx.record_device_batch(n, (time.perf_counter() - tc1) * 1e3,
                                (tc1 - tc0) * 1e3)
        t0 = time.perf_counter()
        feasible = self._feasible_matrix(queries, blocked)
        feas_pad = np.zeros((q_pad, self.config.max_arms), bool)
        feas_pad[:n, : feasible.shape[1]] = feasible
        if ctx.use_cluster:
            cent, cnt, ini = ctx.kmeans.device_state()
        else:
            # placeholders: the fused call donates the k-means buffers and
            # only use_cluster=True adopts the outputs — passing the live
            # resident tuple here would leave it pointing at dead buffers
            km = ctx.kmeans
            cent = jnp.zeros((km.k, km.dim), jnp.float32)
            cnt = jnp.zeros((km.k,), jnp.float32)
            ini = jnp.int32(0)
        w_clf, b_clf = ctx.classifier_params()
        st = self.policy.state
        out = _fused_decide(
            jnp.asarray(ids), jnp.asarray(weights), emb_in, labels_in,
            ctx.embedder.proj_device, w_clf, b_clf, cent, cnt, ini,
            jnp.asarray(comp_counts), jnp.float32(ctx.complexity.lo),
            ctx.complexity.bin_width32, jnp.asarray(feas_pad),
            jnp.asarray(valid), st.A_inv, st.theta, st.active,
            mode=mode, use_task=ctx.use_task, use_cluster=ctx.use_cluster,
            use_complexity=ctx.use_complexity,
            n_tasks=self.config.n_tasks, n_clusters=self.config.n_clusters,
            n_bins=self.config.n_complexity_bins,
            alpha=float(self.config.alpha_ucb))
        _sync(out)                    # timing boundary: the decision clock
        (arms_d, masked, labels, clusters, cent2, cnt2, ini2,
         comp_scores_d, comp_bins_d) = out
        if ctx.use_cluster:
            ctx.kmeans.load_device_state(cent2, cnt2, ini2)
        self.policy.advance_key()     # mirror select_batch's state step
        comp = [(float(s), int(b)) for s, b in
                zip(np.asarray(comp_scores_d, np.float32)[:n],
                    np.asarray(comp_bins_d)[:n])]
        ctxs = ctx.make_contexts(np.asarray(labels, dtype=np.int64)[:n],
                                 np.asarray(clusters, dtype=np.int64)[:n],
                                 comp)
        return (ctxs, np.asarray(arms_d, dtype=np.int64)[:n],
                np.asarray(masked, dtype=np.float32)[:n], feasible, t0)

    def feedback(self, fb: Feedback,
                 oracle_reward: Optional[float] = None) -> float:
        """Close the loop for a routed query; returns the scalarized reward.

        ``oracle_reward`` (counterfactual best reward, Eq. 6) is only
        available in simulation/offline evaluation; when given, regret is
        tracked (Eq. 8).
        """
        decision = self._pending.pop(fb.query_uid, None)
        if decision is None:
            raise KeyError(f"no pending decision for query {fb.query_uid}")
        if fb.model_index != decision.model_index:
            raise ValueError("feedback model does not match routed model")
        r_t = self.rewards.reward(fb.accuracy, fb.energy_wh)
        arm, x = decision.model_index, decision.context.vector
        self._b_acc[arm] += fb.accuracy * x
        self._b_cost[arm] += fb.energy_wh * x
        self._acc_sum[arm] += fb.accuracy
        self._cost_sum[arm] += fb.energy_wh
        self.policy.update(arm, x, r_t)
        if oracle_reward is not None:
            self.regret.step(r_t, oracle_reward)
        return r_t

    def feedback_batch(self, fbs: Sequence[Feedback],
                       oracle_rewards: Optional[Sequence[float]] = None,
                       strict: bool = True) -> List[Optional[float]]:
        """Close the loop for a batch of completions, in the given order.

        Bandit updates to *different* arms commute exactly (each arm owns
        its own sufficient statistics), so completion order across arms
        does not change the posterior; same-arm updates are applied in
        sequence.  With ``strict=False`` a feedback whose query was never
        routed here, or whose model does not match the routed arm (a hedge
        duplicate that won on a non-routed engine), is skipped and its slot
        in the returned reward list is None.
        """
        rewards: List[Optional[float]] = []
        for i, fb in enumerate(fbs):
            oracle = (oracle_rewards[i] if oracle_rewards is not None
                      else None)
            try:
                rewards.append(self.feedback(fb, oracle))
            except (KeyError, ValueError):
                if strict:
                    raise
                rewards.append(None)
        return rewards

    def oracle_reward(self, acc_by_model: np.ndarray,
                      energy_by_model: np.ndarray,
                      feasible: Optional[np.ndarray] = None) -> float:
        """Eq. 6 helper for simulators holding full counterfactual tables."""
        r = np.array([scalarize(a, e, self.config.lam, self.config.energy_scale_wh)
                      for a, e in zip(acc_by_model, energy_by_model)])
        if feasible is not None:
            r = np.where(feasible, r, -np.inf)
        return float(np.max(r))

    # -- introspection / persistence ------------------------------------------

    @property
    def mean_decision_ms(self) -> float:
        return self.decision_ms_total / max(self.n_routed, 1)

    def selection_counts(self) -> np.ndarray:
        return np.asarray(self.policy.state.counts)[: len(self.pool)]

    def state_dict(self) -> dict:
        """Serialize the full routing state — every policy variant.

        The bandit dict carries the whole ``BanditState`` (CTS's PRNG key
        and the Cholesky mode's A matrices included), the context dict
        forces the k-means device→host sync, and ``lam`` pins the
        scalarization the posterior was built under, so a restored router
        routes identically to the one that saved
        (tests/test_fleet.py::test_state_dict_route_equivalence).
        """
        return {"bandit": self.policy.state_dict(),
                "context": self.context.state_dict(),
                "lam": float(self.config.lam),
                "n_routed": self.n_routed,
                "decomposed": {"b_acc": self._b_acc.copy(),
                               "b_cost": self._b_cost.copy(),
                               "acc_sum": self._acc_sum.copy(),
                               "cost_sum": self._cost_sum.copy()},
                "pred_cost_mean": {"mean": self._pred_cost_mean.copy(),
                                   "seen": self._pred_cost_seen.copy()}}

    def load_state_dict(self, d: dict) -> None:
        self.policy.load_state_dict(d["bandit"])
        self.context.load_state_dict(d["context"])
        lam = d.get("lam")
        if lam is not None:
            # restore λ directly (no rescalarize — the loaded posterior
            # was already built under it; rebuilding from the decomposed
            # sums below would be a no-op modulo float noise)
            self.config.lam = float(lam)
        self.n_routed = int(d.get("n_routed", 0))
        dec = d.get("decomposed")
        if dec is not None:
            self._b_acc = np.asarray(dec["b_acc"], np.float64).copy()
            self._b_cost = np.asarray(dec["b_cost"], np.float64).copy()
            self._acc_sum = np.asarray(dec["acc_sum"], np.float64).copy()
            self._cost_sum = np.asarray(dec["cost_sum"], np.float64).copy()
            self._decomposed_complete = True
        else:
            # pre-decomposition checkpoint: the loaded posterior is valid
            # but cannot be re-derived — set_lambda keeps working, minus
            # the instant posterior rebuild
            self._decomposed_complete = False
        pc = d.get("pred_cost_mean")
        if pc is not None:
            self._pred_cost_mean = np.asarray(pc["mean"], np.float64).copy()
            self._pred_cost_seen = np.asarray(pc["seen"], bool).copy()
