"""GreenServ router: context → feasibility → bandit → reward → update.

Implements Algorithm 1 of the paper as a long-lived service object:

    for each query q_t:
        x_t  = GenerateContext(q_t)                 (ContextGenerator)
        m_t  = SelectModel(x_t, M_t*, A)            (BanditPolicy over pool)
        resp = InferenceExecution(m_t, q_t)         (caller / serving engine)
        acc, energy, latency = Monitor(resp)        (caller feeds back)
        r_t  = (1-λ)·acc − λ·energy                 (RewardManager)
        UpdateMAB(A_m, b_m, x_t, r_t)               (BanditPolicy.update)

The router is deliberately decoupled from inference execution: ``route()``
returns a decision, the engine executes it, and ``feedback()`` closes the
loop.  This matches the paper's partial-feedback structure and lets the
serving runtime batch/queue independently.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.bandits import BanditPolicy
from repro.core.context import ContextGenerator
from repro.core.pool import ModelPool
from repro.core.rewards import RegretTracker, RewardManager, scalarize
from repro.core.types import (ContextVector, Feedback, ModelProfile, Query,
                              RouteDecision, RouterConfig)


class GreenServRouter:
    """The paper's contribution as a composable module."""

    def __init__(self, config: RouterConfig, pool: ModelPool,
                 context: Optional[ContextGenerator] = None):
        self.config = config
        self.pool = pool
        self.context = context or ContextGenerator(config)
        self.policy = BanditPolicy(config, n_arms=len(pool))
        self.rewards = RewardManager(config)
        self.regret = RegretTracker()
        self._pending: Dict[int, RouteDecision] = {}
        self.decision_ms_total = 0.0
        self.n_routed = 0
        # λ-decomposed sufficient statistics: b_m = (1-λ)·Σ acc·x −
        # λ·Σ (e/scale)·x, so set_lambda can re-scalarize the bandit
        # exactly instead of waiting for fresh pulls to wash out old λ
        m, d = config.max_arms, config.context_dim
        self._b_acc = np.zeros((m, d), np.float64)
        self._b_cost = np.zeros((m, d), np.float64)
        self._acc_sum = np.zeros(m, np.float64)
        self._cost_sum = np.zeros(m, np.float64)
        self._decomposed_complete = True
        # zero-calibration model addition: pool insert → fresh bandit arm
        pool.on_add(self._on_model_added)

    # -- pool growth ---------------------------------------------------------

    def _on_model_added(self, profile: ModelProfile, idx: int) -> None:
        arm = self.policy.add_arm()
        if arm != idx:
            raise RuntimeError(
                f"pool/bandit index skew: pool={idx} arm={arm}")
        self._b_acc[arm] = 0.0
        self._b_cost[arm] = 0.0
        self._acc_sum[arm] = 0.0
        self._cost_sum[arm] = 0.0

    # -- online λ control (telemetry.budget drives this) -----------------------

    def set_lambda(self, lam: float, rescalarize: bool = True) -> None:
        """Retune the accuracy–energy trade-off online (governor hook).

        Future rewards scalarize under the new λ immediately (RewardManager
        shares this config).  With ``rescalarize`` the bandit's reward
        statistics are also rebuilt from the decomposed accuracy/energy
        sums, so the *posterior* shifts toward cheaper arms in the same
        step — A/A⁻¹ are context-only and stay untouched.
        """
        if not (0.0 <= lam <= 1.0):
            raise ValueError(f"lam must be in [0, 1], got {lam}")
        if lam == self.config.lam:
            return
        self.config.lam = lam
        # a checkpoint from before decomposed stats existed cannot be
        # rescalarized: the sums would be partial (or zero) and rebuilding
        # b/θ from them would silently wipe the restored posterior
        if rescalarize and self._decomposed_complete:
            scale = self.config.energy_scale_wh
            b = (1.0 - lam) * self._b_acc - lam * self._b_cost / scale
            rsum = (1.0 - lam) * self._acc_sum - lam * self._cost_sum / scale
            self.policy.rescalarize(b, rsum)

    # -- Algorithm 1 ---------------------------------------------------------

    def route(self, query: Query) -> RouteDecision:
        # the batch-of-one: keeps the sequential and batched decision paths
        # structurally identical (see route_batch's equivalence guarantee)
        return self.route_batch([query])[0]

    def route_batch(self, queries: Sequence[Query],
                    energy_discounts_wh: Optional[np.ndarray] = None,
                    embeddings: Optional[np.ndarray] = None,
                    task_labels: Optional[np.ndarray] = None
                    ) -> List[RouteDecision]:
        """Route an admitted batch in one shot (the serving hot path).

        Featurization is vectorized (one embed + one classifier matmul for
        the whole batch) and LinUCB scoring runs as a single fused (Q, M)
        kernel call, so per-query decision overhead amortizes to the
        batched cost.  Arm choices are identical to calling ``route`` on
        each query in order (k-means updates are applied in arrival order,
        and LinUCB selection is deterministic given the bandit state).

        ``energy_discounts_wh`` (Q, n_models), optional: expected Wh each
        arm would *save* on each query — e.g. a prefix-KV cache hit whose
        spliced tokens skip prefill (``PoolServer`` fills this from the
        engines' prefix indexes).  The discount enters the decision as the
        energy term of the reward it cancels, ``λ·ΔWh/energy_scale`` added
        to the arm's score, so a warm-cache arm can win over a nominally
        cheaper cold one.  The bandit's *posterior* is untouched: the
        realized saving arrives through feedback (cheap completions), the
        discount only tilts this decision.  Only rows with a nonzero
        discount are re-picked — undiscounted queries keep their original
        arm, so a stochastic policy's exploration draws survive except on
        the queries the tilt is actually about (where the discounted
        greedy choice deliberately wins).

        ``embeddings`` (Q, dim) / ``task_labels`` (Q,) forward feature
        work the caller already did on these texts (the scheduler's cache
        probe) into ``ContextGenerator.batch`` — bitwise identical to
        recomputing, since embedder and classifier are deterministic.
        """
        if not queries:
            return []
        ctxs = self.context.batch([q.text for q in queries],
                                  embeddings=embeddings,
                                  task_labels=task_labels)
        t0 = time.perf_counter()
        masks = [self.pool.feasible_mask(q) for q in queries]
        # a concurrent pool.add() mid-batch yields ragged rows; pad earlier
        # rows with False (those queries were routed before the new model
        # existed, matching sequential semantics)
        width = max(m.shape[0] for m in masks)
        feasible = np.zeros((len(masks), width), dtype=bool)
        for i, m in enumerate(masks):
            feasible[i, : m.shape[0]] = m
        x = np.stack([c.vector for c in ctxs])
        arms, scores = self.policy.select_batch(x, feasible)
        if energy_discounts_wh is not None:
            d = np.asarray(energy_discounts_wh, np.float32)
            if d.shape[0] != len(queries):
                raise ValueError(
                    f"energy_discounts_wh rows {d.shape[0]} != batch "
                    f"{len(queries)}")
            rows = np.flatnonzero(d.any(axis=1))
            if rows.size:
                bonus = np.zeros_like(scores)
                w = min(d.shape[1], bonus.shape[1])
                bonus[:, :w] = (self.config.lam * d[:, :w]
                                / self.config.energy_scale_wh)
                scores = scores + bonus
                # infeasible arms carry NEG_INF scores; a finite bonus
                # cannot resurrect them, so an argmax re-pick of the
                # discounted rows suffices
                arms = arms.copy()
                arms[rows] = np.argmax(scores[rows], axis=1).astype(
                    arms.dtype)
        batch_ms = (time.perf_counter() - t0) * 1e3
        per_query_ms = batch_ms / len(queries)
        self.decision_ms_total += batch_ms
        self.n_routed += len(queries)
        decisions: List[RouteDecision] = []
        for q, ctx, arm, score_row, feas_row in zip(queries, ctxs, arms,
                                                    scores, feasible):
            decision = RouteDecision(
                query_uid=q.uid, model_index=int(arm),
                model_name=self.pool[int(arm)].name, context=ctx,
                ucb_scores=score_row, feasible_mask=feas_row,
                overhead_ms=per_query_ms)
            self._pending[q.uid] = decision
            decisions.append(decision)
        return decisions

    def feedback(self, fb: Feedback,
                 oracle_reward: Optional[float] = None) -> float:
        """Close the loop for a routed query; returns the scalarized reward.

        ``oracle_reward`` (counterfactual best reward, Eq. 6) is only
        available in simulation/offline evaluation; when given, regret is
        tracked (Eq. 8).
        """
        decision = self._pending.pop(fb.query_uid, None)
        if decision is None:
            raise KeyError(f"no pending decision for query {fb.query_uid}")
        if fb.model_index != decision.model_index:
            raise ValueError("feedback model does not match routed model")
        r_t = self.rewards.reward(fb.accuracy, fb.energy_wh)
        arm, x = decision.model_index, decision.context.vector
        self._b_acc[arm] += fb.accuracy * x
        self._b_cost[arm] += fb.energy_wh * x
        self._acc_sum[arm] += fb.accuracy
        self._cost_sum[arm] += fb.energy_wh
        self.policy.update(arm, x, r_t)
        if oracle_reward is not None:
            self.regret.step(r_t, oracle_reward)
        return r_t

    def feedback_batch(self, fbs: Sequence[Feedback],
                       oracle_rewards: Optional[Sequence[float]] = None,
                       strict: bool = True) -> List[Optional[float]]:
        """Close the loop for a batch of completions, in the given order.

        Bandit updates to *different* arms commute exactly (each arm owns
        its own sufficient statistics), so completion order across arms
        does not change the posterior; same-arm updates are applied in
        sequence.  With ``strict=False`` a feedback whose query was never
        routed here, or whose model does not match the routed arm (a hedge
        duplicate that won on a non-routed engine), is skipped and its slot
        in the returned reward list is None.
        """
        rewards: List[Optional[float]] = []
        for i, fb in enumerate(fbs):
            oracle = (oracle_rewards[i] if oracle_rewards is not None
                      else None)
            try:
                rewards.append(self.feedback(fb, oracle))
            except (KeyError, ValueError):
                if strict:
                    raise
                rewards.append(None)
        return rewards

    def oracle_reward(self, acc_by_model: np.ndarray,
                      energy_by_model: np.ndarray,
                      feasible: Optional[np.ndarray] = None) -> float:
        """Eq. 6 helper for simulators holding full counterfactual tables."""
        r = np.array([scalarize(a, e, self.config.lam, self.config.energy_scale_wh)
                      for a, e in zip(acc_by_model, energy_by_model)])
        if feasible is not None:
            r = np.where(feasible, r, -np.inf)
        return float(np.max(r))

    # -- introspection / persistence ------------------------------------------

    @property
    def mean_decision_ms(self) -> float:
        return self.decision_ms_total / max(self.n_routed, 1)

    def selection_counts(self) -> np.ndarray:
        return np.asarray(self.policy.state.counts)[: len(self.pool)]

    def state_dict(self) -> dict:
        return {"bandit": self.policy.state_dict(),
                "context": self.context.state_dict(),
                "n_routed": self.n_routed,
                "decomposed": {"b_acc": self._b_acc.copy(),
                               "b_cost": self._b_cost.copy(),
                               "acc_sum": self._acc_sum.copy(),
                               "cost_sum": self._cost_sum.copy()}}

    def load_state_dict(self, d: dict) -> None:
        self.policy.load_state_dict(d["bandit"])
        self.context.load_state_dict(d["context"])
        self.n_routed = int(d.get("n_routed", 0))
        dec = d.get("decomposed")
        if dec is not None:
            self._b_acc = np.asarray(dec["b_acc"], np.float64).copy()
            self._b_cost = np.asarray(dec["b_cost"], np.float64).copy()
            self._acc_sum = np.asarray(dec["acc_sum"], np.float64).copy()
            self._cost_sum = np.asarray(dec["cost_sum"], np.float64).copy()
            self._decomposed_complete = True
        else:
            # pre-decomposition checkpoint: the loaded posterior is valid
            # but cannot be re-derived — set_lambda keeps working, minus
            # the instant posterior rebuild
            self._decomposed_complete = False
