"""Device-residency accounting for control-plane state.

The fleet convention (docs/FLEET.md) is that ``route_batch`` performs
*zero* per-call host↔device transfer of learning state: the bandit's
sufficient statistics (``BanditState`` — already jnp arrays threaded
through jitted select/update) and the k-means centroids (mirrored on
device by ``OnlineKMeans``) stay resident across batches, and the host
numpy copies are synchronized lazily, only when something actually reads
them (``state_dict``, the host reference path, the fleet all-reduce).

``TransferLedger`` is the bookkeeping that makes the convention
testable: every deliberate host→device upload or device→host download of
*persistent state* bumps a counter at the exact conversion site.  Steady
-state routing on the device path must leave both counters flat
(tests/test_fleet.py::test_route_batch_zero_state_transfers); per-batch
*data* movement — feature ids in, decisions out — is not state and is
never counted.

This lives in ``core`` (not ``repro.fleet``) because ``core.context``
and ``core.bandits`` count into it and must not import the fleet package
(fleet → controller → router → context would cycle); the fleet package
re-exports it.
"""
from __future__ import annotations


class TransferLedger:
    """Counts deliberate host↔device transfers of persistent state."""

    __slots__ = ("h2d", "d2h")

    def __init__(self) -> None:
        self.h2d = 0
        self.d2h = 0

    def count_h2d(self, n: int = 1) -> None:
        self.h2d += n

    def count_d2h(self, n: int = 1) -> None:
        self.d2h += n

    def reset(self) -> None:
        self.h2d = 0
        self.d2h = 0

    def snapshot(self) -> dict:
        return {"h2d": self.h2d, "d2h": self.d2h}

    @property
    def total(self) -> int:
        return self.h2d + self.d2h

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"TransferLedger(h2d={self.h2d}, d2h={self.d2h})"
