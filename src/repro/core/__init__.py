"""GreenServ core: contextual-bandit routing for multi-model LLM inference."""
from repro.core.bandits import BanditPolicy, BanditState, add_arm, init_state
from repro.core.context import (ContextGenerator, FleschComplexity,
                                OnlineKMeans, TaskClassifier,
                                flesch_reading_ease)
from repro.core.embedding import EmbeddingModel
from repro.core.energy import (CostModelParams, EnergyMonitor, RooflineTerms,
                               energy_joules, energy_wh, roofline)
from repro.core.pool import ModelPool
from repro.core.rewards import RegretTracker, RewardManager, scalarize
from repro.core.router import GreenServRouter
from repro.core.types import (ContextVector, Feedback, ModelProfile, Query,
                              RouteDecision, RouterConfig, TaskType)

__all__ = [
    "BanditPolicy", "BanditState", "add_arm", "init_state",
    "ContextGenerator", "FleschComplexity", "OnlineKMeans", "TaskClassifier",
    "flesch_reading_ease", "EmbeddingModel",
    "CostModelParams", "EnergyMonitor", "RooflineTerms", "energy_joules",
    "energy_wh", "roofline", "ModelPool", "RegretTracker", "RewardManager",
    "scalarize", "GreenServRouter", "ContextVector", "Feedback",
    "ModelProfile", "Query", "RouteDecision", "RouterConfig", "TaskType",
]
