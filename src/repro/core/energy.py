"""Analytic TPU energy model + roofline terms (hardware adaptation, DESIGN §4).

The paper measures GPU energy with zeus/NVML.  TPU pods expose no per-query
power counters, so we model energy from first principles:

    t_step  = max(t_compute, t_memory, t_collective)          (roofline)
    E_step  = P_static · t_step
            + e_flop · FLOPs + e_hbm · HBM_bytes + e_ici · ICI_bytes

The FLOPs/bytes terms come from ``compiled.cost_analysis()`` at dry-run time
and from the analytic per-token transformer cost model (below) at serving
time.  Constants are from public TPU v5e specs; the interface is pluggable so
a measured-power backend can replace this on hardware with telemetry.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

# --- TPU v5e hardware constants (per chip) ---------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s per link (~)
ICI_LINKS = 4                   # 2D torus: 4 links/chip on v5e
CHIP_TDP_W = 200.0              # board power envelope
CHIP_IDLE_W = 60.0              # static / leakage share

# dynamic energy coefficients (derived so that a chip at 100% utilization of
# one resource dissipates (TDP - idle) through that resource)
E_PER_FLOP = (CHIP_TDP_W - CHIP_IDLE_W) / PEAK_FLOPS_BF16     # J / FLOP
E_PER_HBM_BYTE = (CHIP_TDP_W - CHIP_IDLE_W) / HBM_BW          # J / byte
E_PER_ICI_BYTE = (CHIP_TDP_W - CHIP_IDLE_W) / (ICI_BW_PER_LINK * ICI_LINKS)

JOULES_PER_WH = 3600.0


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """The three §Roofline terms, in seconds, for one step on `chips` chips."""

    t_compute: float
    t_memory: float
    t_collective: float
    flops: float
    hbm_bytes: float
    ici_bytes: float
    chips: int

    @property
    def t_step(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of ideal (compute-bound) time: 1.0 = at compute roofline."""
        if self.t_step <= 0:
            return 0.0
        return self.t_compute / self.t_step


def roofline(flops: float, hbm_bytes: float, ici_bytes: float,
             chips: int = 1) -> RooflineTerms:
    """FLOPs/bytes are *totals*; we divide across chips (already-sharded HLO
    cost_analysis reports per-device numbers — pass chips=1 in that case)."""
    return RooflineTerms(
        t_compute=flops / (chips * PEAK_FLOPS_BF16),
        t_memory=hbm_bytes / (chips * HBM_BW),
        t_collective=ici_bytes / (chips * ICI_BW_PER_LINK * ICI_LINKS),
        flops=flops, hbm_bytes=hbm_bytes, ici_bytes=ici_bytes, chips=chips)


def energy_joules(terms: RooflineTerms) -> float:
    """Analytic per-step energy across all chips involved."""
    dynamic = (E_PER_FLOP * terms.flops + E_PER_HBM_BYTE * terms.hbm_bytes +
               E_PER_ICI_BYTE * terms.ici_bytes)
    static = CHIP_IDLE_W * terms.t_step * terms.chips
    return dynamic + static


def energy_wh(terms: RooflineTerms) -> float:
    return energy_joules(terms) / JOULES_PER_WH


# ---------------------------------------------------------------------------
# Analytic transformer cost model (per-query serving energy).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostModelParams:
    """Minimal shape info needed for the 6ND-style cost model."""

    n_params: float                  # total parameters
    n_active_params: float           # active per token (MoE: routed subset)
    d_model: int
    n_layers: int
    kv_heads: int
    head_dim: int
    dtype_bytes: int = 2


def decode_step_cost(p: CostModelParams, kv_len: int, batch: int = 1):
    """(flops, hbm_bytes) for one decode token per sequence.

    FLOPs ≈ 2·N_active per token (matmul) + attention 2·2·kv·d_kv reads.
    HBM ≈ full weight read (decode is weight-bandwidth-bound) + KV read.
    """
    flops = 2.0 * p.n_active_params * batch
    kv_dim = p.kv_heads * p.head_dim
    flops += 4.0 * kv_len * kv_dim * p.n_layers * batch
    weight_bytes = p.n_active_params * p.dtype_bytes
    kv_bytes = 2.0 * kv_len * kv_dim * p.n_layers * p.dtype_bytes * batch
    return flops, weight_bytes + kv_bytes


def prefill_chunk_cost(p: CostModelParams, n_tokens: int, kv_len: int,
                       batch: int = 1):
    """(flops, hbm_bytes) for one *chunked-prefill step*: ``n_tokens``
    prompt tokens appended per sequence at cache offset ``kv_len`` — the
    prefill-phase counterpart of ``decode_step_cost``.

    FLOPs ≈ 2·N_active per token (matmuls, compute-bound — prefill
    amortizes the weight read over the chunk) + attention QK/AV against the
    growing cache (midpoint kv depth).  HBM ≈ one active-weight read for
    the whole chunk step (this is the chunking win: the one-token path
    pays that read per token) + KV read/write at the chunk's depth.
    Everything is per step; multiply by steps for a whole prompt.
    """
    n = max(n_tokens, 1)
    flops = 2.0 * p.n_active_params * n * batch
    kv_dim = p.kv_heads * p.head_dim
    mid_kv = kv_len + (n + 1) / 2.0
    flops += 4.0 * n * mid_kv * kv_dim * p.n_layers * batch
    weight_bytes = p.n_active_params * p.dtype_bytes
    kv_bytes = 2.0 * (kv_len + n) * kv_dim * p.n_layers * p.dtype_bytes * batch
    return flops, weight_bytes + kv_bytes


def chunk_rider_cost(p: CostModelParams, chunk: int, kv_len: int,
                     batch: int = 1):
    """(flops, hbm_bytes) for ONE decode token that *rides along* inside a
    ``chunk``-wide chunked-prefill step (a mixed tick on a unified
    engine).  The fused chunk kernel computes all ``chunk`` positions for
    every live slot — padding is real compute, not free — so the rider's
    matmul and attention FLOPs are chunk-padded while its HBM traffic
    stays decode-shaped (weight read + KV read at its depth).  This is
    the prefill/decode *interference* cost that role-specialized
    (disaggregated) engines avoid: on a decode-only engine the same token
    is charged plain ``decode_step_cost``.
    """
    C = max(chunk, 1)
    flops = 2.0 * p.n_active_params * C * batch
    kv_dim = p.kv_heads * p.head_dim
    flops += 4.0 * C * max(kv_len, 1) * kv_dim * p.n_layers * batch
    weight_bytes = p.n_active_params * p.dtype_bytes
    kv_bytes = 2.0 * max(kv_len, 1) * kv_dim * p.n_layers * p.dtype_bytes \
        * batch
    return flops, weight_bytes + kv_bytes


def kv_migration_cost(p: CostModelParams, n_tokens: int):
    """(flops, hbm_bytes) to move ``n_tokens`` of prompt KV between
    engines at the prefill→decode phase boundary: K and V, read out of
    the prefill engine's cache and written into the decode engine's slot
    (2 tensors × 2 directions).  Pure data movement — disaggregation pays
    this honestly, and still has to win on the metered ledger."""
    kv_dim = p.kv_heads * p.head_dim
    bytes_ = 4.0 * max(n_tokens, 0) * kv_dim * p.n_layers * p.dtype_bytes
    return 0.0, bytes_


def prefill_cost(p: CostModelParams, seq_len: int, batch: int = 1):
    """(flops, hbm_bytes) for a full prefill."""
    flops = 2.0 * p.n_active_params * seq_len * batch
    kv_dim = p.kv_heads * p.head_dim
    flops += 2.0 * seq_len * seq_len * kv_dim * p.n_layers * batch  # attn QK+AV
    act_bytes = 10.0 * seq_len * p.d_model * p.n_layers * p.dtype_bytes * batch
    weight_bytes = p.n_params * p.dtype_bytes
    return flops, weight_bytes + act_bytes


class EnergyMonitor:
    """Pluggable per-query energy accounting (zeus stand-in, DESIGN §4)."""

    def __init__(self, chips: int = 1):
        self.chips = chips
        self.total_joules = 0.0
        self.n_queries = 0

    def measure_query(self, p: CostModelParams, input_tokens: int,
                      output_tokens: int, batch: int = 1) -> float:
        """Returns modeled Wh for one query; accumulates totals."""
        f_pre, b_pre = prefill_cost(p, max(input_tokens, 1), batch)
        joules = energy_joules(roofline(f_pre, b_pre, 0.0, self.chips))
        kv = input_tokens
        # decode tokens at increasing kv length (use midpoint approximation)
        mid_kv = kv + max(output_tokens, 1) // 2
        f_dec, b_dec = decode_step_cost(p, mid_kv, batch)
        joules += max(output_tokens, 0) * energy_joules(
            roofline(f_dec, b_dec, 0.0, self.chips))
        self.total_joules += joules
        self.n_queries += 1
        return joules / JOULES_PER_WH

    @property
    def total_wh(self) -> float:
        return self.total_joules / JOULES_PER_WH
