"""Deterministic sentence embeddings for the context generator.

The paper uses sentence-transformers/all-MiniLM-L6-v2 (384-d).  This container
is offline, so we provide a pure-numpy hashed n-gram encoder with the same
interface and dimensionality: tokens and character trigrams are hashed into a
fixed-size space, tf-weighted, projected through a fixed random (seeded)
Gaussian matrix, and L2-normalized.  This preserves the two properties the
router actually relies on:

  * queries about similar topics land near each other (shared vocabulary →
    shared hash buckets → similar projections), so online k-means produces
    stable semantic clusters;
  * the map is deterministic and cheap (paper overhead budget: ~3 ms/query).

A real deployment would swap in a MiniLM forward pass behind ``EmbeddingModel``.
"""
from __future__ import annotations

import hashlib
import re
from typing import List, Sequence

import numpy as np

_TOKEN_RE = re.compile(r"[a-z0-9']+")
_HASH_DIM = 2048
_EMBED_DIM = 384


def _stable_hash(s: str) -> int:
    # Python's hash() is salted per-process; use blake2 for determinism.
    return int.from_bytes(hashlib.blake2b(s.encode(), digest_size=8).digest(), "little")


def tokenize(text: str) -> List[str]:
    return _TOKEN_RE.findall(text.lower())


class EmbeddingModel:
    """384-d deterministic sentence encoder (drop-in for MiniLM)."""

    def __init__(self, dim: int = _EMBED_DIM, hash_dim: int = _HASH_DIM, seed: int = 1234):
        self.dim = dim
        self.hash_dim = hash_dim
        rng = np.random.default_rng(seed)
        # fixed projection: hashed bag-of-features -> dense embedding
        self._proj = rng.standard_normal((hash_dim, dim)).astype(np.float32)
        self._proj /= np.sqrt(hash_dim)

    def _sparse_counts(self, text: str) -> np.ndarray:
        counts = np.zeros(self.hash_dim, dtype=np.float32)
        toks = tokenize(text)
        for tok in toks:
            counts[_stable_hash("w:" + tok) % self.hash_dim] += 1.0
            # char trigrams catch morphology / domain jargon
            padded = f"^{tok}$"
            for i in range(len(padded) - 2):
                counts[_stable_hash("c:" + padded[i : i + 3]) % self.hash_dim] += 0.5
        # bigrams give phrase-level signal (cheap MiniLM stand-in)
        for a, b in zip(toks, toks[1:]):
            counts[_stable_hash(f"b:{a}_{b}") % self.hash_dim] += 0.75
        return counts

    def encode(self, text: str) -> np.ndarray:
        """Embed one string -> (dim,) unit vector."""
        counts = self._sparse_counts(text)
        total = counts.sum()
        if total > 0:
            counts = np.log1p(counts)  # sublinear tf
        v = counts @ self._proj
        n = np.linalg.norm(v)
        return (v / n if n > 0 else v).astype(np.float32)

    def encode_batch(self, texts: Sequence[str]) -> np.ndarray:
        if len(texts) == 0:
            return np.zeros((0, self.dim), dtype=np.float32)
        return np.stack([self.encode(t) for t in texts])
