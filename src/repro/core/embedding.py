"""Deterministic sentence embeddings for the context generator.

The paper uses sentence-transformers/all-MiniLM-L6-v2 (384-d).  This container
is offline, so we provide a pure-numpy hashed n-gram encoder with the same
interface and dimensionality: tokens and character trigrams are hashed into a
fixed-size space, tf-weighted, projected through a fixed random (seeded)
Gaussian matrix, and L2-normalized.  This preserves the two properties the
router actually relies on:

  * queries about similar topics land near each other (shared vocabulary →
    shared hash buckets → similar projections), so online k-means produces
    stable semantic clusters;
  * the map is deterministic and cheap (paper overhead budget: ~3 ms/query).

A real deployment would swap in a MiniLM forward pass behind ``EmbeddingModel``.

Two encode paths share the same hashing:

  * ``encode``/``encode_batch`` — the pure-numpy reference (and the
    router's host-path fallback);
  * ``hashed_features`` + ``kernels/featurize`` — one vectorized host
    pass producing padded ``(Q, L)`` feature-id/weight tensors (string
    work: tokenize + blake2, memoized per token), with the scatter /
    log1p tf / projection / L2 norm fused into a Pallas kernel on device.
"""
from __future__ import annotations

import hashlib
import re
from typing import List, Optional, Sequence, Tuple

import numpy as np

_TOKEN_RE = re.compile(r"[a-z0-9']+")
_HASH_DIM = 2048
_EMBED_DIM = 384


def _stable_hash(s: str) -> int:
    # Python's hash() is salted per-process; use blake2 for determinism.
    return int.from_bytes(hashlib.blake2b(s.encode(), digest_size=8).digest(), "little")


def tokenize(text: str) -> List[str]:
    return _TOKEN_RE.findall(text.lower())


class EmbeddingModel:
    """384-d deterministic sentence encoder (drop-in for MiniLM)."""

    def __init__(self, dim: int = _EMBED_DIM, hash_dim: int = _HASH_DIM, seed: int = 1234):
        self.dim = dim
        self.hash_dim = hash_dim
        rng = np.random.default_rng(seed)
        # fixed projection: hashed bag-of-features -> dense embedding
        self._proj = rng.standard_normal((hash_dim, dim)).astype(np.float32)
        self._proj /= np.sqrt(hash_dim)
        self._proj_dev = None          # jnp copy, built on first device use
        # token -> [(bucket, weight)] memo: the blake2 hash of a token and
        # its trigrams depends only on the token, so repeated vocabulary
        # across a batch (and across batches) hashes exactly once.  Flushed
        # wholesale at _MEMO_CAP so a long-lived server over unbounded
        # vocabulary (ids, URLs, noise) can't leak memory — a flush only
        # costs rehashing, never changes a result.
        self._tok_feats: dict = {}
        self._bigram_ids: dict = {}

    def _sparse_counts(self, text: str) -> np.ndarray:
        counts = np.zeros(self.hash_dim, dtype=np.float32)
        for bucket, weight in self._features(text):
            counts[bucket] += weight
        return counts

    def encode(self, text: str) -> np.ndarray:
        """Embed one string -> (dim,) unit vector."""
        counts = self._sparse_counts(text)
        total = counts.sum()
        if total > 0:
            counts = np.log1p(counts)  # sublinear tf
        v = counts @ self._proj
        n = np.linalg.norm(v)
        return (v / n if n > 0 else v).astype(np.float32)

    def encode_batch(self, texts: Sequence[str]) -> np.ndarray:
        if len(texts) == 0:
            return np.zeros((0, self.dim), dtype=np.float32)
        return np.stack([self.encode(t) for t in texts])

    # -- device featurization (kernels/featurize) ---------------------------

    _MEMO_CAP = 262_144

    def _features(self, text: str) -> List[Tuple[int, float]]:
        """One text's hashed (bucket, weight) feature list — THE feature
        definition (word 1.0, char-trigram 0.5, bigram 0.75); both encode
        paths build on it, so host and device can never disagree on what a
        feature is.  Duplicates are kept; dense accumulation and the device
        scatter sum them identically."""
        if (len(self._tok_feats) > self._MEMO_CAP
                or len(self._bigram_ids) > self._MEMO_CAP):
            self._tok_feats.clear()
            self._bigram_ids.clear()
        toks = tokenize(text)
        feats: List[Tuple[int, float]] = []
        for tok in toks:
            cached = self._tok_feats.get(tok)
            if cached is None:
                # char trigrams catch morphology / domain jargon
                cached = [(_stable_hash("w:" + tok) % self.hash_dim, 1.0)]
                padded = f"^{tok}$"
                for i in range(len(padded) - 2):
                    cached.append((
                        _stable_hash("c:" + padded[i:i + 3]) % self.hash_dim,
                        0.5))
                self._tok_feats[tok] = cached
            feats.extend(cached)
        # bigrams give phrase-level signal (cheap MiniLM stand-in)
        for a, b in zip(toks, toks[1:]):
            bg = (a, b)
            h = self._bigram_ids.get(bg)
            if h is None:
                h = self._bigram_ids[bg] = \
                    _stable_hash(f"b:{a}_{b}") % self.hash_dim
            feats.append((h, 0.75))
        return feats

    def hashed_features(self, texts: Sequence[str]
                        ) -> Tuple[np.ndarray, np.ndarray]:
        """Texts → padded ``(Q, L)`` int32 bucket ids + float32 tf weights.

        This is the single host pass of the device featurization path:
        tokenization + blake2 hashing (string work that cannot leave the
        host) happens here, memoized per token, and everything downstream —
        scatter, log1p tf, projection, L2 norm — runs in the
        ``kernels/featurize`` Pallas kernel.  Padding uses id −1 / weight 0
        (matches no hash bucket); a featureless text (empty/whitespace)
        yields an all-padding row and hence the zero embedding, exactly as
        ``encode`` does."""
        rows = [self._features(t) for t in texts]
        q = len(rows)
        width = max((len(r) for r in rows), default=0)
        ids = np.full((q, max(width, 1)), -1, dtype=np.int32)
        weights = np.zeros((q, max(width, 1)), dtype=np.float32)
        for i, feats in enumerate(rows):
            if feats:
                f = np.asarray(feats, dtype=np.float32)
                ids[i, : len(feats)] = f[:, 0].astype(np.int32)
                weights[i, : len(feats)] = f[:, 1]
        return ids, weights

    @property
    def proj_device(self):
        """The fixed projection as a device (jnp) array, built lazily so
        pure-host users never touch JAX."""
        if self._proj_dev is None:
            import jax.numpy as jnp
            self._proj_dev = jnp.asarray(self._proj)
        return self._proj_dev

    def encode_batch_device(self, texts: Sequence[str],
                            interpret: Optional[bool] = None) -> np.ndarray:
        """``encode_batch`` through the fused Pallas featurization kernel:
        one host hashing pass + one device call.  Agrees with the host
        reference within float32 tolerance (asserted by the parity suite in
        ``tests/test_featurize_parity.py``)."""
        if len(texts) == 0:
            return np.zeros((0, self.dim), dtype=np.float32)
        import jax.numpy as jnp
        from repro.kernels.featurize import hashed_embed
        ids, weights = self.hashed_features(texts)
        out = hashed_embed(jnp.asarray(ids), jnp.asarray(weights),
                           self.proj_device, interpret=interpret)
        return np.asarray(out)
