"""Contextual multi-armed bandits (paper §4.3) in batched, jittable JAX.

Implements the three policies the paper evaluates:

  * LinUCB (primary, Eq. 13)        — linear reward model + UCB exploration
  * Contextual Thompson Sampling    — Bayesian linear posterior sampling
  * ε-Greedy (contextual & plain)   — decayed random exploration

All state lives in a single ``BanditState`` pytree with *static* arm capacity
(``max_arms``) and an ``active`` mask, so jitted select/update never retrace
when models are added at runtime (paper §6.3.4: zero-calibration addition).

Two solve modes:

  * ``sherman_morrison`` (default, beyond-paper): maintain A_m⁻¹ directly via
    the rank-1 Sherman–Morrison identity — O(d²) per update and O(|M|·d²) per
    decision.  Mathematically identical to inverting A_m.
  * ``cholesky`` (paper-faithful, App. B): re-solve A_m θ = b_m per decision —
    O(|M|·d³).  Kept for the §Perf baseline comparison.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.residency import TransferLedger
from repro.core.types import RouterConfig
from repro.kernels.featurize.ops import pad_pow2
from repro.kernels.linucb.ops import linucb_scores as linucb_scores_kernel

NEG_INF = -1e30


class BanditState(NamedTuple):
    """Per-arm sufficient statistics. Shapes: M=max_arms, d=context dim."""

    A: jax.Array          # (M, d, d) ridge design matrices  A_m = λI + Σ x xᵀ
    A_inv: jax.Array      # (M, d, d) maintained inverses (Sherman–Morrison)
    b: jax.Array          # (M, d)    reward-weighted contexts Σ r x
    theta: jax.Array      # (M, d)    cached θ̂_m = A_m⁻¹ b_m
    reward_sum: jax.Array # (M,)      Σ r      (non-contextual ε-greedy)
    counts: jax.Array     # (M,)      pull counts
    active: jax.Array     # (M,) bool — is this slot a live model?
    eps: jax.Array        # ()        current ε (decayed)
    t: jax.Array          # ()        global step
    key: jax.Array        # PRNG key


def init_state(config: RouterConfig, n_arms: int) -> BanditState:
    m, d = config.max_arms, config.context_dim
    if n_arms > m:
        raise ValueError(f"n_arms={n_arms} exceeds max_arms={m}")
    lam = config.lambda_reg
    eye = jnp.eye(d, dtype=jnp.float32)
    return BanditState(
        A=jnp.tile(eye[None] * lam, (m, 1, 1)),
        A_inv=jnp.tile(eye[None] / lam, (m, 1, 1)),
        b=jnp.zeros((m, d), jnp.float32),
        theta=jnp.zeros((m, d), jnp.float32),
        reward_sum=jnp.zeros((m,), jnp.float32),
        counts=jnp.zeros((m,), jnp.float32),
        active=jnp.arange(m) < n_arms,
        eps=jnp.float32(config.epsilon0),
        t=jnp.int32(0),
        key=jax.random.PRNGKey(config.seed),
    )


def add_arm(state: BanditState, config: RouterConfig) -> Tuple[BanditState, int]:
    """Activate the next free slot with a fresh ridge prior (online addition)."""
    idx = int(np.asarray(jnp.sum(state.active)))
    if idx >= config.max_arms:
        raise ValueError("bandit at capacity; raise RouterConfig.max_arms")
    d = config.context_dim
    eye = jnp.eye(d, dtype=jnp.float32)
    state = state._replace(
        A=state.A.at[idx].set(eye * config.lambda_reg),
        A_inv=state.A_inv.at[idx].set(eye / config.lambda_reg),
        b=state.b.at[idx].set(0.0),
        theta=state.theta.at[idx].set(0.0),
        reward_sum=state.reward_sum.at[idx].set(0.0),
        counts=state.counts.at[idx].set(0.0),
        active=state.active.at[idx].set(True),
    )
    return state, idx


# ---------------------------------------------------------------------------
# Scoring
# ---------------------------------------------------------------------------


def _masked(scores: jax.Array, mask: jax.Array) -> jax.Array:
    return jnp.where(mask, scores, NEG_INF)


def linucb_scores(state: BanditState, x: jax.Array, alpha: float,
                  solve_mode: str = "sherman_morrison") -> jax.Array:
    """Eq. 13: θ̂ᵀx + α·sqrt(xᵀ A⁻¹ x), batched over arms."""
    if solve_mode == "cholesky":
        # Paper-faithful path: factor A per decision (O(M d³)).
        chol = jax.vmap(jnp.linalg.cholesky)(state.A)           # (M, d, d)
        theta = jax.vmap(lambda c, b: jax.scipy.linalg.cho_solve((c, True), b))(
            chol, state.b)                                       # (M, d)
        ainv_x = jax.vmap(lambda c: jax.scipy.linalg.cho_solve((c, True), x))(chol)
    else:
        theta = state.theta
        ainv_x = jnp.einsum("mij,j->mi", state.A_inv, x)         # (M, d)
    mean = theta @ x                                             # (M,)
    var = jnp.maximum(ainv_x @ x, 0.0)
    return mean + alpha * jnp.sqrt(var)


def linucb_scores_batch(state: BanditState, X: jax.Array,
                        alpha: float) -> jax.Array:
    """Eq. 13 over a query batch: (Q, d) contexts → (Q, M) UCB scores.

    Runs the fused Pallas kernel (kernels/linucb) over the maintained
    Sherman–Morrison inverses — one VMEM pass per (Q-block, M-block) tile
    instead of Q separate decision solves.
    """
    return linucb_scores_kernel(state.A_inv, state.theta, X, alpha)


def thompson_scores(state: BanditState, x: jax.Array, sigma: float,
                    key: jax.Array) -> jax.Array:
    """Sample θ~N(θ̂, σ²A⁻¹) per arm and score θᵀx (Agrawal & Goyal 2013)."""
    m, d = state.theta.shape
    # A_inv is SPD; its Cholesky factor maps N(0,I) -> N(0, A_inv).
    chol = jax.vmap(jnp.linalg.cholesky)(
        state.A_inv + 1e-8 * jnp.eye(d, dtype=state.A_inv.dtype)[None])
    z = jax.random.normal(key, (m, d), dtype=state.theta.dtype)
    theta_s = state.theta + sigma * jnp.einsum("mij,mj->mi", chol, z)
    return theta_s @ x


def greedy_ctx_scores(state: BanditState, x: jax.Array) -> jax.Array:
    return state.theta @ x


def greedy_plain_scores(state: BanditState) -> jax.Array:
    return state.reward_sum / jnp.maximum(state.counts, 1.0)


# ---------------------------------------------------------------------------
# Select / update (jitted factories)
# ---------------------------------------------------------------------------


def make_select_fn(config: RouterConfig):
    """Returns jitted select(state, x, feasible) -> (arm, scores, state)."""
    algo = config.algorithm
    alpha = config.alpha_ucb
    sigma = config.cts_sigma
    solve_mode = config.solve_mode

    @jax.jit
    def select(state: BanditState, x: jax.Array, feasible: jax.Array):
        mask = state.active & feasible
        key, k_sel, k_eps = jax.random.split(state.key, 3)
        if algo == "linucb":
            scores = linucb_scores(state, x, alpha, solve_mode)
            arm = jnp.argmax(_masked(scores, mask))
        elif algo == "cts":
            scores = thompson_scores(state, x, sigma, k_sel)
            arm = jnp.argmax(_masked(scores, mask))
        elif algo in ("eps_greedy", "eps_greedy_ctx"):
            scores = (greedy_ctx_scores(state, x) if algo == "eps_greedy_ctx"
                      else greedy_plain_scores(state))
            greedy_arm = jnp.argmax(_masked(scores, mask))
            # uniform over feasible arms for the exploration branch
            probs = mask / jnp.maximum(jnp.sum(mask), 1)
            rand_arm = jax.random.choice(k_sel, mask.shape[0], p=probs)
            explore = jax.random.uniform(k_eps) < state.eps
            arm = jnp.where(explore, rand_arm, greedy_arm)
        else:
            raise ValueError(f"unknown algorithm {algo!r}")
        return arm, _masked(scores, mask), state._replace(key=key)

    return select


def make_select_batch_scan_fn(config: RouterConfig):
    """Returns jitted select_batch(state, X, feasible, valid) →
    (arms, masked scores, state) for the policies the fused LinUCB kernel
    cannot serve (CTS posterior draws, ε-greedy exploration, the
    per-decision Cholesky mode).

    One ``lax.scan`` over the batch with *exact* sequential semantics:
    each row repeats ``make_select_fn``'s body — the same
    ``jax.random.split(key, 3)``, the same score arithmetic, the same
    masked argmax — so Q rows leave the state (PRNG key included)
    bit-identical to Q successive ``select`` calls.  ``valid`` marks real
    rows: callers pad Q to a power of two to bound the compiled variants,
    and a padding row must not consume a draw (the key only advances on
    valid rows), or batched and sequential selection would diverge.

    The state threads through and is replaced by the output, so its
    buffers are donated where the backend supports it — batched CTS
    selection allocates no second copy of A/A⁻¹/θ on device.
    """
    algo = config.algorithm
    alpha = config.alpha_ucb
    sigma = config.cts_sigma
    solve_mode = config.solve_mode

    def step(state: BanditState, xs):
        x, feasible, v = xs
        mask = state.active & feasible
        key, k_sel, k_eps = jax.random.split(state.key, 3)
        if algo == "linucb":
            scores = linucb_scores(state, x, alpha, solve_mode)
            arm = jnp.argmax(_masked(scores, mask))
        elif algo == "cts":
            scores = thompson_scores(state, x, sigma, k_sel)
            arm = jnp.argmax(_masked(scores, mask))
        elif algo in ("eps_greedy", "eps_greedy_ctx"):
            scores = (greedy_ctx_scores(state, x) if algo == "eps_greedy_ctx"
                      else greedy_plain_scores(state))
            greedy_arm = jnp.argmax(_masked(scores, mask))
            probs = mask / jnp.maximum(jnp.sum(mask), 1)
            rand_arm = jax.random.choice(k_sel, mask.shape[0], p=probs)
            explore = jax.random.uniform(k_eps) < state.eps
            arm = jnp.where(explore, rand_arm, greedy_arm)
        else:
            raise ValueError(f"unknown algorithm {algo!r}")
        return (state._replace(key=jnp.where(v, key, state.key)),
                (arm, _masked(scores, mask)))

    @functools.partial(jax.jit, **compat.donation_kwargs(0))
    def select_batch(state: BanditState, X: jax.Array, feasible: jax.Array,
                     valid: jax.Array):
        state, (arms, masked) = jax.lax.scan(step, state,
                                             (X, feasible, valid))
        return arms, masked, state

    return select_batch


def make_update_fn(config: RouterConfig):
    """Returns jitted update(state, arm, x, r) -> state.

    LinUCB/CTS posterior update (paper §4.3):
        A_m ← A_m + x xᵀ ;  b_m ← b_m + r x ;  θ̂_m = A_m⁻¹ b_m
    with A⁻¹ maintained by Sherman–Morrison:
        A⁻¹ ← A⁻¹ − (A⁻¹ x)(A⁻¹ x)ᵀ / (1 + xᵀ A⁻¹ x)
    """
    decay = config.epsilon_decay
    eps_min = config.epsilon_min

    # the state is threaded through and replaced by the caller, so its
    # buffers are donated where the backend supports it (compat helper)
    @functools.partial(jax.jit, **compat.donation_kwargs(0))
    def update(state: BanditState, arm: jax.Array, x: jax.Array,
               r: jax.Array) -> BanditState:
        A_m = state.A[arm] + jnp.outer(x, x)
        ainv = state.A_inv[arm]
        ainv_x = ainv @ x
        denom = 1.0 + x @ ainv_x
        ainv_new = ainv - jnp.outer(ainv_x, ainv_x) / denom
        b_m = state.b[arm] + r * x
        theta_m = ainv_new @ b_m
        return state._replace(
            A=state.A.at[arm].set(A_m),
            A_inv=state.A_inv.at[arm].set(ainv_new),
            b=state.b.at[arm].set(b_m),
            theta=state.theta.at[arm].set(theta_m),
            reward_sum=state.reward_sum.at[arm].add(r),
            counts=state.counts.at[arm].add(1.0),
            eps=jnp.maximum(state.eps * decay, eps_min),
            t=state.t + 1,
        )

    return update


# ---------------------------------------------------------------------------
# Convenience OO wrapper used by the router
# ---------------------------------------------------------------------------


class BanditPolicy:
    """Thin stateful wrapper holding a BanditState + jitted fns."""

    def __init__(self, config: RouterConfig, n_arms: int):
        self.config = config
        self.state = init_state(config, n_arms)
        self._select = make_select_fn(config)
        self._update = make_update_fn(config)
        self._select_batch_scan = None   # built on first stochastic batch
        # residency audit: BanditState lives on device; the ledger counts
        # the deliberate host syncs (state_dict / load / rescalarize) so
        # tests can assert routing itself moves no bandit state
        self.transfers = TransferLedger()

    @property
    def n_arms(self) -> int:
        return int(np.asarray(jnp.sum(self.state.active)))

    def select(self, x: np.ndarray, feasible: np.ndarray) -> Tuple[int, np.ndarray]:
        feas = jnp.asarray(feasible, dtype=bool)
        # pad feasibility to capacity
        if feas.shape[0] < self.config.max_arms:
            feas = jnp.pad(feas, (0, self.config.max_arms - feas.shape[0]))
        arm, scores, self.state = self._select(self.state, jnp.asarray(x), feas)
        return int(arm), np.asarray(scores)

    def select_batch(self, X: np.ndarray,
                     feasible: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized arm selection: X (Q, d), feasible (Q, n) bool →
        (arms (Q,), masked scores (Q, max_arms)).

        LinUCB with maintained inverses is deterministic, so the whole
        batch is scored by one fused kernel call and an argmax per row —
        arm choices are identical to Q sequential ``select`` calls on the
        same state.  Stochastic policies (CTS, ε-greedy) and the
        per-decision Cholesky mode keep sequential per-query *semantics*
        (each query consumes its own PRNG draw / solve) but run as one
        jitted ``lax.scan`` (``make_select_batch_scan_fn``) — decisions
        and the final PRNG key are identical to the sequential loop, and
        the bandit state never leaves the device.
        """
        X = np.asarray(X, dtype=np.float32)
        feas = np.asarray(feasible, dtype=bool)
        q, m = X.shape[0], self.config.max_arms
        if q == 0:
            return (np.zeros(0, dtype=np.int64),
                    np.zeros((0, m), dtype=np.float32))
        if feas.shape[1] < m:
            feas = np.pad(feas, ((0, 0), (0, m - feas.shape[1])))
        if (self.config.algorithm == "linucb"
                and self.config.solve_mode == "sherman_morrison"):
            scores = linucb_scores_batch(self.state, jnp.asarray(X),
                                         self.config.alpha_ucb)
            mask = np.asarray(self.state.active)[None, :] & feas
            masked = np.where(mask, np.asarray(scores), NEG_INF)
            arms = np.argmax(masked, axis=1)
            self.advance_key()
            return arms.astype(np.int64), masked.astype(np.float32)
        if self._select_batch_scan is None:
            self._select_batch_scan = make_select_batch_scan_fn(self.config)
        # Q padded to a power of two (bounded jit variants); padding rows
        # are marked invalid so they never advance the PRNG key
        q_pad = pad_pow2(q)
        x_pad = np.zeros((q_pad, X.shape[1]), np.float32)
        x_pad[:q] = X
        feas_pad = np.zeros((q_pad, m), bool)
        feas_pad[:q] = feas
        valid = np.arange(q_pad) < q
        arms, masked, self.state = self._select_batch_scan(
            self.state, jnp.asarray(x_pad), jnp.asarray(feas_pad),
            jnp.asarray(valid))
        return (np.asarray(arms, dtype=np.int64)[:q],
                np.asarray(masked, dtype=np.float32)[:q])

    def advance_key(self) -> None:
        """Advance the PRNG key so a batched selection is not a state no-op
        (LinUCB never consumes it for scoring; the stream does NOT match
        what Q sequential select() calls would produce).  Shared by the
        host ``select_batch`` fast path and the router's fused device
        pipeline so both leave the bandit state identically."""
        key, _ = jax.random.split(self.state.key)
        self.state = self.state._replace(key=key)

    def update(self, arm: int, x: np.ndarray, reward: float) -> None:
        self.state = self._update(self.state, jnp.int32(arm), jnp.asarray(x),
                                  jnp.float32(reward))

    def add_arm(self) -> int:
        self.state, idx = add_arm(self.state, self.config)
        return idx

    def rescalarize(self, b: np.ndarray, reward_sum: np.ndarray) -> None:
        """Swap in reward statistics recomputed under a new scalarization.

        A_m and A_m⁻¹ depend only on the observed contexts, never on the
        rewards, so a λ change (``GreenServRouter.set_lambda``) can rebuild
        b_m = Σ r(λ')·x exactly from decomposed accuracy/energy sums and
        refresh θ̂ = A⁻¹ b in one shot — the posterior mean reacts to the
        new trade-off immediately instead of averaging it in over
        thousands of fresh pulls.
        """
        b = np.asarray(b, dtype=np.float32)
        a_inv = np.asarray(self.state.A_inv)
        self.transfers.count_d2h()
        theta = np.einsum("mij,mj->mi", a_inv, b)
        self.state = self.state._replace(
            b=jnp.asarray(b),
            theta=jnp.asarray(theta.astype(np.float32)),
            reward_sum=jnp.asarray(np.asarray(reward_sum, np.float32)))
        self.transfers.count_h2d()

    def state_dict(self) -> dict:
        self.transfers.count_d2h()
        return {k: np.asarray(v) for k, v in self.state._asdict().items()}

    def load_state_dict(self, d: dict) -> None:
        self.transfers.count_h2d()
        self.state = BanditState(**{k: jnp.asarray(v) for k, v in d.items()})
