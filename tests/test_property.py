"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="dev-only dependency (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.bandits import BanditPolicy
from repro.core.context import FleschComplexity, OnlineKMeans
from repro.core.rewards import RegretTracker, scalarize
from repro.core.types import RouterConfig
from repro.train.compress import dequantize_leaf, quantize_leaf

import jax.numpy as jnp

_SETTINGS = dict(max_examples=25, deadline=None)


@given(acc=st.floats(0, 1), energy=st.floats(0, 10),
       lam=st.floats(0, 1))
@settings(**_SETTINGS)
def test_scalarized_reward_bounds(acc, energy, lam):
    """Eq. 5: r ∈ [−λ·E/scale, 1−λ] and monotone in both objectives."""
    r = scalarize(acc, energy, lam, energy_scale_wh=1.0)
    assert -lam * energy - 1e-9 <= r <= (1 - lam) + 1e-9
    assert scalarize(min(acc + 0.1, 1.0), energy, lam) >= r - 1e-9
    assert scalarize(acc, energy + 0.1, lam) <= r + 1e-9


@given(st.lists(st.tuples(st.floats(-1, 1), st.floats(-1, 1)),
                min_size=1, max_size=60))
@settings(**_SETTINGS)
def test_regret_nonneg_and_monotone(pairs):
    t = RegretTracker()
    prev = 0.0
    for chosen, oracle in pairs:
        t.step(chosen, oracle)
        assert t.cumulative >= prev - 1e-12
        prev = t.cumulative
    assert len(t.history) == len(pairs)


@given(data=st.data())
@settings(**_SETTINGS)
def test_sherman_morrison_inverse_consistency(data):
    """A_inv stays A⁻¹ under arbitrary (x, r) update streams."""
    cfg = RouterConfig(max_arms=4, n_clusters=2, n_complexity_bins=2)
    pol = BanditPolicy(cfg, n_arms=2)
    d = cfg.context_dim
    n = data.draw(st.integers(1, 25))
    for i in range(n):
        vals = data.draw(st.lists(
            st.floats(-2, 2, allow_nan=False), min_size=d, max_size=d))
        x = np.array(vals, np.float32)
        r = data.draw(st.floats(-1, 1, allow_nan=False))
        pol.update(i % 2, x, r)
    st_ = pol.state_dict()
    for m in range(2):
        np.testing.assert_allclose(
            st_["A_inv"][m] @ st_["A"][m], np.eye(d), atol=5e-2)


@given(data=st.data())
@settings(**_SETTINGS)
def test_router_never_selects_infeasible(data):
    cfg = RouterConfig(max_arms=8)
    pol = BanditPolicy(cfg, n_arms=6)
    for i in range(data.draw(st.integers(1, 20))):
        feas = np.array(data.draw(st.lists(st.booleans(), min_size=6,
                                           max_size=6)))
        if not feas.any():
            feas[0] = True
        x = np.array(data.draw(st.lists(st.floats(-1, 1, allow_nan=False),
                                        min_size=cfg.context_dim,
                                        max_size=cfg.context_dim)),
                     np.float32)
        arm, _ = pol.select(x, feas)
        assert feas[arm]


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=64))
@settings(**_SETTINGS)
def test_int8_quantization_error_bound(vals):
    """|x − deq(q(x))| ≤ scale/2 = max|x|/254 per element."""
    x = jnp.asarray(np.array(vals, np.float32))
    q, scale = quantize_leaf(x)
    err = np.abs(np.asarray(dequantize_leaf(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) / 2 + 1e-7


@given(st.integers(1, 5), st.lists(
    st.lists(st.floats(-1, 1, allow_nan=False), min_size=4, max_size=4),
    min_size=1, max_size=30))
@settings(**_SETTINGS)
def test_kmeans_centroids_in_convex_hull_bounds(k, points):
    km = OnlineKMeans(k=k, dim=4)
    arr = np.array(points, np.float32)
    for p in arr:
        c = km.update(p)
        assert 0 <= c < k
    live = km.centroids[: km._initialized]
    assert np.all(live >= arr.min() - 1e-6)
    assert np.all(live <= arr.max() + 1e-6)


@given(st.floats(-50, 150), st.integers(1, 10))
@settings(**_SETTINGS)
def test_flesch_bin_in_range(score, n_bins):
    fc = FleschComplexity(n_bins=n_bins)
    assert 0 <= fc.bin(score) < n_bins


@given(st.floats(0, 1), st.floats(0, 1))
@settings(**_SETTINGS)
def test_energy_model_monotonicity(f_scale, b_scale):
    from repro.core.energy import energy_joules, roofline
    base = roofline(1e12, 1e9, 1e6, chips=4)
    more = roofline(1e12 * (1 + f_scale), 1e9 * (1 + b_scale), 1e6, chips=4)
    assert energy_joules(more) >= energy_joules(base) - 1e-9
