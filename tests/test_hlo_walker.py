"""HLO cost walker: trip-count awareness verified against hand-computed
flops and wire bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.launch.hlo_walker import module_cost, _shape_bytes


def test_shape_bytes():
    assert _shape_bytes("bf16[4,8]") == 64
    assert _shape_bytes("f32[10]{0}") == 40
    assert _shape_bytes("(f32[2,2], s32[3])") == 28
    assert _shape_bytes("pred[]") == 1


def _scanned(w, x, n):
    def body(c, wi):
        return jnp.tanh(c @ wi), None
    out, _ = jax.lax.scan(body, x, w)
    return out


def test_walker_multiplies_scan_flops():
    n, b, d = 8, 4, 64
    w = jax.ShapeDtypeStruct((n, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((b, d), jnp.float32)
    comp = jax.jit(lambda w, x: _scanned(w, x, n)).lower(w, x).compile()
    cost = module_cost(comp.as_text(), 1)
    true_flops = n * 2 * b * d * d
    assert cost.flops == pytest.approx(true_flops, rel=1e-6)
    # XLA's own analysis undercounts by the trip count
    assert compat.cost_analysis(comp)["flops"] < true_flops / 2


def test_walker_matches_unrolled():
    b, d, n = 4, 32, 6
    w = jax.ShapeDtypeStruct((n, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((b, d), jnp.float32)

    def unrolled(w, x):
        for i in range(n):
            x = jnp.tanh(x @ w[i])
        return x

    c_scan = module_cost(jax.jit(lambda w, x: _scanned(w, x, n))
                         .lower(w, x).compile().as_text(), 1)
    c_unrl = module_cost(jax.jit(unrolled).lower(w, x).compile().as_text(), 1)
    assert c_scan.flops == pytest.approx(c_unrl.flops, rel=1e-6)


def test_walker_nested_scans_multiply():
    d = 32
    w = jax.ShapeDtypeStruct((3, 4, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((2, d), jnp.float32)

    def nested(w, x):
        def outer(c, wo):
            def inner(ci, wi):
                return jnp.tanh(ci @ wi), None
            c, _ = jax.lax.scan(inner, c, wo)
            return c, None
        out, _ = jax.lax.scan(outer, x, w)
        return out

    cost = module_cost(jax.jit(nested).lower(w, x).compile().as_text(), 1)
    assert cost.flops == pytest.approx(12 * 2 * 2 * d * d, rel=1e-6)


def test_walker_collective_wire_bytes_subprocess():
    import subprocess, sys, os, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.launch.hlo_walker import module_cost
        mesh = make_mesh((2, 4), ("data", "model"))
        w = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
        x = jax.ShapeDtypeStruct((4, 256), jnp.float32)
        def f(w, x):
            def body(c, wi): return jnp.tanh(c @ wi), None
            return jax.lax.scan(body, x, w)[0]
        comp = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P(None, "model", None)),
            NamedSharding(mesh, P(None, "model"))),
            out_shardings=NamedSharding(mesh, P(None, "model"))
        ).lower(w, x).compile()
        c = module_cost(comp.as_text(), 8)
        # 8 iterations x ring all-reduce of (4,256) f32 over k=4:
        #   2*(k-1)/k*4096 = 6144 B/iter -> 49152 B
        assert abs(c.coll_bytes - 49152) < 1, c.coll_bytes
        assert c.coll_by_kind.get("all-reduce", 0) == c.coll_bytes
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       capture_output=True, text=True, timeout=300)
    assert "OK" in r.stdout, r.stderr[-2000:]
