"""Reliability layer (docs/RELIABILITY.md): deadlines + retries, per-arm
circuit breakers, chaos fault injection, and governor charge hygiene
under failure.

The invariants under test:

  * breaker state machine — CLOSED opens at the rolling failure-rate
    threshold, cools down to HALF_OPEN in scheduler steps, admits a
    bounded probe trickle, and closes (or re-opens) on probe outcome;
  * fault injection — schedules are validated, deterministic in their
    seed, and each fault kind does what the taxonomy says (stalls freeze
    output but keep modeled time moving, garbage corrupts completions
    whose energy was really burned);
  * deadlines / retries — an expired request terminalizes as TIMED_OUT
    and its engine drops it on sight; a failed attempt backs off and
    re-routes *away* from the failed arm; an exhausted retry budget
    terminalizes as FAILED.  ``responses`` ∪ ``failed`` always covers
    every admitted uid — nothing is ever lost;
  * hedging — a hedge never targets a breaker-held or stale-heartbeat
    engine (duplicating onto a sick engine doubles work, saves nothing);
  * governor — a terminal failure releases the in-flight predicted
    charge exactly once, even after a retry re-admission replaced it;
  * telemetry — the reliability counters pre-bind (export at zero on
    healthy runs) and count under chaos;
  * disaggregated pools survive repeated prefill+twin kill cycles with
    nothing lost and hedge bookkeeping cleared.

Run the subset with ``-m chaos``.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import ModelProfile, Query, RouterConfig
from repro.data import tokenizer as tok
from repro.data.scenarios import chaos
from repro.serving import (BreakerConfig, CircuitBreaker, FaultInjector,
                           FaultSpec, LivelockError, ModelEngine, PoolServer,
                           RequestState, SimEngine, fault_storm)
from repro.serving.reliability import CLOSED, HALF_OPEN, OPEN
from repro.telemetry import EnergyBudgetGovernor, Telemetry, to_prometheus

pytestmark = pytest.mark.chaos


# ---------------------------------------------------------------------------
# circuit breaker state machine
# ---------------------------------------------------------------------------


class TestCircuitBreaker:
    def _cfg(self, **kw):
        base = dict(window=4, failure_threshold=0.5, min_samples=2,
                    open_steps=10, probe_quota=1, probe_successes=2)
        base.update(kw)
        return BreakerConfig(**base)

    def test_opens_at_failure_threshold(self):
        br = CircuitBreaker(self._cfg())
        br.record_failure(1)
        assert br.state == CLOSED          # min_samples not met yet
        br.record_failure(2)
        assert br.state == OPEN
        assert br.n_opens == 1
        assert not br.routable(3)

    def test_successes_keep_it_closed(self):
        br = CircuitBreaker(self._cfg())
        for s in range(8):
            br.record_success(s)
        br.record_failure(9)               # 1 failure in a window of 4
        assert br.state == CLOSED
        assert br.routable(10)

    def test_cooldown_probe_and_reclose(self):
        br = CircuitBreaker(self._cfg())
        br.record_failure(1), br.record_failure(2)
        assert br.state == OPEN
        assert not br.routable(5)          # cooling down
        assert br.routable(12, pending=0)  # open_steps elapsed -> HALF_OPEN
        assert br.state == HALF_OPEN
        # probe trickle: quota=1 means a busy arm admits nothing more
        assert not br.routable(12, pending=1)
        br.record_success(13)
        assert br.state == HALF_OPEN       # needs probe_successes=2
        br.record_success(14)
        assert br.state == CLOSED
        assert br.failure_rate() == 0.0    # window cleared on close

    def test_probe_failure_reopens_and_restarts_cooldown(self):
        br = CircuitBreaker(self._cfg())
        br.record_failure(1), br.record_failure(2)
        assert br.routable(12)             # HALF_OPEN
        br.record_failure(13)              # the probe died
        assert br.state == OPEN
        assert br.n_opens == 2
        assert not br.routable(14)
        assert br.routable(23)             # cooldown restarted from step 13

    def test_transition_hook_fires_once_per_change(self):
        seen = []
        br = CircuitBreaker(self._cfg(),
                            on_transition=lambda o, n, s: seen.append((o, n)))
        br.record_failure(1), br.record_failure(2)
        br.routable(12)
        br.record_success(13), br.record_success(14)
        assert seen == [(CLOSED, OPEN), (OPEN, HALF_OPEN),
                        (HALF_OPEN, CLOSED)]

    def test_config_validation(self):
        with pytest.raises(ValueError):
            BreakerConfig(failure_threshold=0.0)
        with pytest.raises(ValueError):
            BreakerConfig(failure_threshold=1.5)
        with pytest.raises(ValueError):
            BreakerConfig(window=0)


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------


def _profile(name="sim0", params_b=1.0):
    return ModelProfile(name=name, family="s", params_b=params_b,
                        ms_per_token=1.0, prefill_ms=10.0)


def _outcome(query, model):
    return 0.8, 0.01, 10.0, 4


class TestFaultInjection:
    def test_fault_spec_validation(self):
        with pytest.raises(ValueError):
            FaultSpec(t_s=0.0, kind="meteor")
        spec = FaultSpec(t_s=1.0, kind="stall", duration_s=2.0)
        assert spec.active(1.5) and not spec.active(3.5)

    def test_fault_storm_deterministic_in_seed(self):
        a = fault_storm(100.0, "m0", ["m1", "m2"], seed=3, n_crashes=4)
        b = fault_storm(100.0, "m0", ["m1", "m2"], seed=3, n_crashes=4)
        assert a == b
        c = fault_storm(100.0, "m0", ["m1", "m2"], seed=4, n_crashes=4)
        assert a != c
        crashes = [f for f in a["m0"] if f.kind == "crash"]
        assert len(crashes) == 4
        assert all(35.0 <= f.t_s <= 65.0 for f in crashes)

    def test_stall_freezes_output_but_not_modeled_time(self):
        clk = {"t": 0.0}
        eng = SimEngine(_profile(), _outcome, clock=lambda: clk["t"])
        inj = FaultInjector(eng, [FaultSpec(t_s=0.0, kind="stall",
                                            duration_s=5.0)],
                            clock=lambda: clk["t"])
        t0 = inj.modeled_time_s()
        assert inj.step() == []
        assert inj.stats["stall_steps"] == 1
        assert inj.modeled_time_s() > t0   # the stalled tick still costs

    def test_garbage_corrupts_completions_energy_still_burned(self):
        from repro.serving import Request
        clk = {"t": 0.0}
        eng = SimEngine(_profile(), _outcome, clock=lambda: clk["t"])
        inj = FaultInjector(eng, [FaultSpec(t_s=0.0, kind="garbage",
                                            duration_s=1e9)],
                            clock=lambda: clk["t"])
        inj.submit(Request(query=Query(uid=0, text="q"),
                           prompt_tokens=[1, 2], max_new_tokens=2))
        out = []
        for _ in range(10):
            out += inj.step()
            if out:
                break
        assert out and out[0].corrupt
        assert out[0].tokens == [] and out[0].accuracy == 0.0
        assert out[0].energy_wh > 0.0      # the joules really happened
        assert inj.stats["garbage"] == 1
        assert inj.cumulative_joules() > 0.0   # delegation to the inner

    def test_chaos_scenario_fingerprint_covers_faults(self):
        a = chaos(per_task=2, seed=0, targets=("qwen2.5-7b",))
        b = chaos(per_task=2, seed=0, targets=("qwen2.5-7b",))
        assert a.fingerprint() == b.fingerprint()
        assert a.faults and "qwen2.5-7b" in a.faults
        c = chaos(per_task=2, seed=0, targets=("qwen2.5-7b",), n_crashes=2)
        assert a.fingerprint() != c.fingerprint()   # schedule is hashed


# ---------------------------------------------------------------------------
# scheduler integration: deadlines, retries, terminal failure
# ---------------------------------------------------------------------------


def _server(n=2, clk=None, telemetry=None, steps_per_query=1, **kw):
    """A small sim pool on a shared virtual clock the test advances."""
    clk = clk if clk is not None else {"t": 0.0}
    clock = lambda: clk["t"]  # noqa: E731
    profiles = [_profile(f"sim{i}", params_b=i + 1.0) for i in range(n)]
    engines = {p.name: SimEngine(p, _outcome, clock=clock,
                                 steps_per_query=steps_per_query)
               for p in profiles}
    router = GreenServRouter(RouterConfig(lam=0.4, max_arms=8),
                             ModelPool(profiles))
    server = PoolServer(router, engines, clock=clock, telemetry=telemetry,
                        **kw)
    return server, engines, clk


def test_deadline_expires_to_timed_out_and_engine_drops_defunct():
    clk = {"t": 0.0}
    server, engines, _ = _server(
        n=1, clk=clk, steps_per_query=10_000,   # will never finish in time
        deadline_s=1.0, max_retries=0, telemetry=Telemetry(
            clock=lambda: clk["t"]))
    req = server.submit(Query(uid=0, text="slow one"))
    assert req.deadline_s == 1.0
    server.step()
    assert not server.failed               # deadline not passed yet
    clk["t"] = 2.0
    server.step()
    assert server.failed[0].state is RequestState.TIMED_OUT
    assert 0 not in server.inflight
    assert server.stats["timeouts"] == 1
    assert server.stats["slo_violations"] == 1
    # the engine still held the request; defunct work is dropped on sight
    server.step()
    assert engines["sim0"].pending == 0
    # responses ∪ failed covers every admitted uid
    assert set(server.responses) | set(server.failed) == {0}
    text = to_prometheus(server.telemetry.registry)
    assert "greenserv_timeouts_total 1" in text
    assert "greenserv_slo_violations_total 1" in text


def test_retry_reroutes_away_from_failed_arm():
    server, engines, clk = _server(
        n=2, steps_per_query=3, max_retries=2, retry_backoff_steps=1,
        breaker_config=BreakerConfig(window=4, min_samples=1,
                                     failure_threshold=0.5, open_steps=50))
    tel = Telemetry(clock=lambda: clk["t"])
    server.telemetry = tel
    req = server.submit(Query(uid=0, text="route me"))
    first_arm = req.model_name
    engines[first_arm].inject_failure()
    # crash -> restart -> failure recorded -> parked for backoff
    server.step()
    assert server.stats["restarts"] == 1
    assert req.attempts == 1
    assert 0 in server.inflight            # parked retries stay visible
    for _ in range(30):
        server.step()
        clk["t"] += 0.1
        if server.responses:
            break
    assert 0 in server.responses
    assert req.model_name != first_arm     # blocked veto re-routed it
    assert server.stats["retries"] == 1
    assert server.breakers[first_arm].failure_rate() > 0.0
    text = to_prometheus(tel.registry)
    assert "greenserv_retries_total 1" in text
    assert f'greenserv_attempt_failures_total{{engine="{first_arm}"}} 1' \
        in text


def test_corrupt_completion_retries_to_clean_answer():
    server, engines, clk = _server(
        n=2, steps_per_query=2, max_retries=2, retry_backoff_steps=1,
        breaker_config=BreakerConfig(window=4, min_samples=1,
                                     failure_threshold=0.5, open_steps=50))
    req = server.submit(Query(uid=0, text="poisoned arm"))
    bad = req.model_name
    server.engines[bad] = FaultInjector(
        engines[bad], [FaultSpec(t_s=0.0, kind="garbage", duration_s=1e9)],
        clock=server.clock)
    for _ in range(30):
        server.step()
        clk["t"] += 0.1
        if server.responses:
            break
    resp = server.responses[0]
    assert not getattr(resp, "corrupt", False)
    assert resp.model_name != bad
    assert server.stats["retries"] >= 1
    # the poisoned arm's breaker saw the garbage as a failure
    assert server.breakers[bad].failure_rate() > 0.0 \
        or server.breakers[bad].state != CLOSED


def test_reliability_off_completes_garbage_at_zero_accuracy():
    server, engines, clk = _server(n=1, steps_per_query=2)   # legacy config
    req = server.submit(Query(uid=0, text="no retries"))
    server.engines[req.model_name] = FaultInjector(
        engines[req.model_name],
        [FaultSpec(t_s=0.0, kind="garbage", duration_s=1e9)],
        clock=server.clock)
    for _ in range(10):
        server.step()
        clk["t"] += 0.1
        if server.responses:
            break
    assert server.responses[0].corrupt     # served, degraded — not lost
    assert server.responses[0].accuracy == 0.0
    assert not server.failed and server.stats["retries"] == 0


def test_attempts_exhausted_terminalizes_failed():
    clk = {"t": 0.0}
    tel = Telemetry(clock=lambda: clk["t"])
    server, engines, _ = _server(
        n=2, clk=clk, steps_per_query=2, max_retries=1,
        retry_backoff_steps=1, telemetry=tel,
        breaker_config=BreakerConfig(window=8, min_samples=2,
                                     failure_threshold=0.5, open_steps=4))
    for name, eng in list(engines.items()):   # every arm serves garbage
        server.engines[name] = FaultInjector(
            eng, [FaultSpec(t_s=0.0, kind="garbage", duration_s=1e9)],
            clock=server.clock)
    server.submit(Query(uid=0, text="doomed"))
    for _ in range(40):
        server.step()
        clk["t"] += 0.1
        if server.failed:
            break
    assert server.failed[0].state is RequestState.FAILED
    assert server.failed[0].attempts == 2     # initial + 1 retry
    assert server.stats["failed"] == 1
    assert set(server.responses) | set(server.failed) == {0}
    text = to_prometheus(tel.registry)
    assert "greenserv_failed_total 1" in text
    assert "greenserv_breaker_transitions_total" in text


def test_reliability_counters_prebound_export_at_zero():
    text = to_prometheus(Telemetry().registry)
    for name in ("greenserv_retries_total", "greenserv_timeouts_total",
                 "greenserv_failed_total", "greenserv_slo_violations_total",
                 "greenserv_breaker_transitions_total"):
        assert f"{name} 0" in text


# ---------------------------------------------------------------------------
# hedge health guard
# ---------------------------------------------------------------------------


def test_hedge_skips_breaker_open_and_stale_heartbeat_targets():
    server, engines, clk = _server(
        n=3, steps_per_query=1_000, hedge_after_steps=1,
        breaker_config=BreakerConfig(window=4, min_samples=1,
                                     failure_threshold=0.5, open_steps=999))
    # stale heartbeat: an engine that hasn't stepped for > timeout
    clk["t"] = 100.0
    for eng in engines.values():
        eng._last_step_s = 100.0           # all fresh at t=100
    req1 = server.submit(Query(uid=0, text="first, occupies the slot"))
    req2 = server.submit(Query(uid=1, text="second, stuck QUEUED"))
    # force both onto one arm so uid 1 genuinely queues behind uid 0
    arm = req1.model_name
    if req2.model_name != arm:
        for eng in engines.values():
            eng.queue = [r for r in eng.queue if r.uid != 1]
        engines[arm].submit(req2)
    others = [n for n in engines if n != arm]
    server.breakers[others[0]].record_failure(0)           # OPEN
    engines[others[1]]._last_step_s = 0.0                  # stale
    assert server.breakers[others[0]].state == OPEN
    assert not server._engine_healthy(others[1], engines[others[1]])
    server.wait_steps[1] = 5               # straggling well past the bar
    server.stats["restarts"] = 0
    server._maybe_hedge()
    assert server.stats["hedges"] == 0     # nowhere healthy to hedge to
    # heal the stale engine: it becomes the only eligible target
    engines[others[1]]._last_step_s = clk["t"]
    server._maybe_hedge()
    assert server.stats["hedges"] == 1
    assert server.hedges[1].model_name == others[1]


# ---------------------------------------------------------------------------
# governor charge hygiene under failure
# ---------------------------------------------------------------------------


def test_governor_cancel_is_idempotent_and_readmission_replaces():
    gov = EnergyBudgetGovernor(10.0, horizon_queries=100)
    gov.on_admission(1, predicted=[(7, 0.5)])
    assert gov.inflight_predicted_wh == pytest.approx(0.5)
    # retry re-admission REPLACES the uid's charge, never stacks it
    gov.on_admission(0, predicted=[(7, 0.8)])
    assert gov.inflight_predicted_wh == pytest.approx(0.8)
    gov.on_cancel(7)
    assert gov.inflight_predicted_wh == 0.0
    assert gov.inflight_pred == {}
    gov.on_cancel(7)                       # second release: a no-op
    assert gov.inflight_predicted_wh == 0.0
    gov.on_cancel(12345)                   # never-predicted uid: a no-op
    assert gov.inflight_predicted_wh == 0.0


def test_timeout_after_retry_releases_predicted_charge_once():
    from repro.costmodel import EnergyCostModel
    clk = {"t": 0.0}
    gov = EnergyBudgetGovernor(10.0, horizon_queries=100)
    tel = Telemetry(governor=gov, clock=lambda: clk["t"])
    server, engines, _ = _server(
        n=2, clk=clk, steps_per_query=10_000, telemetry=tel,
        cost_model=EnergyCostModel(), deadline_s=2.0, max_retries=2,
        retry_backoff_steps=1)
    req = server.submit(Query(uid=0, text="will time out after a retry"))
    assert 0 in gov.inflight_pred          # admission predicted a charge
    engines[req.model_name].inject_failure()
    server.step()                          # crash -> parked retry
    clk["t"] += 0.1
    server.step()                          # backoff elapsed -> re-admitted
    assert server.stats["retries"] == 1
    assert 0 in gov.inflight_pred          # replaced, still exactly one
    charge = gov.inflight_predicted_wh
    assert charge >= 0.0
    clk["t"] = 5.0                         # blow the end-to-end deadline
    server.step()
    assert server.failed[0].state is RequestState.TIMED_OUT
    assert gov.inflight_pred == {}         # released exactly once
    assert gov.inflight_predicted_wh == 0.0
    tel.on_cancelled(0)                    # stray second release: no-op
    assert gov.inflight_predicted_wh == 0.0


# ---------------------------------------------------------------------------
# livelock diagnostics
# ---------------------------------------------------------------------------


def test_livelock_error_carries_drain_snapshot():
    server, _, _ = _server(n=2, steps_per_query=10**6,
                           deadline_s=0.0, max_retries=1,
                           breaker_config=BreakerConfig())
    server.submit(Query(uid=0, text="never finishes"))
    with pytest.raises(LivelockError) as err:
        server.run_until_drained(max_steps=3)
    msg = str(err.value)
    assert "still in flight" in msg
    assert "retry-parked" in msg           # the reliability ledger
    assert "sim0" in msg and "breaker" in msg


# ---------------------------------------------------------------------------
# disaggregated pool under repeated kill cycles
# ---------------------------------------------------------------------------


def test_disagg_survives_repeated_prefill_and_twin_kill_cycles():
    """Kill the prefill engine AND its decode twin in the same window,
    twice, mid-traffic: every request still completes, hedge bookkeeping
    is clear, and the governor's in-flight ledger balances to zero."""
    arch = "granite-3-8b"
    cfg = get_config(arch, smoke=True, vocab_size=tok.VOCAB_SIZE,
                     dtype="float32", kv_update="where")
    eng = ModelEngine(arch, cfg, jax.random.PRNGKey(0), max_batch=2,
                      max_len=48, prefill_chunk=4)
    twin = ModelEngine(arch, cfg, jax.random.PRNGKey(0), max_batch=2,
                      max_len=48, params=eng.params, prefill_chunk=4,
                      role="decode")
    gov = EnergyBudgetGovernor(50.0, horizon_queries=100)
    router = GreenServRouter(RouterConfig(lam=0.4, energy_scale_wh=0.05),
                             ModelPool([eng.profile]))
    server = PoolServer(router, {eng.name: eng}, tokenizer=tok.encode,
                        prefill_chunk=4, decode_engines={eng.name: twin},
                        telemetry=Telemetry(governor=gov),
                        hedge_after_steps=2)
    rng = np.random.default_rng(0)
    n = 6
    for i in range(n):
        server.submit(Query(uid=i, text=f"probe {i} " + "ctx " * int(
            rng.integers(1, 6)), max_new_tokens=int(rng.integers(2, 5))))
    kill_at = {4, 12}                      # two full kill cycles
    for step in range(600):
        if step in kill_at:
            eng.inject_failure()
            twin.inject_failure()
        server.step()
        if len(server.responses) == n:
            break
    assert set(server.responses) == set(range(n))   # nothing lost
    assert not server.failed
    assert server.stats["restarts"] >= 4   # both roles, both cycles
    assert not server.hedges               # hedge bookkeeping cleared
    assert gov.inflight_pred == {}         # governor ledger balanced
    assert gov.inflight_predicted_wh == 0.0
