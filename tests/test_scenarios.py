"""Scenario-lab tests: generator determinism, closed-loop economics,
flash-crowd liveness, pool-churn durability, and the hoisted
RouterBench classifier fit.

Mirrors ``test_paper_claims.py``'s GreenServ-vs-random comparison, but
through the *full* serving stack — ``PoolServer.enqueue`` → GreenCache →
``route_batch`` → feedback — on the virtual clock, so a regression
anywhere in the closed loop (admission, caching, cost model, governor
attachment) surfaces here even when the offline router loop stays green.
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import (make_closed_loop_router, run_record,
                               run_scenario)
from repro.configs.pool import PAPER_POOL, make_profile
from repro.core.pool import ModelPool
from repro.core.types import RouterConfig
from repro.data import ENERGY_SCALE_WH, OutcomeSimulator
from repro.data.scenarios import (duplicate_flood, flash_crowd,
                                  mmpp_arrivals, poisson_arrivals,
                                  pool_churn, steady)

pytestmark = pytest.mark.scenario


# -- generator determinism ----------------------------------------------------


@pytest.mark.parametrize("gen", [steady, flash_crowd, duplicate_flood,
                                 pool_churn])
def test_generators_deterministic_under_seed(gen):
    a = gen(per_task=10, seed=3)
    b = gen(per_task=10, seed=3)
    assert a.fingerprint() == b.fingerprint()
    assert a.n_queries == b.n_queries
    assert a.arrivals_s == b.arrivals_s
    c = gen(per_task=10, seed=4)
    assert a.fingerprint() != c.fingerprint()


def test_arrival_processes_are_monotone_and_seeded():
    for fn, kw in [(poisson_arrivals, dict(rate_qps=20.0)),
                   (mmpp_arrivals, dict())]:
        t = fn(200, seed=1, **kw)
        assert len(t) == 200
        assert all(b > a for a, b in zip(t, t[1:]))
        assert t == fn(200, seed=1, **kw)
        assert t != fn(200, seed=2, **kw)


def test_mmpp_bursts_are_faster_than_calm():
    t = np.asarray(mmpp_arrivals(2000, seed=0, calm_qps=5.0,
                                 burst_qps=500.0))
    gaps = np.diff(t)
    # a two-state process this asymmetric must show both regimes
    assert np.percentile(gaps, 10) < 1.0 / 100.0
    assert np.percentile(gaps, 90) > 1.0 / 50.0


def test_pool_churn_events_are_ordered_and_exclude_addition():
    sc = pool_churn(per_task=10, seed=0)
    kinds = [e.kind for e in sorted(sc.events, key=lambda e: e.t_s)]
    assert kinds == ["kill", "add"]
    add = next(e for e in sc.events if e.kind == "add")
    assert sc.exclude == [add.model]
    assert all(0.0 < e.t_s < sc.span_s for e in sc.events)


# -- closed-loop economics (3 models x 200 queries through PoolServer) --------


def _small_pool():
    names = ("yi-34b", "phi-4-mini-4b", "qwen2.5-14b")
    return ModelPool([make_profile(*r) for r in PAPER_POOL
                      if r[0] in names])


def test_closed_loop_greenserv_beats_random_on_acc_and_wh():
    """The paper's headline ordering, end to end through the serving
    stack: the pool mixes an expensive-and-weak arm (yi-34b) with cheap
    and mid arms, so learning to avoid it wins both axes at once."""
    scenario = steady(per_task=40, seed=0)
    results = {}
    for policy in ("greenserv", "random"):
        router = make_closed_loop_router(
            policy=policy, pool=_small_pool(),
            config=RouterConfig(lam=0.4, seed=0,
                                energy_scale_wh=ENERGY_SCALE_WH,
                                max_arms=8))
        results[policy] = run_scenario(
            scenario, router, outcome_fn=OutcomeSimulator(seed=7),
            seed=0, cache_mode="full", semantic_threshold=0.97)
    gs, rnd = results["greenserv"], results["random"]
    assert gs.completed == scenario.n_queries
    assert rnd.completed == scenario.n_queries
    assert gs.mean_accuracy >= rnd.mean_accuracy
    assert gs.total_energy_wh < rnd.total_energy_wh


def test_run_record_schema_is_uniform():
    scenario = steady(per_task=5, seed=0)
    router = make_closed_loop_router(pool=_small_pool(),
                                     config=RouterConfig(
                                         lam=0.4, seed=0,
                                         energy_scale_wh=ENERGY_SCALE_WH,
                                         max_arms=8),
                                     fit_classifier=False)
    res = run_scenario(scenario, router,
                       outcome_fn=OutcomeSimulator(seed=7), seed=0,
                       trace_every=5)
    rec = run_record(res)
    assert set(rec) == {"mean_accuracy", "total_energy_wh", "wh_per_query",
                        "completed", "failed", "n_queries", "span_s",
                        "avoided_wh", "stats", "trajectory"}
    assert rec["completed"] == scenario.n_queries
    assert rec["failed"] == 0
    traj = rec["trajectory"]
    assert traj, "trajectory must not be empty"
    keys = {"t_s", "completed", "failed", "joules", "inflight", "parked",
            "deferred", "cache_hits", "retries", "timeouts", "breaker_opens",
            "selections", "lam"}
    assert all(set(p) == keys for p in traj)
    ts = [p["t_s"] for p in traj]
    assert ts == sorted(ts)
    assert traj[-1]["completed"] == scenario.n_queries
    # per-arm selection counters are cumulative: every completion was
    # either routed to an engine or answered from cache
    last = traj[-1]
    assert sum(last["selections"].values()) + last["cache_hits"] \
        == scenario.n_queries


# -- scenario invariants ------------------------------------------------------


def test_flash_crowd_with_planner_never_livelocks():
    """MMPP bursts ~10x past service rate with the budget governor and
    the energy-aware admission planner on: admission pressure may slow
    the pool but must never stop it (LivelockError would propagate)."""
    scenario = flash_crowd(per_task=20, seed=0)
    router = make_closed_loop_router(lam=0.4, seed=0)
    res = run_scenario(scenario, router,
                       outcome_fn=OutcomeSimulator(seed=7), seed=0,
                       cache_mode="full", semantic_threshold=0.97,
                       budget_wh_per_query=0.05, admission_planner=True)
    assert res.completed == scenario.n_queries


def test_duplicate_flood_hits_semantic_cache():
    scenario = duplicate_flood(per_task=10, seed=0, n_hot=4, dup_factor=5)
    router = make_closed_loop_router(lam=0.4, seed=0)
    res = run_scenario(scenario, router,
                       outcome_fn=OutcomeSimulator(seed=7), seed=0,
                       cache_mode="full")
    assert res.completed == scenario.n_queries
    assert res.stats["cache_hits"] > 0


def test_pool_churn_loses_no_requests():
    """An engine killed mid-run and the held-out model joining via
    add_engine: every query must still be answered, the kill must
    surface as a restart, and the router must end with the grown pool."""
    scenario = pool_churn(per_task=15, seed=0)
    router = make_closed_loop_router(lam=0.4, seed=0,
                                     exclude=scenario.exclude)
    n_start = len(router.pool.names)
    res = run_scenario(scenario, router,
                       outcome_fn=OutcomeSimulator(seed=7), seed=0,
                       cache_mode="full", semantic_threshold=0.97)
    assert res.completed == scenario.n_queries
    assert res.stats["restarts"] >= 1
    assert len(router.pool.names) == n_start + 1
    assert scenario.exclude[0] in router.pool.names


# -- RouterBench classifier hoist (bench_routerbench fix) ---------------------


def test_run_algorithm_hoisted_fit_matches_refit():
    """The task classifier is fit once per sweep instead of once per WTP
    point; with identical training data per point the scorecards must be
    bitwise identical."""
    from benchmarks.bench_routerbench import run_algorithm
    hoisted = run_algorithm("linucb", wtps=(0.0, 0.4, 1.0), n_per_task=10,
                            seed=0, refit_per_point=False)
    refit = run_algorithm("linucb", wtps=(0.0, 0.4, 1.0), n_per_task=10,
                          seed=0, refit_per_point=True)
    assert hoisted == refit
