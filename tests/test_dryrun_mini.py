"""Mini dry-run in a subprocess: the full lower → compile → analyse path on
an 8-fake-device mesh with a reduced config (the 512-device production runs
live in launch/dryrun.py; this guards the machinery in CI time)."""
import os
import subprocess
import sys
import textwrap


def _run(code: str, timeout=900):
    return subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True, text=True, timeout=timeout)


def test_mini_dryrun_train_and_decode():
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax
        from repro import compat
        from repro.configs import get_config, input_specs, ShapeCell
        from repro.launch.mesh import make_mesh
        from repro.launch.hlo_walker import module_cost
        from repro.models import api
        from repro.train import make_train_step, make_serve_step
        from repro.train.step import opt_state_shapes

        mesh = make_mesh((2, 4), ("data", "model"))
        cfg = get_config("granite-3-8b", smoke=True)
        cell = ShapeCell("t", "train", 128, 8)
        batch = input_specs(cfg, cell)
        bundle = make_train_step(cfg, mesh, batch, n_micro=2, loss_chunk=64)
        fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings)
        with compat.set_mesh(mesh):
            lowered = fn.lower(api.param_shapes(cfg), opt_state_shapes(cfg),
                               batch)
        comp = lowered.compile()
        mem = comp.memory_analysis()
        assert mem.argument_size_in_bytes > 0
        cost = module_cost(comp.as_text(), 8)
        assert cost.flops > 1e6, cost.flops
        assert cost.coll_bytes > 0            # grad all-reduce exists

        # decode bundle on the same mesh
        b2 = make_serve_step(cfg, mesh, batch_size=8, seq_len=256)
        cache = api.cache_shapes(cfg, 8, 256)
        import jax.numpy as jnp
        tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
        fn2 = jax.jit(b2.fn, in_shardings=b2.in_shardings,
                      out_shardings=b2.out_shardings)
        with compat.set_mesh(mesh):
            comp2 = fn2.lower(api.param_shapes(cfg), cache, tok).compile()
        assert comp2.memory_analysis().argument_size_in_bytes > 0
        print("OK")
    """)
    assert "OK" in r.stdout, (r.stdout[-1000:], r.stderr[-3000:])


def test_multipod_mesh_axes_subprocess():
    r = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax
        from repro.launch.mesh import make_mesh
        from repro.models.sharding import ShardingPolicy
        from repro.configs import get_config
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("granite-3-8b", smoke=True)
        pol = ShardingPolicy(cfg, mesh, "train")
        spec = pol.batch_spec("tokens", (8, 128))
        assert spec[0] == ("pod", "data"), spec   # batch spans the pod axis
        print("OK")
    """)
    assert "OK" in r.stdout, (r.stdout[-500:], r.stderr[-3000:])
