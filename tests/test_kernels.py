"""Per-kernel shape/dtype sweeps asserted against the pure-jnp oracles
(interpret mode executes the kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.featurize.ops import hashed_embed
from repro.kernels.featurize.ref import hashed_embed_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.linucb.ops import linucb_scores
from repro.kernels.linucb.ref import linucb_scores_ref
from repro.kernels.mamba2.ops import ssd
from repro.kernels.mamba2.ref import ssd_ref
from repro.kernels.moe_gating.ops import topk_gating
from repro.kernels.moe_gating.ref import topk_gating_ref
from repro.kernels.rwkv6.ops import wkv
from repro.kernels.rwkv6.ref import wkv_ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("b,sq,sk,hq,hk,hd,win,causal", [
    (2, 128, 128, 4, 2, 64, 128, True),
    (1, 256, 256, 8, 8, 32, 64, True),      # sliding window
    (2, 64, 192, 4, 4, 64, 192, False),     # cross attention
    (1, 128, 128, 6, 2, 128, 10_000, True), # GQA group 3
])
def test_flash_attention(dtype, b, sq, sk, hq, hk, hd, win, causal):
    ks = jax.random.split(jax.random.PRNGKey(sq + hq), 3)
    q = _rand(ks[0], (b, sq, hq, hd), dtype)
    k = _rand(ks[1], (b, sk, hk, hd), dtype)
    v = _rand(ks[2], (b, sk, hk, hd), dtype)
    out = flash_attention(q, k, v, window=win, chunk=64, causal=causal,
                          interpret=True)
    ref = attention_ref(q, k, v, window=win, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=tol, rtol=tol)


def test_flash_attention_dynamic_window():
    """One compiled kernel must serve different traced windows (gemma3)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (1, 128, 4, 64), jnp.float32)
    k = _rand(ks[1], (1, 128, 4, 64), jnp.float32)
    v = _rand(ks[2], (1, 128, 4, 64), jnp.float32)
    fn = jax.jit(lambda w: flash_attention(q, k, v, window=w, chunk=64,
                                           interpret=True))
    for w in (16, 64, 128):
        np.testing.assert_allclose(
            np.asarray(fn(jnp.int32(w))),
            np.asarray(attention_ref(q, k, v, window=w)), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("b,s,hq,hk,hd,clen,win", [
    (2, 1024, 8, 2, 64, 700, 10_000),
    (1, 2048, 4, 4, 128, 2047, 256),
    (3, 512, 6, 2, 64, 5, 10_000),
])
def test_decode_attention(dtype, b, s, hq, hk, hd, clen, win):
    ks = jax.random.split(jax.random.PRNGKey(s + clen), 3)
    q = _rand(ks[0], (b, 1, hq, hd), dtype)
    k = _rand(ks[1], (b, s, hk, hd), dtype)
    v = _rand(ks[2], (b, s, hk, hd), dtype)
    out = decode_attention(q, k, v, window=win, cache_len=clen, block_k=256,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, window=win, cache_len=clen)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("t,e,k", [(256, 8, 2), (128, 60, 4), (64, 16, 1),
                                   (512, 32, 4)])
def test_moe_gating(t, e, k):
    logits = jax.random.normal(jax.random.PRNGKey(t + e), (t, e), jnp.float32)
    w, i = topk_gating(logits, k, block_t=64, interpret=True)
    wr, ir = topk_gating_ref(logits, k)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(w).sum(-1), 1.0, atol=1e-6)


@pytest.mark.parametrize("b,s,h,kd,chunk", [(1, 64, 1, 64, 16),
                                            (2, 96, 3, 64, 32)])
def test_rwkv6_wkv(b, s, h, kd, chunk):
    ks = jax.random.split(jax.random.PRNGKey(b * s), 6)
    r = jax.random.normal(ks[0], (b, s, h, kd)) * 0.5
    k = jax.random.normal(ks[1], (b, s, h, kd)) * 0.5
    v = jax.random.normal(ks[2], (b, s, h, kd)) * 0.5
    logw = -jnp.exp(jax.random.uniform(ks[3], (b, s, h, kd),
                                       minval=-6.0, maxval=-2.0))
    u = jax.random.normal(ks[4], (h, kd)) * 0.5
    s0 = jax.random.normal(ks[5], (b, h, kd, kd)) * 0.1
    y, sf = wkv(r, k, v, logw, u, s0=s0, chunk=chunk, interpret=True)
    yr, sfr = wkv_ref(r, k, v, logw, u, s0=s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sfr),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [(1, 64, 2, 64, 16, 16),
                                             (2, 96, 1, 64, 64, 32)])
def test_mamba2_ssd(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(b + s), 4)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    B = jax.random.normal(ks[2], (b, s, n)) * 0.5
    C = jax.random.normal(ks[3], (b, s, n)) * 0.5
    A = -jnp.exp(jax.random.uniform(jax.random.PRNGKey(7), (h,),
                                    minval=0.0, maxval=1.5))
    y, hf = ssd(x, dt, B, C, A, chunk=chunk, interpret=True)
    yr, hfr = ssd_ref(x, dt, B, C, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hfr),
                               atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("m,d,q,alpha", [(16, 12, 1, 0.1), (8, 64, 32, 0.5),
                                         (64, 128, 128, 0.1),
                                         # non-power-of-two Q: pad + slice
                                         (16, 12, 7, 0.1), (8, 64, 50, 0.5)])
def test_linucb_kernel(m, d, q, alpha):
    ks = jax.random.split(jax.random.PRNGKey(m + d), 3)
    L = jax.random.normal(ks[0], (m, d, d)) * 0.2
    a_inv = jnp.einsum("mij,mkj->mik", L, L) + jnp.eye(d)[None]
    theta = jax.random.normal(ks[1], (m, d))
    x = jax.random.normal(ks[2], (q, d))
    out = linucb_scores(a_inv, theta, x, alpha, interpret=True)
    ref = linucb_scores_ref(a_inv, theta, x, alpha)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("q,l,h,d", [(1, 5, 256, 128), (8, 64, 2048, 384),
                                     # non-power-of-two Q/L: pad + slice
                                     (7, 37, 512, 128), (12, 200, 2048, 384)])
def test_featurize_kernel(q, l, h, d):
    ks = jax.random.split(jax.random.PRNGKey(q + l), 3)
    ids = jax.random.randint(ks[0], (q, l), 0, h, dtype=jnp.int32)
    # ragged rows: pad the tail of each row with id -1 / weight 0
    lens = jax.random.randint(ks[1], (q, 1), 0, l + 1)
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, l), 1) < lens
    ids = jnp.where(mask, ids, -1)
    weights = jnp.where(mask, jax.random.uniform(ks[2], (q, l)) + 0.25, 0.0)
    proj = jax.random.normal(jax.random.PRNGKey(3), (h, d)) / np.sqrt(h)
    out = hashed_embed(ids, weights, proj, interpret=True)
    ref = hashed_embed_ref(ids, weights, proj)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    # unit rows (or exactly zero for all-padding rows)
    norms = np.linalg.norm(np.asarray(out), axis=1)
    empty = np.asarray(lens)[:, 0] == 0
    np.testing.assert_allclose(norms[~empty], 1.0, atol=1e-5)
    assert np.all(norms[empty] == 0.0)
