"""Telemetry subsystem: metrics, power traces, export round-trips, and the
energy-budget governor's accuracy-preserving cap enforcement."""
import numpy as np
import pytest

from repro.configs.pool import PAPER_POOL
from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import Feedback, ModelProfile, Query, RouterConfig
from repro.data.stream import make_stream
from repro.serving import Request, SimEngine
from repro.telemetry import (EnergyBudgetGovernor, EventLog, MetricsRegistry,
                             P2Quantile, PowerTrace, Telemetry,
                             diurnal_carbon_intensity, dump_jsonl, load_jsonl,
                             parse_prometheus, to_prometheus)


# ---------------------------------------------------------------------------
# metrics primitives
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_counter_and_gauge(self):
        r = MetricsRegistry()
        c = r.counter("requests_total", {"model": "a"})
        c.inc()
        c.inc(2.5)
        assert c.value == pytest.approx(3.5)
        g = r.gauge("queue_depth")
        g.set(7)
        assert g.value == 7.0

    def test_registry_get_or_create_identity(self):
        r = MetricsRegistry()
        assert r.counter("x", {"m": "1"}) is r.counter("x", {"m": "1"})
        assert r.counter("x", {"m": "1"}) is not r.counter("x", {"m": "2"})
        with pytest.raises(TypeError):
            r.gauge("x", {"m": "1"})    # same name+labels, different kind

    def test_p2_quantile_approximates_numpy(self):
        rng = np.random.default_rng(0)
        data = rng.lognormal(3.0, 0.7, size=5000)
        for q in (0.5, 0.95, 0.99):
            est = P2Quantile(q)
            for x in data:
                est.update(x)
            exact = np.percentile(data, 100 * q)
            assert est.value == pytest.approx(exact, rel=0.12)

    def test_histogram_summary_stats(self):
        r = MetricsRegistry()
        h = r.histogram("latency_ms")
        for v in [1.0, 2.0, 3.0, 4.0, 100.0]:
            h.record(v)
        assert h.count == 5
        assert h.sum == pytest.approx(110.0)
        assert h.min == 1.0 and h.max == 100.0
        assert h.mean == pytest.approx(22.0)
        assert 1.0 <= h.quantile(0.5) <= 4.0


class TestPowerTrace:
    def test_watts_from_joule_deltas(self):
        tr = PowerTrace()
        # 10 J per 2 s per engine → 5 W each, 10 W pool-wide
        for i, t in enumerate([0.0, 2.0, 4.0, 6.0]):
            tr.sample_all(t, {"a": 10.0 * i, "b": 10.0 * i})
        assert tr.last_watts("a") == pytest.approx(5.0)
        assert tr.last_watts() == pytest.approx(10.0)      # pool
        assert tr.peak_watts() == pytest.approx(10.0)
        assert tr.avg_watts() == pytest.approx(10.0)
        assert tr.total_wh() == pytest.approx(60.0 / 3600.0)

    def test_non_monotone_clock_does_not_divide_by_zero(self):
        tr = PowerTrace()
        tr.sample("a", 1.0, 0.0)
        tr.sample("a", 1.0, 5.0)      # same timestamp: folded, not crashed
        tr.sample("a", 2.0, 10.0)
        assert tr.last_watts("a") == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# export round-trips
# ---------------------------------------------------------------------------


def _recorded_registry():
    r = MetricsRegistry()
    r.counter("greenserv_completed_total", {"model": "m1"},
              help="completions").inc(41)
    r.gauge("greenserv_lambda").set(0.62)
    h = r.histogram("greenserv_latency_ms", {"model": "m1"})
    for v in np.linspace(5.0, 500.0, 200):
        h.record(float(v))
    return r


class TestExport:
    def test_prometheus_round_trip(self):
        r = _recorded_registry()
        parsed = parse_prometheus(to_prometheus(r))
        assert parsed[("greenserv_completed_total",
                       (("model", "m1"),))] == 41.0
        assert parsed[("greenserv_lambda", ())] == pytest.approx(0.62)
        h = r.find("greenserv_latency_ms", {"model": "m1"})
        key = ("greenserv_latency_ms",
               tuple(sorted((("model", "m1"), ("quantile", "0.5")))))
        assert parsed[key] == pytest.approx(h.quantile(0.5))
        assert parsed[("greenserv_latency_ms_count",
                       (("model", "m1"),))] == 200

    def test_jsonl_round_trip(self, tmp_path):
        r = _recorded_registry()
        tr = PowerTrace()
        for i, t in enumerate([0.0, 1.0, 2.0]):
            tr.sample_all(t, {"eng": 36.0 * i})
        ev = EventLog()
        ev.emit("restart", 1.5, engine="eng", n_requeued=3)
        path = str(tmp_path / "metrics.jsonl")
        n = dump_jsonl(path, r, tr, ev, meta={"run": "test"})
        assert n > 0
        back = load_jsonl(path)
        assert back["meta"][0]["run"] == "test"
        counters = {(row["name"], tuple(sorted(row["labels"].items()))): row
                    for row in back["counter"]}
        assert counters[("greenserv_completed_total",
                         (("model", "m1"),))]["value"] == 41.0
        hist = back["histogram"][0]
        h = r.find("greenserv_latency_ms", {"model": "m1"})
        assert hist["count"] == h.count
        assert hist["sum"] == pytest.approx(h.sum)
        assert hist["quantiles"]["0.5"] == pytest.approx(h.quantile(0.5))
        # power series round-trips sample-for-sample
        pool_rows = [row for row in back["power"]
                     if row["source"] == "__pool__"]
        assert [r_["watts"] for r_ in pool_rows] == pytest.approx(
            [s.watts for s in tr.series()])
        assert back["event"][0]["engine"] == "eng"


# ---------------------------------------------------------------------------
# governor mechanics (unit level)
# ---------------------------------------------------------------------------


class _RouterStub:
    def __init__(self, lam=0.4):
        self.config = RouterConfig(lam=lam)
        self.calls = []

    def set_lambda(self, lam):
        self.config.lam = lam
        self.calls.append(lam)


class TestGovernorMechanics:
    def test_requires_exactly_one_horizon(self):
        with pytest.raises(ValueError):
            EnergyBudgetGovernor(1.0)
        with pytest.raises(ValueError):
            EnergyBudgetGovernor(1.0, horizon_queries=10, horizon_s=10.0)

    def test_overburn_tightens_lambda(self):
        router = _RouterStub(lam=0.4)
        gov = EnergyBudgetGovernor(1.0, horizon_queries=100, router=router)
        for i in range(30):                       # 10× the sustainable rate
            gov.on_completion(0.1, t_s=float(i))
        assert router.config.lam > 0.4
        assert gov.pressure > 0.5

    def test_underburn_relaxes_lambda_back(self):
        router = _RouterStub(lam=0.4)
        gov = EnergyBudgetGovernor(10.0, horizon_queries=100, router=router)
        for i in range(20):
            gov.on_completion(0.2, t_s=float(i))  # hot: 2× sustainable
        tight = router.config.lam
        assert tight > 0.4
        assert not gov.exhausted
        for i in range(20, 60):
            gov.on_completion(0.001, t_s=float(i))  # cold: 1% of rate
        assert router.config.lam < tight

    def test_exhaustion_pins_lambda_max(self):
        router = _RouterStub(lam=0.4)
        gov = EnergyBudgetGovernor(1.0, horizon_queries=1000, router=router,
                                   lambda_max=0.85)
        for i in range(10):
            gov.on_completion(0.12, t_s=float(i))  # blows the whole budget
        assert gov.exhausted
        assert router.config.lam == pytest.approx(0.85)

    def test_wall_clock_refill(self):
        router = _RouterStub(lam=0.4)
        gov = EnergyBudgetGovernor(3600.0, horizon_s=3600.0, router=router)
        gov.step(0.0)
        gov.on_completion(50.0, t_s=1.0)          # drain far below refill
        gov.step(1.0)
        drained = gov.bucket_wh
        gov.step(100.0)                           # 99 s of ~1 Wh/s refill
        assert gov.bucket_wh > drained

    def test_carbon_signal_scales_refill(self):
        dirty = EnergyBudgetGovernor(100.0, horizon_queries=100,
                                     carbon_fn=lambda t: 2.0)
        clean = EnergyBudgetGovernor(100.0, horizon_queries=100,
                                     carbon_fn=lambda t: 0.5)
        for gov in (dirty, clean):
            gov.bucket_wh = 0.0
            gov.on_completion(0.0, t_s=0.0)
        assert dirty.bucket_wh < clean.bucket_wh

    def test_diurnal_carbon_intensity_cycles(self):
        period = 86_400.0
        vals = [diurnal_carbon_intensity(t, period_s=period)
                for t in np.linspace(0, period, 97)]
        assert max(vals) > 1.2 and min(vals) < 0.8
        assert np.mean(vals) == pytest.approx(1.0, abs=0.02)


class TestSetLambdaRescalarization:
    def test_posterior_shifts_without_new_feedback(self):
        """After set_lambda, the bandit prefers the cheap arm immediately —
        the decomposed statistics rebuild b/θ under the new trade-off."""
        profiles = [ModelProfile(name="cheap", family="s", params_b=1.0),
                    ModelProfile(name="lux", family="s", params_b=30.0)]
        pool = ModelPool(profiles)
        router = GreenServRouter(
            RouterConfig(lam=0.1, energy_scale_wh=0.05, max_arms=4,
                         alpha_ucb=0.01), pool)
        q = [Query(uid=i, text=f"question number {i} about physics")
             for i in range(40)]
        outcomes = {"cheap": (0.5, 0.01), "lux": (0.9, 0.09)}
        for i, query in enumerate(q):
            arm = i % 2                           # force both arms to learn
            d = router.route(query)
            name = pool[arm].name
            acc, e = outcomes[name]
            router._pending[query.uid] = d.__class__(
                query_uid=query.uid, model_index=arm, model_name=name,
                context=d.context, ucb_scores=d.ucb_scores,
                feasible_mask=d.feasible_mask, overhead_ms=0.0)
            router.feedback(Feedback(query_uid=query.uid, model_index=arm,
                                     accuracy=acc, energy_wh=e,
                                     latency_ms=1.0))
        probe = Query(uid=999, text="a fresh probe question about physics")
        assert router.route(probe).model_name == "lux"    # λ=0.1: accuracy
        router.set_lambda(0.9)
        probe2 = Query(uid=1000, text="a fresh probe question about physics")
        assert router.route(probe2).model_name == "cheap"  # λ=0.9: energy

    def test_set_lambda_after_legacy_checkpoint_keeps_posterior(self):
        """A checkpoint without decomposed stats cannot be rescalarized;
        set_lambda must not rebuild b/θ from the (all-zero) sums and wipe
        the restored posterior."""
        pool = ModelPool([ModelProfile(name="m", family="s", params_b=1.0)])
        router = GreenServRouter(RouterConfig(lam=0.4, max_arms=2), pool)
        q = Query(uid=1, text="warm the posterior with one observation")
        router.route(q)
        router.feedback(Feedback(query_uid=1, model_index=0, accuracy=0.8,
                                 energy_wh=0.02, latency_ms=1.0))
        sd = router.state_dict()
        del sd["decomposed"]                      # pre-decomposition era
        pool2 = ModelPool([ModelProfile(name="m", family="s", params_b=1.0)])
        restored = GreenServRouter(RouterConfig(lam=0.4, max_arms=2), pool2)
        restored.load_state_dict(sd)
        b_before = np.asarray(restored.policy.state.b).copy()
        assert np.any(b_before != 0.0)
        restored.set_lambda(0.9)
        assert restored.config.lam == 0.9
        np.testing.assert_array_equal(
            np.asarray(restored.policy.state.b), b_before)

    def test_set_lambda_validates_and_updates_rewards(self):
        pool = ModelPool([ModelProfile(name="m", family="s", params_b=1.0)])
        router = GreenServRouter(RouterConfig(lam=0.4, max_arms=2), pool)
        with pytest.raises(ValueError):
            router.set_lambda(1.5)
        router.set_lambda(0.7)
        assert router.config.lam == 0.7
        assert router.rewards.config.lam == 0.7   # shared config object


# ---------------------------------------------------------------------------
# SimEngine concurrency (satellite)
# ---------------------------------------------------------------------------


class TestSimEngineConcurrency:
    def _engine(self, concurrency, steps_per_query=1):
        prof = ModelProfile(name="sim", family="s", params_b=1.0)
        return SimEngine(prof, lambda q, m: (1.0, 0.001, 1.0, 4),
                         steps_per_query=steps_per_query,
                         concurrency=concurrency)

    def _submit(self, eng, n):
        for i in range(n):
            eng.submit(Request(query=Query(uid=i, text=f"q{i}"),
                               prompt_tokens=[1, 2], max_new_tokens=4))

    def test_deep_queue_drains_k_per_step(self):
        eng = self._engine(concurrency=3)
        self._submit(eng, 10)
        assert len(eng.step()) == 3
        assert eng.pending == 7

    def test_default_concurrency_matches_old_serial_semantics(self):
        eng = self._engine(concurrency=1, steps_per_query=2)
        self._submit(eng, 2)
        assert eng.step() == []                   # head in progress
        assert len(eng.step()) == 1               # head completes
        assert eng.pending == 1

    def test_progress_is_per_request_not_per_queue(self):
        eng = self._engine(concurrency=2, steps_per_query=2)
        self._submit(eng, 4)
        assert eng.step() == []                   # two in flight, mid-work
        assert len(eng.step()) == 2               # both complete together
        assert len(eng.step()) == 0
        assert len(eng.step()) == 2


def test_hedge_duplicate_energy_charged_to_governor():
    """The losing hedge duplicate never completes, but its work drew real
    power — the budget must be charged (winner's energy as proxy)."""
    profiles = [ModelProfile(name="sim0", family="s", params_b=1.0),
                ModelProfile(name="sim1", family="s", params_b=2.0)]
    pool = ModelPool(profiles)

    def outcome(query, model):
        return 0.5, 0.02, 10.0, 4
    # fresh bandit routes to arm 0 (scores tie); make it slow so the
    # hedge onto the fast engine wins
    engines = {"sim0": SimEngine(profiles[0], outcome, steps_per_query=50),
               "sim1": SimEngine(profiles[1], outcome, steps_per_query=1)}
    from repro.serving import PoolServer
    router = GreenServRouter(RouterConfig(max_arms=4), pool)
    governor = EnergyBudgetGovernor(10.0, horizon_queries=4)
    server = PoolServer(router, engines, hedge_after_steps=1,
                        telemetry=Telemetry(governor=governor))
    q = make_stream(per_task=1)[0]
    server.submit(q)
    for _ in range(10):
        server.step()
        if q.uid in server.responses:
            break
    assert server.stats["hedges"] == 1
    resp_wh = sum(r.energy_wh for r in server.responses.values())
    assert governor.cumulative_wh == pytest.approx(2 * resp_wh)


# ---------------------------------------------------------------------------
# the acceptance run: governed vs ungoverned serving (deterministic)
# ---------------------------------------------------------------------------


_KEEP = {"qwen2.5-0.5b", "qwen2.5-1.5b", "qwen2.5-7b", "llama-3.1-8b",
         "phi-4-mini-4b", "phi-4-14b", "gemma-3-12b", "qwen2.5-14b"}
_EXCLUDE = [row[0] for row in PAPER_POOL if row[0] not in _KEEP]


def _serve_stream(queries, telemetry, seed=0, batch=10):
    from benchmarks.common import drive_pool_stream
    res = drive_pool_stream(queries, telemetry, seed=seed, batch=batch,
                            exclude=_EXCLUDE, max_arms=16,
                            fit_classifier=True)
    return res.mean_accuracy, res.total_energy_wh, res.server


def test_governor_holds_cap_and_preserves_accuracy():
    """Acceptance: a Wh cap at 60% of the ungoverned consumption is held,
    with mean accuracy within 10% relative of the ungoverned run."""
    queries = make_stream(per_task=200, seed=0)
    acc_un, wh_un, _ = _serve_stream(queries, Telemetry())
    budget = 0.6 * wh_un
    governor = EnergyBudgetGovernor(budget, horizon_queries=len(queries),
                                    gain=0.005, lambda_max=0.75)
    acc_gov, wh_gov, server = _serve_stream(
        queries, Telemetry(governor=governor))
    assert wh_gov <= budget, (
        f"governed {wh_gov:.2f} Wh exceeds {budget:.2f} Wh cap")
    assert acc_gov >= 0.9 * acc_un, (
        f"governed accuracy {acc_gov:.3f} below 90% of ungoverned "
        f"{acc_un:.3f}")
    # the governor actually acted, and its accounting matches the server's
    assert len(governor.lambda_history) > 0
    assert max(l for _, l in governor.lambda_history) > 0.4
    assert governor.cumulative_wh == pytest.approx(wh_gov, rel=1e-6)
    # telemetry observed the run end to end
    tel = server.telemetry
    assert tel.registry.find("greenserv_admitted_total").value == len(queries)
    lat = tel.registry.find("greenserv_latency_ms")
    assert lat is not None and lat.count == len(queries)
    assert tel.power.total_wh() > 0


# ---------------------------------------------------------------------------
# phase-tagged energy (chunked prefill)
# ---------------------------------------------------------------------------


class _PhaseFakeEngine:
    """Minimal BaseEngine stand-in reporting phase-tagged joules."""

    def __init__(self):
        self.pending = 0
        self.joules = {"prefill": 0.0, "decode": 0.0}

    def cumulative_joules(self):
        return sum(self.joules.values())

    def cumulative_joules_by_phase(self):
        return dict(self.joules)


def test_hub_tags_prefill_vs_decode_energy():
    """Telemetry.on_step splits the pool burn by serving phase: counters,
    watts gauges, PowerTrace series, and the governor's phase ledger all
    see prefill and decode separately."""
    from repro.telemetry.power import PHASE_DECODE, PHASE_PREFILL

    governor = EnergyBudgetGovernor(10.0, horizon_queries=100)
    clock = iter(float(i) for i in range(100))
    tel = Telemetry(governor=governor, clock=lambda: next(clock))
    eng = _PhaseFakeEngine()
    engines = {"m0": eng}
    tel.on_step(engines)                       # anchor sample
    eng.joules = {"prefill": 36.0, "decode": 0.0}     # 36 J = 0.01 Wh
    tel.on_step(engines)
    eng.joules = {"prefill": 36.0, "decode": 72.0}
    tel.on_step(engines)

    pre = tel.registry.find("greenserv_energy_joules_total",
                            {"phase": "prefill"})
    dec = tel.registry.find("greenserv_energy_joules_total",
                            {"phase": "decode"})
    assert pre.value == pytest.approx(36.0)
    assert dec.value == pytest.approx(72.0)
    # phase watts: 36 J over 1 s then 72 J over 1 s
    assert tel.power.last_watts(PHASE_PREFILL) == pytest.approx(0.0)
    assert tel.power.last_watts(PHASE_DECODE) == pytest.approx(72.0)
    assert tel.power.total_wh(PHASE_PREFILL) == pytest.approx(36.0 / 3600.0)
    # phase pseudo-sources never leak into the per-engine source list
    assert set(tel.power.sources) == {"m0"}
    # governor ledger: attribution only (the bucket drains per completion)
    stats = governor.stats()
    assert stats["prefill_wh"] == pytest.approx(0.01)
    assert stats["decode_wh"] == pytest.approx(0.02)
    assert governor.cumulative_wh == 0.0
    # the end-of-run summary surfaces the split
    assert "phases" in tel.summary()
