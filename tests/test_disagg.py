"""Disaggregated prefill/decode serving: migration correctness, fault
injection, and unified equivalence (docs/SERVING.md "Disaggregated
serving").

The invariants under test:

  * equivalence — a disaggregated pool (prefill engine + decode twin,
    shared params, KV migrated at the phase boundary) generates *token
    identical* output to a unified pool for the same traffic, across
    dense / moe / encdec layouts;
  * liveness — under arbitrary bursty arrival/finish interleavings and
    engine restarts (hypothesis), no request is ever lost, duplicated,
    or completed twice, and per-response accounting fields
    (``prefix_reused``, ``kv_migrated``, phase energy) stay consistent;
  * fault tolerance — killing the prefill engine mid-migration or the
    decode twin mid-stream re-queues and completes every request, with
    energy re-charged for redone work but never un-spent;
  * fallback — recurrent layouts (no full-depth positional KV) refuse
    the prefill role and keep serving unified;
  * drain honesty — ``run_until_drained`` raises ``LivelockError``
    instead of silently returning with live work.

Run the subset with ``-m disagg``.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import Query, RouterConfig
from repro.data import tokenizer as tok
from repro.serving import LivelockError, ModelEngine, PoolServer

pytestmark = pytest.mark.disagg

MAX_LEN = 48
CHUNK = 4


def _cfg(arch):
    # fp32 so unified and disaggregated runs argmax identically
    return get_config(arch, smoke=True, vocab_size=tok.VOCAB_SIZE,
                      dtype="float32", kv_update="where")


def _build_pair(arch, seed=0, max_batch=2):
    """(prefill-capable engine, decode twin sharing its params)."""
    cfg = _cfg(arch)
    eng = ModelEngine(arch, cfg, jax.random.PRNGKey(seed),
                      max_batch=max_batch, max_len=MAX_LEN,
                      prefill_chunk=CHUNK)
    twin = ModelEngine(arch, cfg, jax.random.PRNGKey(seed),
                       max_batch=max_batch, max_len=MAX_LEN,
                       params=eng.params, prefill_chunk=CHUNK,
                       role="decode")
    return eng, twin


def _server(eng, twin=None):
    pool = ModelPool([eng.profile])
    router = GreenServRouter(
        RouterConfig(lam=0.4, energy_scale_wh=0.05), pool)
    return PoolServer(router, {eng.name: eng}, tokenizer=tok.encode,
                      prefill_chunk=CHUNK,
                      decode_engines={eng.name: twin} if twin else None)


def _queries(n, seed=0, max_new=(2, 6)):
    rng = np.random.default_rng(seed)
    return [Query(uid=i,
                  text=f"probe {i} " + "ctx " * int(rng.integers(1, 7)),
                  max_new_tokens=int(rng.integers(*max_new)))
            for i in range(n)]


def _drain(server, queries):
    for q in queries:
        server.enqueue(q)
        server.step()
    server.run_until_drained(max_steps=2_000)
    return server.responses


# -- equivalence: disaggregated == unified, token for token ---------------

@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen2-moe-a2.7b",
                                  "whisper-medium"])
def test_disagg_token_identical_to_unified(arch):
    """Same traffic, same seeds: the disaggregated pool must generate
    exactly the tokens the unified pool does — the migrated KV state is
    bit-for-bit the state the prefill engine left behind (mirrors
    tests/test_prefill_chunk.py's chunk-vs-tokenwise equivalence)."""
    uni, _ = _build_pair(arch)
    ref = _drain(_server(uni), _queries(6, seed=3))

    eng, twin = _build_pair(arch)
    srv = _server(eng, twin)
    got = _drain(srv, _queries(6, seed=3))

    assert set(got) == set(ref) == set(range(6))
    for uid in ref:
        assert got[uid].tokens == ref[uid].tokens, f"uid {uid} diverged"
    assert srv.stats["migrations"] > 0
    assert any(r.kv_migrated > 0 for r in got.values())
    # role split actually happened: prefill engine never decoded, twin
    # never prefilled (its only "prefill" joules are migration DMA)
    assert eng.cumulative_joules_by_phase()["decode"] == 0
    assert twin.cumulative_joules_by_phase()["decode"] > 0


def test_recurrent_layout_falls_back_to_unified():
    """rwkv has no positional KV to export — the primary refuses the
    prefill role, the twin is never registered, serving stays unified."""
    cfg = get_config("rwkv6-1.6b", smoke=True, vocab_size=tok.VOCAB_SIZE)
    eng = ModelEngine("rwkv6-1.6b", cfg, jax.random.PRNGKey(0),
                      max_batch=2, max_len=MAX_LEN, prefill_chunk=CHUNK)
    twin = ModelEngine("rwkv6-1.6b", cfg, jax.random.PRNGKey(0),
                       max_batch=2, max_len=MAX_LEN, params=eng.params,
                       role="decode")
    srv = _server(eng, twin)
    assert eng.role == "unified"
    assert not srv.decode_engines
    got = _drain(srv, _queries(4, seed=1))
    assert len(got) == 4
    assert srv.stats["migrations"] == 0
    assert all(r.kv_migrated == 0 for r in got.values())


# -- migration accounting --------------------------------------------------

def test_migration_energy_and_field_accounting():
    eng, twin = _build_pair("granite-3-8b")
    srv = _server(eng, twin)
    got = _drain(srv, _queries(5, seed=7))
    assert len(got) == 5
    for r in got.values():
        assert r.energy_wh > 0
        assert r.output_tokens == len(r.tokens) >= 1
        assert 0 <= r.kv_migrated <= r.input_tokens
    # each engine's phase ledger must sum to its cumulative meter
    for e in (eng, twin):
        phases = e.cumulative_joules_by_phase()
        assert phases["prefill"] + phases["decode"] == pytest.approx(
            e.cumulative_joules())
    # the twin's prefill-ledger entry is exactly the migration DMA —
    # phase-boundary overhead is charged to prefill, never to decode
    assert twin.cumulative_joules_by_phase()["prefill"] == pytest.approx(
        twin._migration_joules)
    assert twin._migration_joules > 0


# -- fault injection -------------------------------------------------------

def test_kill_prefill_engine_mid_migration():
    """The prefill engine dies while requests are split across both
    engines: everything re-queues and completes, and already-charged
    joules stay spent (monotone meters) while redone work is re-charged."""
    eng, twin = _build_pair("granite-3-8b")
    srv = _server(eng, twin)
    queries = _queries(8, seed=11, max_new=(3, 8))
    for q in queries:
        srv.enqueue(q)
    killed = False
    for _ in range(2_000):
        srv.step()
        if (not killed and srv.stats["migrations"] > 0 and srv.inflight):
            before = eng.cumulative_joules() + twin.cumulative_joules()
            eng.inject_failure()
            killed = True
        if not srv.inflight and not srv.arrivals:
            break
    assert killed, "stream drained before the kill window"
    assert srv.stats["restarts"] >= 1
    assert len(srv.responses) == len(queries)
    assert all(r.energy_wh > 0 for r in srv.responses.values())
    assert eng.cumulative_joules() + twin.cumulative_joules() >= before


def test_kill_decode_twin_mid_stream():
    """The decode twin dies with requests decoding on it: they re-route
    through the prefill side, re-prefill, re-migrate, and complete."""
    eng, twin = _build_pair("granite-3-8b")
    srv = _server(eng, twin)
    queries = _queries(8, seed=13, max_new=(4, 9))
    for q in queries:
        srv.enqueue(q)
    killed = False
    for _ in range(2_000):
        srv.step()
        if not killed and any(s is not None for s in twin.slots):
            before = eng.cumulative_joules() + twin.cumulative_joules()
            twin.inject_failure()
            killed = True
        if not srv.inflight and not srv.arrivals:
            break
    assert killed, "stream drained before the kill window"
    assert srv.stats["restarts"] >= 1
    assert len(srv.responses) == len(queries)
    assert all(r.energy_wh > 0 for r in srv.responses.values())
    assert eng.cumulative_joules() + twin.cumulative_joules() >= before


# -- drain honesty ---------------------------------------------------------

def test_run_until_drained_raises_on_live_work():
    eng, twin = _build_pair("granite-3-8b")
    srv = _server(eng, twin)
    srv.enqueue(_queries(1)[0])
    with pytest.raises(LivelockError) as exc:
        srv.run_until_drained(max_steps=0)
    assert isinstance(exc.value, TimeoutError)   # compat promise
    srv.run_until_drained()                      # and then drains fine
    assert len(srv.responses) == 1


# -- property suite: arbitrary interleavings + restarts --------------------
#
# A ``plan`` is a list of (arrivals_this_tick, kill) tuples where each
# arrival is (prompt_words, max_new_tokens) and kill targets the prefill
# engine, the decode twin, or nobody.  ``_run_interleaving`` drives it and
# asserts the liveness + accounting invariants.  The hypothesis variant
# explores adversarial plans; the seeded variant replays fixed random
# plans so the invariants stay exercised where hypothesis (a dev-only
# dependency) isn't installed.

@pytest.fixture(scope="module")
def shared_pair():
    """One compiled engine pair for every example — the jitted chunk and
    decode steps are per-instance closures, so rebuilding engines per
    example would recompile them each time.  Examples hard-reset the pair
    instead (restart() clears all per-request state; the energy meters
    stay monotone, which is exactly the production restart contract the
    properties assert against)."""
    return _build_pair("granite-3-8b")


def _run_interleaving(eng, twin, plan):
    eng.restart()
    twin.restart()
    srv = _server(eng, twin)
    uid, completions, expected = 0, [], []
    joules_floor = 0.0
    for arrivals, kill in plan:
        for words, max_new in arrivals:
            srv.enqueue(Query(uid=uid, text=f"u{uid} " + "tok " * words,
                              max_new_tokens=max_new))
            expected.append(uid)
            uid += 1
        if kill == "prefill" and (srv.inflight or srv.arrivals):
            eng.inject_failure()
        elif kill == "decode" and srv.inflight:
            twin.inject_failure()
        completions += [r.uid for r in srv.step()]
        total = eng.cumulative_joules() + twin.cumulative_joules()
        assert total >= joules_floor       # energy is never un-spent
        joules_floor = total
    for _ in range(2_000):
        if not srv.inflight and not srv.arrivals:
            break
        completions += [r.uid for r in srv.step()]

    # no request lost, duplicated, or completed twice
    assert sorted(completions) == sorted(expected)
    assert set(srv.responses) == set(expected)
    for r in srv.responses.values():
        assert r.energy_wh > 0
        assert r.output_tokens == len(r.tokens) >= 1
        assert 0 <= r.prefix_reused < max(r.input_tokens, 1)
        assert 0 <= r.kv_migrated <= r.input_tokens
    for e in (eng, twin):
        phases = e.cumulative_joules_by_phase()
        assert phases["prefill"] + phases["decode"] == pytest.approx(
            e.cumulative_joules())


_KILLS = ["none", "none", "none", "prefill", "decode"]


def test_no_request_lost_or_duplicated_seeded(shared_pair):
    eng, twin = shared_pair
    for seed in range(4):
        rng = np.random.default_rng(seed)
        plan = [([(int(rng.integers(1, 9)), int(rng.integers(1, 6)))
                  for _ in range(int(rng.integers(0, 3)))],
                 _KILLS[int(rng.integers(0, len(_KILLS)))])
                for _ in range(int(rng.integers(4, 15)))]
        _run_interleaving(eng, twin, plan)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                          # pragma: no cover
    pass
else:
    _PLAN = st.lists(
        st.tuples(
            st.lists(st.tuples(st.integers(1, 8), st.integers(1, 5)),
                     min_size=0, max_size=2),
            st.sampled_from(_KILLS)),
        min_size=4, max_size=14)

    @given(plan=_PLAN)
    @settings(max_examples=10, deadline=None)
    def test_no_request_lost_or_duplicated(shared_pair, plan):
        eng, twin = shared_pair
        _run_interleaving(eng, twin, plan)
