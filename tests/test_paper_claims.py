"""Validation against the paper's own claims (EXPERIMENTS.md §Validation).

Reduced-T versions of the paper's headline results — loose tolerances, the
full-scale numbers live in bench_output.txt.
"""
import numpy as np
import pytest

from benchmarks.common import make_router, run_policy, stream
from repro.data import OutcomeSimulator


@pytest.fixture(scope="module")
def results():
    qs = stream(per_task=150)          # T = 750 (paper: 2,500)
    out = {}
    router = make_router(lam=0.4, seed=0)
    out["greenserv"] = run_policy(router, qs, OutcomeSimulator(7),
                                  "greenserv")
    out["random"] = run_policy(None, qs, OutcomeSimulator(7), "random",
                               random_seed=3)
    out["largest"] = run_policy(None, qs, OutcomeSimulator(7), "largest",
                                static_model="yi-34b")
    out["smallest"] = run_policy(None, qs, OutcomeSimulator(7), "smallest",
                                 static_model="qwen2.5-0.5b")
    out["best"] = run_policy(None, qs, OutcomeSimulator(7), "best",
                             static_model="gemma-3-27b")
    nonctx = make_router(lam=0.4, algorithm="eps_greedy",
                         features=(False, False, False), seed=0)
    out["nonctx"] = run_policy(nonctx, qs, OutcomeSimulator(7), "nonctx")
    return out


def test_greenserv_beats_random_accuracy(results):
    """Paper: +22% accuracy vs random routing."""
    gain = results["greenserv"].mean_accuracy / results["random"].mean_accuracy
    assert gain > 1.10, gain


def test_greenserv_saves_energy_vs_random(results):
    """Paper: −31% cumulative energy vs random routing (full T=2,500 run in
    bench_output.txt hits it; at T=750 exploration still dominates)."""
    ratio = (results["greenserv"].total_energy_wh
             / results["random"].total_energy_wh)
    assert ratio < 0.95, ratio


def test_static_baseline_ordering(results):
    """Paper Fig. 2a orderings: best-static most accurate AND most energy;
    smallest least energy; largest mediocre accuracy."""
    assert results["best"].mean_accuracy > results["greenserv"].mean_accuracy
    assert (results["best"].total_energy_wh
            > 3 * results["greenserv"].total_energy_wh)
    assert (results["smallest"].total_energy_wh
            < results["greenserv"].total_energy_wh)
    assert results["largest"].mean_accuracy < 0.45


def test_contextual_regret_below_noncontextual(results):
    """Paper Fig. 3: contextual ≈398–412 < non-contextual ≈466.

    At the fixture's reduced T=750 the contextual policy is still paying
    its (larger-d) exploration cost — the paper's own Fig. 5 shows the
    same effect — so the bound carries slack; the strict inequality holds
    at T=2,500 (bench_output.txt: 48.5 vs 140.8)."""
    assert (results["greenserv"].cumulative_regret
            < 1.25 * results["nonctx"].cumulative_regret)


def test_regret_sublinear(results):
    """Learning: per-step regret in the last quarter ≪ the first quarter."""
    curve = results["greenserv"].regret_curve
    t = len(curve)
    first = curve[t // 4] / (t // 4)
    last = (curve[-1] - curve[3 * t // 4]) / (t - 3 * t // 4)
    assert last < 0.6 * first, (first, last)


def test_greenserv_near_static_pareto(results):
    """Paper: GreenServ reaches accuracy-energy points no single model
    offers — more accurate than anything at comparable energy."""
    gs = results["greenserv"]
    from repro.data.profiles import ACCURACY, mean_energy_mwh, TASK_TOKENS
    from repro.core.types import TaskType
    for m, accs in ACCURACY.items():
        e = np.mean([mean_energy_mwh(m, t) / 1e3 for t in TaskType])
        acc = np.mean(accs)
        per_q = gs.total_energy_wh / len(gs.selection_trace)
        if e <= per_q * 1.05:
            assert acc <= gs.mean_accuracy + 0.02, \
                (m, acc, gs.mean_accuracy)


def test_routing_overhead_small(results):
    """Paper Table 4: ≈7 ms/query total; assert same order of magnitude."""
    assert results["greenserv"].mean_decision_ms < 25.0
