"""Direct coverage for the analytic TPU energy model (core.energy)."""
import numpy as np
import pytest

from repro.core.energy import (CHIP_IDLE_W, CostModelParams, EnergyMonitor,
                               JOULES_PER_WH, decode_step_cost, energy_joules,
                               energy_wh, prefill_cost, roofline)


def _params(n=7e9, active=None):
    return CostModelParams(n_params=n, n_active_params=active or n,
                           d_model=4096, n_layers=32, kv_heads=8,
                           head_dim=128)


class TestRoofline:
    def test_bottleneck_selection(self):
        # overwhelming FLOPs → compute-bound, and so on for each resource
        assert roofline(1e18, 1e6, 1e3).bottleneck == "compute"
        assert roofline(1e9, 1e15, 1e3).bottleneck == "memory"
        assert roofline(1e9, 1e6, 1e14).bottleneck == "collective"

    def test_t_step_is_max_of_terms(self):
        t = roofline(1e15, 1e12, 1e9, chips=2)
        assert t.t_step == max(t.t_compute, t.t_memory, t.t_collective)

    def test_chips_divide_time(self):
        t1 = roofline(1e15, 1e12, 0.0, chips=1)
        t4 = roofline(1e15, 1e12, 0.0, chips=4)
        assert t4.t_compute == pytest.approx(t1.t_compute / 4)
        assert t4.t_memory == pytest.approx(t1.t_memory / 4)

    def test_roofline_fraction_compute_bound_is_one(self):
        t = roofline(1e18, 1.0, 0.0)
        assert t.roofline_fraction == pytest.approx(1.0)

    def test_energy_decomposes_static_plus_dynamic(self):
        t = roofline(1e12, 1e9, 0.0, chips=3)
        static = CHIP_IDLE_W * t.t_step * 3
        assert energy_joules(t) > static        # dynamic part is positive
        assert energy_wh(t) == pytest.approx(energy_joules(t) / JOULES_PER_WH)


class TestCostModelMonotonicity:
    def test_decode_cost_monotone_in_kv_len(self):
        p = _params()
        flops = [decode_step_cost(p, kv)[0] for kv in (16, 64, 256, 1024)]
        bytes_ = [decode_step_cost(p, kv)[1] for kv in (16, 64, 256, 1024)]
        assert flops == sorted(flops) and len(set(flops)) == 4
        assert bytes_ == sorted(bytes_) and len(set(bytes_)) == 4

    def test_prefill_cost_monotone_in_seq_len(self):
        p = _params()
        flops = [prefill_cost(p, s)[0] for s in (16, 64, 256, 1024)]
        bytes_ = [prefill_cost(p, s)[1] for s in (16, 64, 256, 1024)]
        assert flops == sorted(flops) and len(set(flops)) == 4
        assert bytes_ == sorted(bytes_) and len(set(bytes_)) == 4

    def test_costs_scale_with_batch(self):
        p = _params()
        f1, b1 = decode_step_cost(p, 128, batch=1)
        f4, b4 = decode_step_cost(p, 128, batch=4)
        assert f4 == pytest.approx(4 * f1)
        # weights are read once regardless of batch; only KV scales
        assert b1 < b4 < 4 * b1

    def test_moe_active_params_cut_decode_flops(self):
        dense = _params(n=14e9)
        moe = _params(n=14e9, active=2.7e9)
        assert decode_step_cost(moe, 128)[0] < decode_step_cost(dense, 128)[0]


class TestEnergyMonitor:
    def test_accumulates_totals_and_counts(self):
        mon = EnergyMonitor()
        p = _params()
        wh1 = mon.measure_query(p, input_tokens=128, output_tokens=32)
        wh2 = mon.measure_query(p, input_tokens=64, output_tokens=8)
        assert wh1 > 0 and wh2 > 0
        assert mon.n_queries == 2
        assert mon.total_wh == pytest.approx(wh1 + wh2)
        assert mon.total_joules == pytest.approx((wh1 + wh2) * JOULES_PER_WH)

    def test_longer_outputs_cost_more(self):
        p = _params()
        short = EnergyMonitor().measure_query(p, 128, 8)
        long = EnergyMonitor().measure_query(p, 128, 256)
        assert long > short

    def test_bigger_models_cost_more(self):
        small = EnergyMonitor().measure_query(_params(n=1e9), 128, 32)
        big = EnergyMonitor().measure_query(_params(n=30e9), 128, 32)
        assert big > small

    def test_zero_output_query_still_pays_prefill(self):
        mon = EnergyMonitor()
        wh = mon.measure_query(_params(), input_tokens=256, output_tokens=0)
        assert wh > 0
        assert mon.n_queries == 1
