"""Host-numpy vs device (Pallas) featurization parity — the documented
guarantee behind ``RouterConfig.featurize``: embeddings agree within
float32 tolerance; task labels, cluster assignments, context one-hots and
routing decisions agree exactly.  Includes ragged batches,
empty/whitespace-only and non-ASCII texts, plus the async-timing fix
(timestamps only after a device sync)."""
import time

import numpy as np
import pytest

import repro.core.context as context_mod
import repro.core.router as router_mod
from repro.core.context import ContextGenerator, OnlineKMeans
from repro.core.embedding import EmbeddingModel
from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import Feedback, ModelProfile, Query, RouterConfig

WEIRD_TEXTS = [
    "Answer the question.\nWhat is the boiling point of water?",
    " ",                                  # whitespace-only -> zero features
    "\t\n  \n",                           # ditto, multiline
    "héllo wörld — naïve café über straße",   # non-ASCII, accents
    "数学の問題を解いてください。17個のりんご",        # CJK (tokenizer drops it)
    "a",                                  # single short token
    "Solve step by step.\n17 apples shared among 4 children leaves",
    "answer ANSWER Answer aNsWeR",        # case folding
    "x" * 500,                            # one long token
    "the the the the the the",            # heavy duplicate features
]


def _pool(n=4):
    return ModelPool([ModelProfile(name=f"m{i}", family="t",
                                   params_b=float(i + 1),
                                   ms_per_token=float(i + 1),
                                   prefill_ms=10.0)
                      for i in range(n)])


def _router(featurize, n=4, **kw):
    cfg = RouterConfig(max_arms=16, featurize=featurize, **kw)
    return GreenServRouter(cfg, _pool(n))


def _warm(router, n=8):
    for i in range(n):
        q = Query(uid=50_000 + i, text=f"Summarize the following.\nDoc {i} "
                                       f"on topic {i % 3} with detail words")
        d = router.route(q)
        router.feedback(Feedback(
            query_uid=q.uid, model_index=d.model_index,
            accuracy=0.3 + 0.2 * (d.model_index % 3),
            energy_wh=0.01 * (d.model_index + 1), latency_ms=5.0))


def _queries(texts, uid0=0):
    return [Query(uid=uid0 + i, text=t) for i, t in enumerate(texts)
            if t.strip() or True]         # Query allows whitespace-only


# ---------------------------------------------------------------------------
# Embedding parity
# ---------------------------------------------------------------------------


def test_embeddings_host_vs_device_tolerance():
    em = EmbeddingModel()
    host = em.encode_batch(WEIRD_TEXTS)
    dev = em.encode_batch_device(WEIRD_TEXTS)
    np.testing.assert_allclose(dev, host, atol=1e-5)
    # featureless rows are exactly zero on both paths
    assert np.all(host[1] == 0.0) and np.all(dev[1] == 0.0)
    assert np.all(host[2] == 0.0) and np.all(dev[2] == 0.0)


def test_embeddings_unit_norm_or_zero():
    em = EmbeddingModel()
    dev = em.encode_batch_device(WEIRD_TEXTS)
    norms = np.linalg.norm(dev, axis=1)
    for n in norms:
        assert n == pytest.approx(1.0, abs=1e-5) or n == 0.0


def test_hashed_features_padding_and_memo():
    em = EmbeddingModel()
    ids, w = em.hashed_features(["alpha beta", "", "alpha"])
    assert ids.shape == w.shape and ids.shape[0] == 3
    assert np.all(ids[1] == -1) and np.all(w[1] == 0.0)   # empty row
    assert np.all(w[ids < 0] == 0.0)                      # padding weight 0
    # memoized rehash is identical
    ids2, w2 = em.hashed_features(["alpha beta", "", "alpha"])
    np.testing.assert_array_equal(ids, ids2)
    np.testing.assert_array_equal(w, w2)


def test_empty_batch():
    em = EmbeddingModel()
    assert em.encode_batch_device([]).shape == (0, em.dim)


# ---------------------------------------------------------------------------
# k-means scan parity
# ---------------------------------------------------------------------------


def test_kmeans_scan_matches_sequential_updates():
    rng = np.random.default_rng(0)
    embs = rng.standard_normal((40, 16)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=1, keepdims=True)
    embs[7] = embs[0]                      # exact duplicate (seed dedup)
    embs[11] = 0.0                         # zero embedding
    km_host, km_dev = OnlineKMeans(3, 16), OnlineKMeans(3, 16)
    host = [km_host.update(e) for e in embs]
    dev = km_dev.update_batch_device(embs).tolist()
    assert host == dev
    assert km_host._initialized == km_dev._initialized
    np.testing.assert_array_equal(km_host.counts, km_dev.counts)
    np.testing.assert_allclose(km_host.centroids, km_dev.centroids,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# Full routing parity (the acceptance guarantee)
# ---------------------------------------------------------------------------


def test_route_batch_host_vs_device_decisions_exact():
    r_host, r_dev = _router("host"), _router("device")
    _warm(r_host), _warm(r_dev)
    qs = _queries([t for t in WEIRD_TEXTS])
    d_host = r_host.route_batch(qs)
    d_dev = r_dev.route_batch(qs)
    assert [d.model_index for d in d_host] == [d.model_index for d in d_dev]
    for a, b in zip(d_host, d_dev):
        assert a.context.task_label == b.context.task_label
        assert a.context.cluster == b.context.cluster
        assert a.context.complexity_bin == b.context.complexity_bin
        np.testing.assert_array_equal(a.context.vector, b.context.vector)
        np.testing.assert_allclose(a.ucb_scores, b.ucb_scores, atol=1e-4)
    # the sequential Eq. 10 state evolved identically
    np.testing.assert_array_equal(r_host.context.kmeans.counts,
                                  r_dev.context.kmeans.counts)
    assert (r_host.context.kmeans._initialized
            == r_dev.context.kmeans._initialized)


def test_route_batch_device_matches_sequential_device():
    r_seq, r_bat = _router("device"), _router("device")
    _warm(r_seq), _warm(r_bat)
    qs = _queries(WEIRD_TEXTS, uid0=100)
    seq = [r_seq.route(q) for q in qs]
    bat = r_bat.route_batch(qs)
    assert [d.model_index for d in seq] == [d.model_index for d in bat]
    for a, b in zip(seq, bat):
        np.testing.assert_array_equal(a.context.vector, b.context.vector)


@pytest.mark.parametrize("sizes", [(1,), (3, 1, 5), (2, 7)])
def test_ragged_batches_agree_with_one_big_batch(sizes):
    """Splitting a stream into ragged admission batches must not change
    decisions (each split replays the same sequential state)."""
    texts = [WEIRD_TEXTS[i % len(WEIRD_TEXTS)] + f" v{i}"
             for i in range(sum(sizes))]
    r_one, r_rag = _router("device"), _router("device")
    _warm(r_one), _warm(r_rag)
    qs = _queries(texts, uid0=300)
    one = r_one.route_batch(qs)
    ragged = []
    i = 0
    for s in sizes:
        ragged.extend(r_rag.route_batch(qs[i:i + s]))
        i += s
    assert [d.model_index for d in one] == [d.model_index for d in ragged]


def test_device_respects_feasibility():
    r = _router("device")
    qs = [Query(uid=i, text=f"short question {i}", max_new_tokens=50,
                latency_budget_ms=70.0) for i in range(4)]
    for d in r.route_batch(qs):
        assert d.model_name == "m0"        # only m0 meets the budget


def test_breaker_mask_and_blocked_parity_host_vs_device():
    """The reliability layer's arm-health mask (breaker-OPEN arms) and
    per-row ``blocked`` vetoes ride the feasibility matrix, so masked
    routing must stay decision-identical across scoring backends."""
    r_host, r_dev = _router("host"), _router("device")
    _warm(r_host), _warm(r_dev)
    health = np.array([True, False, True, True])     # arm 1 breaker-OPEN
    r_host.set_arm_health(lambda: health)
    r_dev.set_arm_health(lambda: health)
    qs = _queries(WEIRD_TEXTS, uid0=300)
    blocked = np.zeros((len(qs), 4), bool)
    blocked[::2, 2] = True                 # retries vetoing their failed arm
    d_host = r_host.route_batch(_queries(WEIRD_TEXTS, uid0=300),
                                blocked=blocked)
    d_dev = r_dev.route_batch(qs, blocked=blocked)
    assert [d.model_index for d in d_host] == [d.model_index for d in d_dev]
    assert all(d.model_index != 1 for d in d_host)   # mask actually bites
    for i, d in enumerate(d_host):
        if i % 2 == 0:
            assert d.model_index != 2
    # all arms of a row vetoed -> fall back to the unmasked feasibility
    # row (serving must answer), identically on both backends
    all_blocked = np.ones((2, 4), bool)
    f_host = r_host.route_batch(_queries(WEIRD_TEXTS[:2], uid0=340),
                                blocked=all_blocked)
    f_dev = r_dev.route_batch(_queries(WEIRD_TEXTS[:2], uid0=340),
                              blocked=all_blocked)
    assert [d.model_index for d in f_host] == [d.model_index for d in f_dev]


def test_device_forwarded_features_match_recompute():
    """Forwarding probe embeddings/labels into route_batch is identical to
    recomputing them (the scheduler's cache-probe reuse)."""
    r_a, r_b = _router("device"), _router("device")
    _warm(r_a), _warm(r_b)
    texts = [t + " fwd" for t in WEIRD_TEXTS[:6]]
    qs_a = _queries(texts, uid0=400)
    qs_b = _queries(texts, uid0=400)
    labels, clusters, embs = r_a.context.probe_batch(texts)
    d_a = r_a.route_batch(qs_a, embeddings=embs, task_labels=labels)
    d_b = r_b.route_batch(qs_b)
    assert [d.model_index for d in d_a] == [d.model_index for d in d_b]
    for a, b in zip(d_a, d_b):
        np.testing.assert_array_equal(a.context.vector, b.context.vector)


def test_probe_batch_host_vs_device_and_read_only():
    gen_h = ContextGenerator(RouterConfig(featurize="host"))
    gen_d = ContextGenerator(RouterConfig(featurize="device"))
    # seed identical k-means state through identical updates
    warm = [f"Summarize the following.\nDoc {i} topic {i % 3}"
            for i in range(6)]
    gen_h.batch(warm)
    gen_d.batch(warm)             # device toggle does not change .batch()
    state_before = gen_d.kmeans.state_dict()
    lh, ch, eh = gen_h.probe_batch(WEIRD_TEXTS)
    ld, cd, ed = gen_d.probe_batch(WEIRD_TEXTS)
    np.testing.assert_array_equal(lh, ld)
    np.testing.assert_array_equal(ch, cd)
    np.testing.assert_allclose(ed, eh, atol=1e-5)
    # read-only: no k-means mutation, no classifier mutation
    after = gen_d.kmeans.state_dict()
    np.testing.assert_array_equal(state_before["centroids"],
                                  after["centroids"])
    assert state_before["initialized"] == after["initialized"]


def test_feature_toggle_ablation_parity():
    for feats in [(False, True, True), (True, False, True),
                  (True, True, False), (False, False, False)]:
        r_h, r_d = _router("host"), _router("device")
        r_h.context.set_features(*feats)
        r_d.context.set_features(*feats)
        qs = _queries(WEIRD_TEXTS[:5], uid0=500)
        d_h = r_h.route_batch(_queries(WEIRD_TEXTS[:5], uid0=500))
        d_d = r_d.route_batch(qs)
        assert ([d.model_index for d in d_h]
                == [d.model_index for d in d_d]), feats
        for a, b in zip(d_h, d_d):
            np.testing.assert_array_equal(a.context.vector, b.context.vector)


def test_stochastic_policy_falls_back_to_host():
    r = _router("device", algorithm="cts")
    assert not r._device_featurize_active()
    d = r.route_batch(_queries(WEIRD_TEXTS[:3]))
    assert len(d) == 3                    # host fallback still routes


# ---------------------------------------------------------------------------
# Serving integration: device router behind the PoolServer
# ---------------------------------------------------------------------------


def test_pool_server_serves_on_device_path():
    from repro.cache import GreenCache
    from repro.serving import PoolServer, SimEngine

    profiles = [ModelProfile(name=f"sim{i}", family="s", params_b=i + 1.0)
                for i in range(3)]
    pool = ModelPool(profiles)
    engines = {p.name: SimEngine(p, lambda q, m: (0.5, 0.01, 10.0, 4))
               for p in profiles}
    router = GreenServRouter(
        RouterConfig(max_arms=16, featurize="device"), pool)
    # cluster_guard off: routing's Eq. 10 updates move centroids between
    # the insert-time and repeat-time probes, which is the guard's job to
    # notice — this test is about the device probe/short-circuit mechanics
    cache = GreenCache(mode="semantic", semantic_threshold=0.99,
                       cluster_guard=False)
    server = PoolServer(router, engines, cache=cache)
    texts = [t for t in WEIRD_TEXTS if t.strip()][:6]
    qs = [Query(uid=i, text=t, max_new_tokens=4)
          for i, t in enumerate(texts)]
    server.submit_batch(qs)
    server.run_until_drained()
    assert len(server.responses) == len(qs)
    assert int(router.policy.state.t) == len(qs)      # loop closed
    # an exact repeat now hits the semantic cache: no extra routing
    routed = router.n_routed
    rep = [Query(uid=100 + i, text=t, max_new_tokens=4)
           for i, t in enumerate(texts[:2])]
    reqs = server.submit_batch(rep)
    assert all(r.done for r in reqs)
    assert router.n_routed == routed


# ---------------------------------------------------------------------------
# Async-timing fix: timestamps only after a device sync
# ---------------------------------------------------------------------------


def test_decision_clock_syncs_device_work(monkeypatch):
    synced = []
    real = router_mod._sync
    monkeypatch.setattr(router_mod, "_sync",
                        lambda x: (synced.append(True), real(x))[1])
    r = _router("device")
    t0 = time.perf_counter()
    r.route_batch(_queries(WEIRD_TEXTS[:4], uid0=600))
    wall_ms = (time.perf_counter() - t0) * 1e3
    assert synced, "decision timestamp taken without block_until_ready"
    # the reported decision window is inside the measured wall window
    assert 0.0 < r.decision_ms_total <= wall_ms * 1.05


def test_context_clock_syncs_stage_boundaries(monkeypatch):
    synced = []
    real = context_mod._sync
    monkeypatch.setattr(context_mod, "_sync",
                        lambda x: (synced.append(True), real(x))[1])
    gen = ContextGenerator(RouterConfig(featurize="host"))
    t0 = time.perf_counter()
    gen.batch(["Answer this.\nWhat?", "Summarize that.\nLong doc."])
    wall_ms = (time.perf_counter() - t0) * 1e3
    assert len(synced) >= 2, "stage timestamps taken without sync"
    stage_sum = sum(v for k, v in gen.timings_ms.items() if k != "n")
    assert 0.0 < stage_sum <= wall_ms * 1.05


def test_device_path_populates_featurize_timing():
    r = _router("device")
    r.route_batch(_queries(WEIRD_TEXTS[:4], uid0=700))
    tm = r.context.timings_ms
    assert tm["n"] == 4
    assert tm["featurize"] >= 0.0 and tm["complexity"] > 0.0
    assert r.mean_decision_ms > 0.0


# ---------------------------------------------------------------------------
# Hypothesis sweep (dev-only dependency, mirrors test_property.py — but the
# deterministic suite above must run even where hypothesis is absent, so
# only this block is conditional rather than importorskip'ing the module)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                      # dev-only (requirements-dev.txt)
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    # default alphabet: all codepoints minus surrogates — covers ASCII,
    # accents, CJK, emoji, control chars (the tokenizer drops most)
    _TEXT = st.text(min_size=0, max_size=80)

    @given(texts=st.lists(_TEXT, min_size=1, max_size=6))
    @settings(max_examples=15, deadline=None)
    def test_hypothesis_embedding_parity(texts):
        em = EmbeddingModel()
        np.testing.assert_allclose(em.encode_batch_device(texts),
                                   em.encode_batch(texts), atol=1e-5)

    @given(data=st.data())
    @settings(max_examples=10, deadline=None)
    def test_hypothesis_routing_parity(data):
        """Arbitrary ragged unicode streams: labels, clusters, one-hots
        and arm choices identical between the host and device routers."""
        batches = data.draw(st.lists(
            st.lists(_TEXT.filter(lambda t: len(t) > 0), min_size=1,
                     max_size=4),
            min_size=1, max_size=3))
        r_h, r_d = _router("host"), _router("device")
        uid = 0
        for batch in batches:
            qs_h = [Query(uid=uid + i, text=t) for i, t in enumerate(batch)]
            qs_d = [Query(uid=uid + i, text=t) for i, t in enumerate(batch)]
            uid += len(batch)
            d_h = r_h.route_batch(qs_h)
            d_d = r_d.route_batch(qs_d)
            assert ([d.model_index for d in d_h]
                    == [d.model_index for d in d_d])
            for a, b in zip(d_h, d_d):
                assert a.context.task_label == b.context.task_label
                assert a.context.cluster == b.context.cluster
                np.testing.assert_array_equal(a.context.vector,
                                              b.context.vector)
