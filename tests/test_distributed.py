"""Checkpointing, elastic re-meshing, fault detection."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import checkpoint as ckpt
from repro.distributed.elastic import plan_remesh
from repro.distributed.fault import HeartbeatMonitor, StragglerDetector
from repro.launch.mesh import make_host_mesh


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"layer": {"w": jnp.asarray(rng.standard_normal((4, 8)),
                                       jnp.float32),
                      "b": jnp.asarray(rng.standard_normal(8), jnp.bfloat16)},
            "step_count": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 10, t, extra={"step": 10, "note": "hi"})
    like = jax.tree.map(jnp.zeros_like, t)
    restored, extra = ckpt.restore(tmp_path, like)
    assert extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_pointer_and_prune(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        ckpt.save(tmp_path, s, t)
    assert ckpt.latest_step(tmp_path) == 4
    ckpt.prune(tmp_path, keep=2)
    dirs = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert len(dirs) == 2
    assert ckpt.latest_step(tmp_path) == 4


def test_interrupted_write_never_corrupts(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    # simulate a crash mid-write of step 2: stray tmp dir with partial data
    tmp = pathlib.Path(tmp_path) / "step_000000002.tmp"
    tmp.mkdir()
    (tmp / "leaf_00000.npy").write_bytes(b"garbage")
    assert ckpt.latest_step(tmp_path) == 1          # LATEST untouched
    restored, _ = ckpt.restore(tmp_path, jax.tree.map(jnp.zeros_like, t))
    np.testing.assert_array_equal(np.asarray(restored["layer"]["w"]),
                                  np.asarray(t["layer"]["w"]))


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt.save(tmp_path, 1, {"w": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        ckpt.restore(tmp_path, {"w": jnp.zeros((3, 3))})


def test_remesh_plan_single_pod():
    mesh = make_host_mesh()  # (1, 1) — use shapes math on a synthetic mesh
    from repro.launch.mesh import make_mesh
    # pretend 16x16 pod lost 18 chips → data shrinks 16→14
    import jax as _jax
    if len(_jax.devices()) == 1:
        # shape math only (can't build a 256-device mesh here)
        from repro.distributed.elastic import RemeshPlan
        plan = RemeshPlan(old_shape=(16, 16), new_shape=(14, 16),
                          axes=("data", "model"), lost_chips=18,
                          batch_policy="hold", n_micro_multiplier=2)
        assert plan.new_data_parallel == 14
    m = make_mesh((1, 1), ("data", "model"))
    plan = plan_remesh(m, 0)
    assert plan.new_shape == (1, 1)


def test_remesh_plan_math_8dev_subprocess():
    import subprocess, sys, os, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        from repro.launch.mesh import make_mesh
        from repro.distributed.elastic import plan_remesh, build_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        plan = plan_remesh(mesh, lost_chips=2, batch_policy="hold")
        assert plan.new_shape == (3, 2), plan
        assert plan.n_micro_multiplier == 2, plan
        m2 = build_mesh(plan)
        assert m2.devices.size == 6
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       capture_output=True, text=True, timeout=300)
    assert "OK" in r.stdout, r.stderr[-2000:]


def test_heartbeat_monitor():
    mon = HeartbeatMonitor(timeout_s=0.0)
    mon.register("w0")
    assert mon.stale() == ["w0"]
    mon2 = HeartbeatMonitor(timeout_s=60.0)
    mon2.register("w1")
    assert mon2.stale() == []


def test_straggler_detector():
    det = StragglerDetector(k=2.0)
    for w in ("a", "b", "c", "d"):
        det.record(w, 1.0)
    det.record("d", 5.0)
    assert det.stragglers() == ["d"]
    # global slowdown: nobody flagged
    det2 = StragglerDetector(k=2.0)
    for w in ("a", "b", "c", "d"):
        det2.record(w, 10.0)
    assert det2.stragglers() == []


def test_router_state_checkpoints_with_same_machinery(tmp_path):
    from repro.core.pool import ModelPool
    from repro.core.router import GreenServRouter
    from repro.core.types import (Feedback, ModelProfile, Query,
                                  RouterConfig)
    pool = ModelPool([ModelProfile(name=f"m{i}", family="x", params_b=1.0)
                      for i in range(3)])
    r = GreenServRouter(RouterConfig(max_arms=8), pool)
    for uid in range(5):
        q = Query(uid=uid, text=f"Answer the question.\nQ{uid}?")
        d = r.route(q)
        r.feedback(Feedback(query_uid=uid, model_index=d.model_index,
                            accuracy=0.7, energy_wh=0.02, latency_ms=9.0))
    ckpt.save(tmp_path, 5, r.state_dict()["bandit"],
              extra={"n_routed": r.n_routed})
    like = jax.tree.map(np.zeros_like, r.state_dict()["bandit"])
    blob, extra = ckpt.restore(tmp_path, like)
    assert extra["n_routed"] == 5
    np.testing.assert_allclose(np.asarray(blob["counts"]),
                               r.state_dict()["bandit"]["counts"])


def test_restore_explicit_step(tmp_path):
    ckpt.save(tmp_path, 1, {"w": jnp.ones((2,))})
    ckpt.save(tmp_path, 2, {"w": jnp.full((2,), 9.0)})
    assert ckpt.latest_step(tmp_path) == 2
    old, _ = ckpt.restore(tmp_path, {"w": jnp.zeros((2,))}, step=1)
    np.testing.assert_array_equal(np.asarray(old["w"]), np.ones(2))
    new, _ = ckpt.restore(tmp_path, {"w": jnp.zeros((2,))})
    np.testing.assert_array_equal(np.asarray(new["w"]), np.full(2, 9.0))


def test_restore_missing_leaf_raises(tmp_path):
    ckpt.save(tmp_path, 1, {"w": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        ckpt.restore(tmp_path, {"w": jnp.zeros((2,)),
                                "extra": jnp.zeros((3,))})


def test_train_watchdog_restart_policy(tmp_path):
    from repro.distributed.fault import TrainWatchdog
    wd = TrainWatchdog(checkpoint_dir=str(tmp_path), max_restarts=2)
    # failure before the first checkpoint is unrecoverable
    with pytest.raises(RuntimeError):
        wd.on_failure()
    ckpt.save(tmp_path, 3, {"w": jnp.zeros((2,))})
    assert wd.should_restart()
    assert wd.on_failure() == 3            # restore target = newest step
    assert not wd.should_restart()         # budget (2) exhausted


def test_cost_model_state_checkpoints_with_same_machinery(tmp_path):
    """The predictive energy model rides distributed.checkpoint next to
    the router state (the fleet controller saves both)."""
    from repro.costmodel.model import EnergyCostModel
    cm = EnergyCostModel()
    for name in ("m0", "m1"):
        cm.register_engine(name)
    state = cm.state_dict()
    ckpt.save(tmp_path, 1, state)
    like = jax.tree.map(np.zeros_like, state)
    blob, _ = ckpt.restore(tmp_path, like)
    cm2 = EnergyCostModel()
    cm2.load_state_dict(blob)
    assert set(cm2.engines) == {"m0", "m1"}
    sd1, sd2 = cm.state_dict(), cm2.state_dict()
    for a, b in zip(jax.tree.leaves(sd1), jax.tree.leaves(sd2)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-6)


def test_remesh_model_axis_unsatisfiable_raises():
    from repro.launch.mesh import make_mesh
    m = make_mesh((1, 1), ("data", "model"))
    # losing the only chip leaves nothing to host the model axis
    with pytest.raises(ValueError):
        plan_remesh(m, lost_chips=1)
