"""Chunked-prefill equivalence: the chunk-step populates the decode cache
and produces next-token logits identical (within fp tolerance) to feeding
the same prompt one token at a time through ``serve_step``.

Covered: dense (granite), moe (qwen2), encdec (whisper, stub encoder
cross-KV); several chunk sizes including non-divisors of the prompt
length; ragged per-slot activity (one slot idle while another prefills);
the engine-level fallback for unsupported layouts; phase-split energy
metering.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.energy import (decode_step_cost, energy_joules,
                               prefill_chunk_cost, roofline)
from repro.core.types import Query
from repro.data import tokenizer as tok
from repro.models import api
from repro.serving import ModelEngine, Request

MAX_LEN = 48
PROMPT = list(range(3, 17))       # 14 tokens

RTOL = ATOL = 3e-4                # fp32 compute, batched-vs-stepped matmuls


def _cfg(arch):
    # fp32 compute so one-shot vs chunked matmul batching stays within a
    # tight tolerance; kv_update="where" matches the serving engine
    return get_config(arch, smoke=True, vocab_size=256, dtype="float32",
                      kv_update="where")


def _one_shot(params, cfg, prompts, batch):
    """Token-at-a-time reference: per-slot prompts fed through serve_step;
    returns (per-slot last-position logits, final cache)."""
    cache = api.init_cache(cfg, batch, MAX_LEN)
    last = [None] * batch
    for t in range(max(len(p) for p in prompts)):
        toks = np.zeros((batch, 1), np.int32)
        feed = np.zeros((batch,), bool)
        for b, p in enumerate(prompts):
            if t < len(p):
                toks[b, 0] = p[t]
                feed[b] = True
        logits, cache2 = api.serve_step(params, jnp.asarray(toks), cache, cfg)
        # keep per-slot raggedness: slots past their prompt keep the OLD
        # cache row (serve_step has no per-slot gating, so splice per leaf)
        sel = jnp.asarray(feed)
        cache = jax.tree.map(
            lambda new, old: _splice_leaf(new, old, sel, batch), cache2, cache)
        for b, p in enumerate(prompts):
            if t == len(p) - 1:
                last[b] = np.asarray(logits[b, 0])
    return last, cache


def _splice_leaf(new, old, sel, batch):
    """Keep slot b's updated leaf iff sel[b] (leaves are (B,) lengths or
    (L, B, ...) stacked caches)."""
    if new.shape[0] == batch and new.ndim == 1:          # length (B,)
        return jnp.where(sel, new, old)
    shape = [1] * new.ndim
    shape[1] = batch
    return jnp.where(sel.reshape(shape), new, old)


def _chunked(params, cfg, prompts, batch, chunk):
    """Chunked path: per-slot slabs of up to ``chunk`` tokens per step."""
    cache = api.init_cache(cfg, batch, MAX_LEN)
    fed = [0] * batch
    last = [None] * batch
    while any(fed[b] < len(p) for b, p in enumerate(prompts)):
        toks = np.zeros((batch, chunk), np.int32)
        n_active = np.zeros((batch,), np.int32)
        for b, p in enumerate(prompts):
            n = min(chunk, len(p) - fed[b])
            if n > 0:
                toks[b, :n] = p[fed[b]:fed[b] + n]
                n_active[b] = n
        logits, cache = api.prefill_chunk(params, jnp.asarray(toks), cache,
                                          cfg, jnp.asarray(n_active))
        for b, p in enumerate(prompts):
            n = int(n_active[b])
            if n and fed[b] + n == len(p):
                last[b] = np.asarray(logits[b, n - 1])
            fed[b] += n
    return last, cache


# MoE stops at chunk 8: expert capacity is computed per dispatch group
# (moe_block routes each row's chunk as one group), so a large chunk can
# overflow an expert and drop tokens the one-at-a-time path kept — an
# inherent property of capacity-bounded MoE, not of the chunked cache path
# (documented in docs/SERVING.md).
@pytest.mark.parametrize("arch,chunk", [
    ("granite-3-8b", 2), ("granite-3-8b", 5), ("granite-3-8b", 8),
    ("granite-3-8b", len(PROMPT)),
    ("qwen2-moe-a2.7b", 2), ("qwen2-moe-a2.7b", 5), ("qwen2-moe-a2.7b", 8),
])
def test_chunked_matches_one_shot(arch, chunk):
    cfg = _cfg(arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompts = [PROMPT]
    ref_logits, ref_cache = _one_shot(params, cfg, prompts, batch=1)
    got_logits, got_cache = _chunked(params, cfg, prompts, batch=1, chunk=chunk)
    np.testing.assert_allclose(got_logits[0], ref_logits[0],
                               rtol=RTOL, atol=ATOL)
    for leaf_name in ("k", "v"):
        np.testing.assert_allclose(np.asarray(got_cache[leaf_name]),
                                   np.asarray(ref_cache[leaf_name]),
                                   rtol=RTOL, atol=ATOL)
    np.testing.assert_array_equal(np.asarray(got_cache["length"]),
                                  np.asarray(ref_cache["length"]))


def test_chunked_ragged_batch_gates_idle_slots():
    """Slots at different offsets: a 14-token and a 5-token prompt share a
    batch; the short slot goes idle (n_active=0) mid-prefill and its cache
    must stay untouched."""
    cfg = _cfg("granite-3-8b")
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    prompts = [PROMPT, list(range(40, 45))]
    ref_logits, ref_cache = _one_shot(params, cfg, prompts, batch=2)
    got_logits, got_cache = _chunked(params, cfg, prompts, batch=2, chunk=4)
    for b in range(2):
        np.testing.assert_allclose(got_logits[b], ref_logits[b],
                                   rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(got_cache["k"]),
                               np.asarray(ref_cache["k"]),
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_array_equal(np.asarray(got_cache["length"]),
                                  np.asarray(ref_cache["length"]))


def test_chunked_matches_one_shot_encdec():
    """Whisper-style decoder: self-attention cache chunked, static cross-KV
    read per chunk (zeros here, as in the serving engine's stub frontend)."""
    cfg = _cfg("whisper-medium")
    params = api.init_params(cfg, jax.random.PRNGKey(2))
    prompts = [PROMPT]
    ref_logits, ref_cache = _one_shot(params, cfg, prompts, batch=1)
    got_logits, got_cache = _chunked(params, cfg, prompts, batch=1, chunk=5)
    np.testing.assert_allclose(got_logits[0], ref_logits[0],
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(got_cache["k"]),
                               np.asarray(ref_cache["k"]),
                               rtol=RTOL, atol=ATOL)


def test_supports_chunked_prefill_gating():
    assert api.supports_chunked_prefill(_cfg("granite-3-8b"))
    assert api.supports_chunked_prefill(_cfg("qwen2-moe-a2.7b"))
    assert api.supports_chunked_prefill(_cfg("whisper-medium"))
    assert not api.supports_chunked_prefill(_cfg("rwkv6-1.6b"))
    assert not api.supports_chunked_prefill(_cfg("zamba2-7b"))


def test_engine_clamps_chunk_for_recurrent_layouts():
    """An rwkv engine asked for chunking silently falls back to the
    one-token path (and still serves correctly)."""
    cfg = get_config("rwkv6-1.6b", smoke=True, vocab_size=tok.VOCAB_SIZE)
    eng = ModelEngine("rwkv6-1.6b", cfg, jax.random.PRNGKey(0), max_batch=2,
                      max_len=64, prefill_chunk=8)
    assert eng.prefill_chunk == 1
    req = Request(query=Query(uid=0, text="hello"),
                  prompt_tokens=tok.encode("hi")[:4], max_new_tokens=2)
    eng.submit(req)
    for _ in range(12):
        if eng.step():
            break
    assert len(req.generated) >= 2


def test_engine_chunked_generation_matches_tokenwise():
    """End-to-end engine equivalence: same prompt, chunk=1 vs chunk=4
    engines greedy-decode the same tokens (fp32 compute)."""
    def run(chunk):
        cfg = get_config("granite-3-8b", smoke=True,
                         vocab_size=tok.VOCAB_SIZE, dtype="float32")
        eng = ModelEngine("granite-3-8b", cfg, jax.random.PRNGKey(3),
                          max_batch=2, max_len=64, prefill_chunk=chunk)
        req = Request(query=Query(uid=0, text="equivalence probe"),
                      prompt_tokens=list(range(7, 21)), max_new_tokens=4)
        eng.submit(req)
        out = []
        for _ in range(40):
            out += eng.step()
            if out:
                break
        assert out
        return out[0].tokens, eng

    toks1, _ = run(1)
    toks4, eng4 = run(4)
    assert toks1 == toks4
    phases = eng4.cumulative_joules_by_phase()
    assert phases["prefill"] > 0 and phases["decode"] > 0
    assert phases["prefill"] + phases["decode"] == pytest.approx(
        eng4.cumulative_joules())


def test_moe_mixed_tick_padding_is_harmless():
    """A mixed chunk tick on an MoE engine is the worst padding case: a
    decode rider is 1 real row + chunk-1 padding rows in one dispatch
    group, plus a final partial prompt slab.  Padding must never evict a
    real token from expert capacity (stable dispatch sort + prefix-shaped
    ``active`` — invariant documented at moe._dispatch_group), so chunked
    and token-wise runs generate identical tokens."""
    def run(chunk):
        cfg = get_config("qwen2-moe-a2.7b", smoke=True, vocab_size=256,
                         dtype="float32", n_experts=4, top_k=2)
        eng = ModelEngine("qwen", cfg, jax.random.PRNGKey(5), max_batch=2,
                          max_len=64, prefill_chunk=chunk)
        first = Request(query=Query(uid=0, text="a"),
                        prompt_tokens=[5, 6, 7], max_new_tokens=12)
        eng.submit(first)
        while not first.generated:          # drive into decode
            eng.step()
        second = Request(query=Query(uid=1, text="b"),
                         prompt_tokens=list(range(7, 21)),  # slabs 8 + 6
                         max_new_tokens=4)
        eng.submit(second)
        done = []
        for _ in range(80):
            done += eng.step()
            if len(done) == 2:
                break
        assert len(done) == 2
        return {r.uid: r.tokens for r in done}

    assert run(1) == run(8)


def test_prefill_chunk_cost_amortizes_weight_reads():
    """The prefill-phase cost model: an n-token chunk step reads weights
    once, so its HBM bytes are far below n one-token decode steps — the
    energy face of the chunking win."""
    from repro.core.energy import CostModelParams
    cm = CostModelParams(n_params=1e9, n_active_params=1e9, d_model=1024,
                         n_layers=8, kv_heads=8, head_dim=128)
    f_chunk, b_chunk = prefill_chunk_cost(cm, 8, kv_len=0)
    f_tok, b_tok = decode_step_cost(cm, 4)
    assert b_chunk < 8 * b_tok / 4          # ≥4× fewer bytes than 8 decodes
    assert f_chunk > f_tok                  # but strictly more FLOPs
    e_chunk = energy_joules(roofline(f_chunk, b_chunk, 0.0))
    e_8tok = 8 * energy_joules(roofline(f_tok, b_tok, 0.0))
    assert e_chunk < e_8tok                 # chunked prefill is cheaper
