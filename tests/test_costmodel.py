"""Predictive energy cost model (repro.costmodel): analytic prior vs
engine accounting, RLS calibration, checkpoint roundtrip, the router's
predicted-cost tilt, governor predict-then-reconcile (property-style),
and the energy-aware admission planner."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import ModelProfile, Query, RouterConfig
from repro.costmodel import EnergyCostModel
from repro.costmodel.model import EngineCostModel
from repro.data import tokenizer as tok
from repro.serving import ModelEngine, PoolServer, SimEngine
from repro.telemetry.budget import EnergyBudgetGovernor
from repro.telemetry.hub import Telemetry

pytestmark = pytest.mark.costmodel

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _real_engine(name="rwkv6-1.6b", max_batch=3, max_len=96, seed=0,
                 prefill_chunk=4):
    cfg = get_config(name, smoke=True, vocab_size=tok.VOCAB_SIZE)
    return ModelEngine(name, cfg, jax.random.PRNGKey(seed),
                       max_batch=max_batch, max_len=max_len,
                       prefill_chunk=prefill_chunk, detokenize=tok.decode)


# -- analytic prior mirrors the engine's accounting of record ---------------


def test_analytic_prior_matches_metered_unified():
    """For a unified engine with no reuse, the prior evaluated at the
    *actual* (n_prompt, n_out) must reproduce the metered Wh exactly —
    the residual then only has to learn the decode-length expectation."""
    eng = _real_engine()
    pool = ModelPool([eng.profile])
    router = GreenServRouter(RouterConfig(max_arms=4), pool)
    cm = EnergyCostModel()
    server = PoolServer(router, {eng.profile.name: eng}, tokenizer=tok.encode,
                        prefill_chunk=4, cost_model=cm)
    qs = [Query(uid=i, text=f"measure prompt number {i} with some words",
                max_new_tokens=3 + i % 4) for i in range(5)]
    server.enqueue_many(qs)
    server.run_until_drained(max_steps=5000)
    m = cm.engines[eng.profile.name]
    assert m.split_phases          # real engines expose a shape model
    for resp in server.responses.values():
        a_pre, a_dec = m.analytic_split_wh(resp.input_tokens,
                                           resp.output_tokens)
        assert resp.energy_wh == pytest.approx(a_pre + a_dec, rel=1e-9)


def test_cost_model_reconciles_every_completion():
    eng = _real_engine()
    pool = ModelPool([eng.profile])
    router = GreenServRouter(RouterConfig(max_arms=4), pool)
    cm = EnergyCostModel()
    server = PoolServer(router, {eng.profile.name: eng}, tokenizer=tok.encode,
                        cost_model=cm)
    qs = [Query(uid=i, text=f"query {i}", max_new_tokens=4)
          for i in range(6)]
    server.enqueue_many(qs)
    server.run_until_drained(max_steps=5000)
    assert cm.n_predicted == cm.n_reconciled == len(qs)
    assert cm.inflight_predicted == 0
    # the prior is exact in shape; the error budget is entirely the cold
    # decode-length expectation (an immediate-EOS query predicts a full
    # generation), so the cold bound is loose — the calibrated <10% gate
    # lives in bench_energy_model --smoke
    assert cm.mae_ratio() < 0.5


# -- RLS residual calibration ----------------------------------------------


def test_rls_residual_learns_linear_ledger():
    """A shape-model-free engine (single bucket) must fit a linear Wh
    ledger from observations alone."""
    m = EngineCostModel("sim", cost_params=None)
    rng = np.random.default_rng(0)
    base, slope = 4e-3, 2e-5

    def wh_of(tokens):
        return base + slope * tokens

    for _ in range(200):
        n_p = int(rng.integers(8, 64))
        n_out = int(rng.integers(2, 12))
        m.observe(n_prompt=n_p, n_out=n_out, max_new_tokens=n_out,
                  reused=0, migrated=False, occupancy=0.0,
                  measured_wh=wh_of(n_p + n_out))
    for n_p, n_out in [(16, 4), (40, 8), (60, 10)]:
        pred = m.predict_wh(n_p, n_out)
        assert pred == pytest.approx(wh_of(n_p + n_out), rel=0.05)


def test_out_ratio_tracks_early_stopping():
    m = EngineCostModel("sim", cost_params=None)
    for _ in range(60):
        m.observe(n_prompt=10, n_out=5, max_new_tokens=10, reused=0,
                  migrated=False, occupancy=0.0, measured_wh=1e-3)
    # generations consistently stop at half the budget
    assert m.out_ratio == pytest.approx(0.5, abs=0.05)
    assert m.expected_out(10) == pytest.approx(5, abs=1)


def test_discount_wh_positive_only_with_reuse():
    # needs an attention arch: chunked prefill is what makes resuming at
    # a prefix offset cheaper than a cold pass (recurrent engines clamp
    # the chunk to 1 and legitimately forecast no saving)
    cfg = get_config("granite-3-8b", smoke=True, vocab_size=tok.VOCAB_SIZE,
                     dtype="float32", max_seq_len=96)
    eng = ModelEngine("granite-3-8b", cfg, jax.random.PRNGKey(0),
                      max_batch=2, max_len=96, prefill_chunk=8)
    cm = EnergyCostModel()
    cm.register_engine(eng.profile.name, eng)
    name = eng.profile.name
    assert cm.engines[name].prefill_chunk == 8
    assert cm.discount_wh(name, 32, 4, reused=0) == 0.0
    # deep reuse (one remaining slab) is forecast to save energy; shallow
    # reuse on a tiny smoke model may cost more than a cold pass (per-slab
    # weight re-reads) and must then clamp to a zero discount, never a
    # negative one
    assert cm.discount_wh(name, 32, 4, reused=24) > 0.0
    assert cm.discount_wh(name, 32, 4, reused=8) >= 0.0


# -- checkpoint roundtrip ---------------------------------------------------


def test_cost_model_state_roundtrip():
    src = EnergyCostModel()
    m = src.register_engine("sim")
    rng = np.random.default_rng(1)
    for _ in range(50):
        n = int(rng.integers(8, 64))
        m.observe(n_prompt=n, n_out=6, max_new_tokens=8, reused=0,
                  migrated=False, occupancy=0.0, measured_wh=1e-4 * n)
    dst = EnergyCostModel()
    dst.load_state_dict(src.state_dict())
    for n_p in (10, 30, 50):
        assert dst.predict_wh("sim", n_p, 8) == pytest.approx(
            src.predict_wh("sim", n_p, 8), rel=1e-12)
    assert dst.engines["sim"].out_ratio == pytest.approx(m.out_ratio)
    assert dst.engines["sim"].n_obs == m.n_obs


# -- router predicted-cost tilt --------------------------------------------


def _tilt_router(seed=0):
    profiles = [ModelProfile(name=f"sim{i}", family="s", params_b=i + 1.0)
                for i in range(3)]
    pool = ModelPool(profiles)
    return GreenServRouter(
        RouterConfig(lam=0.5, energy_scale_wh=0.01, max_arms=8, seed=seed),
        pool)


def test_uniform_cost_matrix_never_perturbs_decisions():
    """Per-arm-constant predictions carry no shape information — the
    self-centred tilt must leave every decision exactly as without the
    cost model."""
    qs = [Query(uid=i, text=f"tilt query {i}") for i in range(12)]
    base = [d.model_index for d in _tilt_router().route_batch(qs)]
    costs = np.tile([0.001, 0.005, 0.02], (len(qs), 1))
    tilted = [d.model_index
              for d in _tilt_router().route_batch(qs, energy_costs_wh=costs)]
    assert tilted == base


def test_shaped_cost_matrix_steers_away_from_expensive_arm():
    qs = [Query(uid=i, text=f"steer query {i}") for i in range(8)]
    base = [d.model_index for d in _tilt_router().route_batch(qs)]
    costs = np.full((len(qs), 3), 0.001)
    costs[0, base[0]] = 5.0        # query 0: its chosen arm forecast huge
    tilted = [d.model_index
              for d in _tilt_router().route_batch(qs, energy_costs_wh=costs)]
    assert tilted[0] != base[0]


def test_router_tilt_baseline_checkpoints():
    r = _tilt_router()
    qs = [Query(uid=i, text=f"ckpt query {i}") for i in range(6)]
    costs = np.abs(np.random.default_rng(2).normal(0.01, 0.004, (6, 3)))
    r.route_batch(qs, energy_costs_wh=costs)
    r2 = _tilt_router()
    r2.load_state_dict(r.state_dict())
    np.testing.assert_allclose(r2._pred_cost_mean, r._pred_cost_mean)
    np.testing.assert_array_equal(r2._pred_cost_seen, r._pred_cost_seen)


# -- governor predict-then-reconcile (property-style) -----------------------
#
# Trace interpreter: a sequence of (op, uid, wh) steps runs against both
# the governor and a plain-dict reference model; the invariants below
# must hold after every step:
#   * inflight_predicted_wh == sum of outstanding per-uid predictions
#     (never negative, re-admission replaces rather than double-charges);
#   * prediction_error counts exactly the completions whose uid held a
#     live prediction at completion time (cancel → complete never
#     reconciles, complete → complete reconciles once);
#   * once every admitted uid has completed or cancelled, the in-flight
#     predicted charge is released to exactly zero.


def _run_trace(ops):
    gov = EnergyBudgetGovernor(100.0, horizon_queries=1000)
    ref = {}
    reconciled = 0
    for op, uid, wh in ops:
        if op == "admit":
            gov.on_admission(1, predicted=[(uid, wh)])
            ref[uid] = max(wh, 0.0)
        elif op == "complete":
            if uid in ref:
                reconciled += 1
                ref.pop(uid)
            gov.on_completion(wh, uid=uid)
        elif op == "cancel":
            ref.pop(uid, None)
            gov.on_cancel(uid)
        assert gov.inflight_predicted_wh >= -1e-12
        assert gov.inflight_predicted_wh == pytest.approx(
            sum(ref.values()), abs=1e-9)
        assert set(gov.inflight_pred) == set(ref)
        assert gov.prediction_error["n"] == reconciled
    # drain: everything still outstanding completes exactly once
    for uid in list(ref):
        gov.on_completion(1e-3, uid=uid)
        reconciled += 1
    assert gov.inflight_predicted_wh == pytest.approx(0.0, abs=1e-9)
    assert not gov.inflight_pred
    assert gov.prediction_error["n"] == reconciled


def _random_ops(rng, n_steps=40, n_uids=8):
    ops = []
    for _ in range(n_steps):
        op = rng.choice(["admit", "admit", "complete", "cancel"])
        uid = int(rng.integers(0, n_uids))
        wh = float(rng.uniform(0.0, 0.01))
        ops.append((op, uid, wh))
    return ops


def test_governor_predict_reconcile_seeded_traces():
    for seed in range(40):
        _run_trace(_random_ops(np.random.default_rng(seed)))


if HAVE_HYPOTHESIS:
    @settings(max_examples=100, deadline=None)
    @given(st.lists(st.tuples(
        st.sampled_from(["admit", "complete", "cancel"]),
        st.integers(min_value=0, max_value=7),
        st.floats(min_value=0.0, max_value=0.05,
                  allow_nan=False, allow_infinity=False)),
        max_size=60))
    def test_governor_predict_reconcile_hypothesis(ops):
        _run_trace(ops)


def test_governor_readmission_replaces_charge():
    """A restart re-route re-admits the same uid — the prior charge must
    be replaced, not stacked."""
    gov = EnergyBudgetGovernor(100.0, horizon_queries=1000)
    gov.on_admission(1, predicted=[(7, 0.004)])
    gov.on_admission(1, predicted=[(7, 0.009)])
    assert gov.inflight_predicted_wh == pytest.approx(0.009)
    gov.on_completion(0.008, uid=7)
    assert gov.inflight_predicted_wh == pytest.approx(0.0)
    assert gov.prediction_error["n"] == 1


def test_governor_headroom_shrinks_with_inflight_predictions():
    gov = EnergyBudgetGovernor(10.0, horizon_queries=100, burst_frac=0.1)
    h0 = gov.admission_headroom_wh()
    gov.on_admission(1, predicted=[(1, 0.5)])
    assert gov.admission_headroom_wh() == pytest.approx(h0 - 0.5)
    gov.on_cancel(1)
    assert gov.admission_headroom_wh() == pytest.approx(h0)


# -- admission planner ------------------------------------------------------


def _planner_server(budget_wh, admission_planner=True, n_queries=12,
                    wh_per_query=0.01):
    profiles = [ModelProfile(name=f"sim{i}", family="s", params_b=i + 1.0)
                for i in range(2)]
    pool = ModelPool(profiles)
    router = GreenServRouter(RouterConfig(lam=0.4, max_arms=8), pool)
    engines = {p.name: SimEngine(
        p, lambda q, m: (0.9, wh_per_query, 5.0, 4)) for p in profiles}
    gov = EnergyBudgetGovernor(budget_wh, horizon_queries=n_queries)
    cm = EnergyCostModel()
    # seed the single-bucket residuals so forecasts are non-trivial
    for p in profiles:
        eng_m = cm.register_engine(p.name)
        for _ in range(30):
            eng_m.observe(n_prompt=8, n_out=4, max_new_tokens=4, reused=0,
                          migrated=False, occupancy=0.0,
                          measured_wh=wh_per_query)
    server = PoolServer(router, engines, telemetry=Telemetry(governor=gov),
                        cost_model=cm, admission_planner=admission_planner)
    qs = [Query(uid=i, text=f"plan query {i}", max_new_tokens=4)
          for i in range(n_queries)]
    server.enqueue_many(qs)
    return server, n_queries


def test_planner_defers_under_tight_budget_but_never_stalls():
    server, n = _planner_server(budget_wh=0.02)   # ~2 queries of headroom
    server.run_until_drained()
    assert len(server.responses) == n             # head-of-line liveness
    assert server.stats["deferred"] > 0


def test_planner_admits_freely_with_headroom():
    server, n = _planner_server(budget_wh=10.0)
    server.run_until_drained()
    assert len(server.responses) == n
    assert server.stats["deferred"] == 0


def test_planner_off_never_defers():
    server, n = _planner_server(budget_wh=0.02, admission_planner=False)
    server.run_until_drained()
    assert len(server.responses) == n
    assert server.stats["deferred"] == 0


# -- scheduler integration --------------------------------------------------


def test_end_to_end_predictions_reconcile_into_governor():
    server, n = _planner_server(budget_wh=10.0)
    server.run_until_drained()
    gov = server.telemetry.governor
    cm = server.cost_model
    assert cm.n_reconciled == n
    assert cm.inflight_predicted == 0
    assert gov.prediction_error["n"] == n
    assert gov.inflight_predicted_wh == pytest.approx(0.0, abs=1e-9)
    assert not gov.inflight_pred
