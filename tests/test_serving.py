"""Serving runtime: continuous batching, hedging, fault restart, addition."""
import jax
import numpy as np
import pytest

from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import ModelProfile, Query, RouterConfig, TaskType
from repro.configs import get_config
from repro.data import tokenizer as tok
from repro.data.profiles import OutcomeSimulator
from repro.data.stream import make_stream
from repro.serving import (ModelEngine, PoolServer, Request, RequestState,
                           SimEngine)


def _real_engine(name="rwkv6-1.6b", max_batch=3, max_len=96, seed=0):
    cfg = get_config(name, smoke=True, vocab_size=tok.VOCAB_SIZE)
    return ModelEngine(name, cfg, jax.random.PRNGKey(seed),
                       max_batch=max_batch, max_len=max_len,
                       detokenize=tok.decode)


def test_engine_continuous_batching_admits_midstream():
    eng = _real_engine(max_batch=2)
    reqs = [Request(query=Query(uid=i, text=f"q{i} text"),
                    prompt_tokens=tok.encode("hi")[:4], max_new_tokens=3)
            for i in range(4)]
    for r in reqs[:2]:
        eng.submit(r)
    done = []
    for step in range(40):
        done += eng.step()
        if step == 2:                 # queue more while slots are busy
            eng.submit(reqs[2])
            eng.submit(reqs[3])
        if len(done) == 4:
            break
    assert len(done) == 4
    assert {r.uid for r in done} == {0, 1, 2, 3}
    assert all(r.output_tokens <= 3 for r in done)
    assert all(r.energy_wh > 0 for r in done)


def _sim_server(n_models=4, hedge=None, steps_per_query=1, lam=0.4):
    profiles = [ModelProfile(name=f"sim{i}", family="s", params_b=i + 1.0)
                for i in range(n_models)]
    pool = ModelPool(profiles)
    sim = OutcomeSimulator(seed=1)

    def outcome(query, model):
        return 0.5, 0.01, 10.0, 4
    engines = {p.name: SimEngine(p, outcome, steps_per_query=steps_per_query)
               for p in profiles}
    router = GreenServRouter(RouterConfig(lam=lam, max_arms=16), pool)
    return PoolServer(router, engines, hedge_after_steps=hedge), engines


def test_pool_server_routes_and_completes():
    server, _ = _sim_server()
    qs = make_stream(per_task=2)
    for q in qs:
        server.submit(q)
    server.run_until_drained()
    assert len(server.responses) == len(qs)
    assert server.router.policy.state.t == len(qs)   # every query fed back


def test_hedging_fires_for_stuck_queue():
    server, engines = _sim_server(n_models=2, hedge=2, steps_per_query=50)
    qs = make_stream(per_task=2)[:6]
    for q in qs:
        server.submit(q)
    for _ in range(300):
        server.step()
        if not server.inflight:
            break
    assert server.stats["hedges"] > 0
    assert not server.inflight


def test_engine_failure_restart_requeues():
    server, engines = _sim_server(n_models=3)
    qs = make_stream(per_task=3)[:9]
    for i, q in enumerate(qs):
        server.submit(q)
        if i == 4:
            for e in engines.values():
                e.inject_failure()
        server.step()
    server.run_until_drained()
    assert server.stats["restarts"] >= 1
    assert len(server.responses) == 9


def test_restart_does_not_resurrect_answered_query():
    """A hedge loser sitting in a failed engine must not be re-routed:
    its query is already answered, and resurrecting it re-inserts a
    finished uid into inflight (run_until_drained would never drain)."""
    profiles = [ModelProfile(name=f"sim{i}", family="s", params_b=i + 1.0)
                for i in range(2)]
    pool = ModelPool(profiles)

    def outcome(query, model):
        return 0.5, 0.01, 10.0, 4
    # a fresh bandit routes to arm 0 (all scores tie) — make that engine
    # slow so the hedge onto the fast engine wins while the primary is
    # still queued
    engines = {"sim0": SimEngine(profiles[0], outcome, steps_per_query=50),
               "sim1": SimEngine(profiles[1], outcome, steps_per_query=1)}
    router = GreenServRouter(RouterConfig(max_arms=16), pool)
    server = PoolServer(router, engines, hedge_after_steps=1)
    q = make_stream(per_task=1)[0]
    req = server.submit(q)
    assert req.model_name == "sim0"
    for _ in range(10):
        server.step()
        if q.uid in server.responses:
            break
    assert q.uid in server.responses          # hedge won on sim1
    assert req.state == RequestState.CANCELLED
    # the cancelled primary still sits in sim0's queue; fail sim0 so
    # restart() resets it to QUEUED and hands it back for re-routing
    engines["sim0"].inject_failure()
    server.step()
    assert server.stats["restarts"] == 1
    server.run_until_drained(max_steps=200)   # must not TimeoutError
    assert len(server.responses) == 1
    assert not server.inflight


def test_runtime_model_addition_grows_router():
    server, engines = _sim_server(n_models=3)
    assert server.router.policy.n_arms == 3
    prof = ModelProfile(name="late-model", family="s", params_b=9.0)
    server.add_engine(prof, SimEngine(prof, lambda q, m: (0.9, 0.001, 5.0, 4)))
    assert server.router.policy.n_arms == 4
    qs = make_stream(per_task=6)
    for q in qs:
        server.submit(q)
        server.step()
    server.run_until_drained()
    counts = server.router.selection_counts()
    assert counts[3] > 0            # the new arm gets explored (≈adopted)


def test_chunked_prefill_cuts_ttft_by_chunk_factor():
    """A 64-token prompt reaches its first token in ~64 engine steps on the
    seed one-token path and ~64/8 steps with prefill_chunk=8 — the ≥4×
    TTFT reduction the chunked path exists for."""
    prompt = [1 + (i % 250) for i in range(64)]

    def steps_to_first_token(chunk):
        cfg = get_config("granite-3-8b", smoke=True, vocab_size=tok.VOCAB_SIZE)
        eng = ModelEngine("granite-3-8b", cfg, jax.random.PRNGKey(0),
                          max_batch=2, max_len=128, prefill_chunk=chunk)
        req = Request(query=Query(uid=0, text="long prompt"),
                      prompt_tokens=prompt, max_new_tokens=4)
        eng.submit(req)
        steps = 0
        while not req.generated:
            eng.step()
            steps += 1
            assert steps < 200
        assert req.first_token_s > 0      # TTFT recorded at first decode token
        return steps, eng

    steps_tokenwise, _ = steps_to_first_token(1)
    steps_chunked, eng = steps_to_first_token(8)
    assert steps_tokenwise >= 4 * steps_chunked
    assert steps_chunked <= -(-len(prompt) // 8) + 1   # ≈ ceil(64/8)
    # phase-split metering: the chunked run charged real prefill joules
    phases = eng.cumulative_joules_by_phase()
    assert phases["prefill"] > 0


def test_chunked_engine_decode_rides_along_with_prefill():
    """Continuous batching through the chunk step: a decoding request keeps
    producing tokens while a newly admitted long prompt prefills in slabs."""
    cfg = get_config("granite-3-8b", smoke=True, vocab_size=tok.VOCAB_SIZE)
    eng = ModelEngine("granite-3-8b", cfg, jax.random.PRNGKey(1),
                      max_batch=2, max_len=128, prefill_chunk=8)
    first = Request(query=Query(uid=0, text="short"),
                    prompt_tokens=[5, 6, 7], max_new_tokens=30)
    eng.submit(first)
    while not first.generated:            # drive into decode
        eng.step()
    gen_before = len(first.generated)
    second = Request(query=Query(uid=1, text="long"),
                     prompt_tokens=[1 + (i % 250) for i in range(48)],
                     max_new_tokens=2)
    eng.submit(second)
    eng.step()                            # mixed tick: prefill slab + decode
    assert second.n_prompt_fed == 8       # slab consumed
    assert len(first.generated) == gen_before + 1   # decode never stalled
    done = []
    for _ in range(60):
        done += eng.step()
        if len(done) == 2:
            break
    assert {r.uid for r in done} == {0, 1}


# -- SimEngine edge cases (the scenario lab's ModelEngine twin) ---------------


def _sim_req(uid, submit_s=0.0, n_prompt=2):
    return Request(query=Query(uid=uid, text=f"q{uid}"),
                   prompt_tokens=list(range(1, n_prompt + 1)),
                   max_new_tokens=4, submit_s=submit_s)


def test_sim_engine_concurrency_drains_fifo():
    prof = ModelProfile(name="sim", family="s", params_b=1.0)
    eng = SimEngine(prof, lambda q, m: (0.5, 0.01, 10.0, 4), concurrency=2)
    for i in range(5):
        eng.submit(_sim_req(i))
    assert eng.free_capacity == 0
    assert [r.uid for r in eng.step()] == [0, 1]   # head slots drain first
    assert [r.uid for r in eng.step()] == [2, 3]
    assert eng.free_capacity == 1                  # one slot already free
    assert [r.uid for r in eng.step()] == [4]
    assert eng.pending == 0 and eng.free_capacity == 2


def test_sim_engine_midqueue_cancel_frees_slot_same_tick():
    """A CANCELLED request sitting mid-queue (a hedge loser) must be
    dropped in place, freeing its slot for the next waiter on the *same*
    tick, with its pinned outcome discarded and never completed."""
    prof = ModelProfile(name="sim", family="s", params_b=1.0)
    calls = []

    def outcome(query, model):
        calls.append(query.uid)
        return 0.5, 0.01, 10.0, 4
    eng = SimEngine(prof, outcome, steps_per_query=3, concurrency=2)
    reqs = [_sim_req(i) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.step()                        # uids 0,1 hold the two slots
    assert calls == [0, 1]
    reqs[1].state = RequestState.CANCELLED
    eng.step()                        # drop 1 mid-queue...
    assert calls == [0, 1, 2]         # ...and 2 activates the same tick
    assert [r.uid for r in eng.queue] == [0, 2]
    done = []
    for _ in range(4):
        done += eng.step()
    assert [r.uid for r in done] == [0, 2]
    assert calls == [0, 1, 2]         # outcome drawn once per request


def test_sim_engine_steps_per_query_paces_completion_and_clock():
    prof = ModelProfile(name="sim", family="s", params_b=1.0)
    eng = SimEngine(prof, lambda q, m: (0.5, 0.02, 80.0, 4),
                    steps_per_query=4)
    eng.submit(_sim_req(0))
    for _ in range(3):
        assert eng.step() == []       # in service, not yet done
    assert [r.uid for r in eng.step()] == [0]   # exactly step 4
    # each tick advances modeled time by latency/steps: 4 x 20 ms
    assert eng.modeled_time_s() == pytest.approx(0.080)
    assert eng.step() == []           # idle tick leaves the clock alone
    assert eng.modeled_time_s() == pytest.approx(0.080)


def test_sim_engine_injectable_clock_stamps_virtual_time():
    """With an injected clock the lifecycle stamps live on the bench's
    virtual timeline, so queue_ms reflects modeled wait, not wall time."""
    clk = {"t": 50.0}
    prof = ModelProfile(name="sim", family="s", params_b=1.0)
    eng = SimEngine(prof, lambda q, m: (0.5, 0.01, 10.0, 4),
                    clock=lambda: clk["t"])
    req = _sim_req(0, submit_s=47.5)
    eng.submit(req)
    clk["t"] = 53.5
    resp = eng.step()[0]
    assert req.start_s == 53.5 and req.finish_s == 53.5
    assert resp.queue_ms == pytest.approx(6000.0)


def test_single_engine_failure_recovers_and_serves_again():
    """EngineFailure on one pool member: PoolServer must surface it as a
    restart, re-route that engine's inflight work without losing any
    response, and return the engine to service."""
    server, engines = _sim_server(n_models=3, steps_per_query=4)
    qs = make_stream(per_task=2)[:6]
    reqs = [server.submit(q) for q in qs]
    victim = reqs[0].model_name
    on_victim = {r.uid for r in engines[victim].queue}
    assert on_victim
    engines[victim].inject_failure()
    server.step()
    assert server.stats["restarts"] == 1
    server.run_until_drained(max_steps=500)
    assert len(server.responses) == 6
    assert on_victim <= set(server.responses)
    engines[victim].step()            # restarted: stepping no longer raises


def test_real_engine_through_server():
    eng = _real_engine()
    pool = ModelPool([eng.profile])
    router = GreenServRouter(RouterConfig(max_arms=4), pool)
    server = PoolServer(router, {eng.profile.name: eng}, tokenizer=tok.encode)
    qs = make_stream(per_task=1)
    for q in qs:
        server.submit(q)
    server.run_until_drained(max_steps=2000)
    assert len(server.responses) == len(qs)
