"""Fleet subsystem: device-resident router state, sharded pools,
all-reduce merge, heartbeat fail-over, fleet checkpointing (-m fleet)."""
import copy

import numpy as np
import pytest

from repro.core.bandits import BanditPolicy
from repro.core.context import OnlineKMeans
from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import (Feedback, ModelProfile, Query, RouterConfig,
                              TaskType)
from repro.data.stream import make_stream
from repro.fleet import (FeedbackAllReduce, TransferLedger, base_model_name,
                         build_fleet, drive_fleet, plan_fleet)

pytestmark = pytest.mark.fleet


def _pool(n=4):
    return ModelPool([ModelProfile(name=f"m{i}", family="t",
                                   params_b=float(i + 1),
                                   ms_per_token=float(i + 1),
                                   prefill_ms=10.0)
                      for i in range(n)])


def _queries(n, seed=0):
    return make_stream(per_task=max(1, n // 5 + 1), seed=seed)[:n]


def _drive(router, queries, accs=None):
    """Route + close the loop for every query."""
    for i, q in enumerate(queries):
        d = router.route(q)
        acc = accs[i] if accs is not None else 0.5 + 0.4 * (i % 2)
        router.feedback(Feedback(query_uid=q.uid, model_index=d.model_index,
                                 accuracy=acc, energy_wh=0.02 + 0.01 * i,
                                 latency_ms=25.0))


# ---------------------------------------------------------------------------
# tentpole (a): device-resident state — zero per-call transfers
# ---------------------------------------------------------------------------

def test_route_batch_zero_state_transfers():
    """Steady-state device-path routing moves no persistent state across
    the host↔device boundary: after warm-up, N route/feedback rounds
    leave both transfer ledgers (bandit + k-means) flat."""
    cfg = RouterConfig(max_arms=16, featurize="device",
                       algorithm="linucb", solve_mode="sherman_morrison")
    router = GreenServRouter(cfg, _pool())
    qs = _queries(40)
    _drive(router, qs[:10])                     # warm-up: jit + initial h2d
    km = router.context.kmeans.transfers.snapshot()
    bd = router.policy.transfers.snapshot()
    _drive(router, qs[10:])                     # steady state
    assert router.context.kmeans.transfers.snapshot() == km, (
        "k-means state crossed host<->device during steady-state routing")
    assert router.policy.transfers.snapshot() == bd, (
        "bandit state crossed host<->device during steady-state routing")
    # reading the state back out is exactly one deliberate d2h per store
    router.state_dict()
    assert router.context.kmeans.transfers.d2h == km["d2h"] + 1
    assert router.policy.transfers.d2h == bd["d2h"] + 1


def test_kmeans_device_cache_identity_and_lazy_sync():
    km = OnlineKMeans(k=3, dim=8)
    rng = np.random.default_rng(0)
    km.update(rng.normal(size=8).astype(np.float32))
    dev1 = km.device_state()
    assert km.transfers.h2d == 1
    assert km.device_state() is dev1            # cached, no re-upload
    assert km.transfers.h2d == 1
    # device-side update: host mirror goes stale with zero transfers
    km.load_device_state(*dev1)
    d2h_before = km.transfers.d2h
    assert km.transfers.d2h == d2h_before
    _ = km.centroids                            # first host read syncs
    assert km.transfers.d2h == d2h_before + 1
    _ = km.counts                               # already synced
    assert km.transfers.d2h == d2h_before + 1


def test_transfer_ledger():
    led = TransferLedger()
    led.count_h2d()
    led.count_d2h(2)
    assert led.snapshot() == {"h2d": 1, "d2h": 2} and led.total == 3
    led.reset()
    assert led.total == 0


@pytest.mark.parametrize("algorithm,solve_mode", [
    ("cts", "sherman_morrison"),
    ("linucb", "cholesky"),
    ("eps_greedy", "sherman_morrison"),
])
def test_scan_select_matches_sequential(algorithm, solve_mode):
    """The batched lax.scan path replicates sequential select exactly —
    same arms, same scores, same final PRNG key (padding rows never
    consume a draw)."""
    cfg = RouterConfig(max_arms=8, algorithm=algorithm,
                       solve_mode=solve_mode, seed=7)
    pol_batch = BanditPolicy(cfg, n_arms=5)
    pol_seq = BanditPolicy(cfg, n_arms=5)
    rng = np.random.default_rng(1)
    X = rng.normal(size=(6, cfg.context_dim)).astype(np.float32)
    feas = np.ones((6, 5), bool)
    feas[2, :3] = False
    arms_b, scores_b = pol_batch.select_batch(X, feas)
    arms_s = []
    for i in range(X.shape[0]):
        arm, _ = pol_seq.select(X[i], feas[i])
        arms_s.append(arm)
    np.testing.assert_array_equal(arms_b, np.asarray(arms_s))
    np.testing.assert_array_equal(np.asarray(pol_batch.state.key),
                                  np.asarray(pol_seq.state.key))
    assert scores_b.shape == (6, cfg.max_arms)


# ---------------------------------------------------------------------------
# satellite: state_dict round-trips every policy variant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm,solve_mode", [
    ("linucb", "sherman_morrison"),
    ("linucb", "cholesky"),
    ("cts", "sherman_morrison"),
    ("eps_greedy", "sherman_morrison"),
])
def test_state_dict_route_equivalence(algorithm, solve_mode):
    """A restored router routes identically to the one that saved —
    including CTS (PRNG key round-trip) and the cholesky solve mode."""
    cfg = RouterConfig(max_arms=16, algorithm=algorithm,
                       solve_mode=solve_mode, seed=3, lam=0.35)
    router = GreenServRouter(cfg, _pool())
    _drive(router, _queries(25))
    saved = copy.deepcopy(router.state_dict())
    clone = GreenServRouter(RouterConfig(max_arms=16, algorithm=algorithm,
                                         solve_mode=solve_mode, seed=99),
                            _pool())
    clone.load_state_dict(saved)
    assert clone.config.lam == pytest.approx(cfg.lam)
    probe = _queries(12, seed=5)
    d_orig = [d.model_index for d in router.route_batch(probe)]
    d_clone = [d.model_index for d in clone.route_batch(probe)]
    assert d_orig == d_clone, (
        f"{algorithm}/{solve_mode}: restored router diverged")


# ---------------------------------------------------------------------------
# tentpole (b): sharded pool — exact all-reduce
# ---------------------------------------------------------------------------

def _replica(seed=11):
    cfg = RouterConfig(max_arms=16, seed=seed, lam=0.4)
    return GreenServRouter(cfg, _pool())


def test_allreduce_exact_merge():
    """After a sync, every replica's arm statistics equal the *sum* of
    both replicas' locally-applied updates (LinUCB stats are additive),
    and the replicas route identically."""
    r1, r2 = _replica(), _replica()
    qs = _queries(30)
    accs = [0.3 + 0.02 * i for i in range(30)]
    # both replicas route the same stream; feedback is split half/half
    for i, q in enumerate(qs):
        d1, d2 = r1.route(q), r2.route(q)
        if i < 15:
            r1.feedback(Feedback(query_uid=q.uid, model_index=d1.model_index,
                                 accuracy=accs[i], energy_wh=0.03,
                                 latency_ms=20.0))
        else:
            r2.feedback(Feedback(query_uid=q.uid, model_index=d2.model_index,
                                 accuracy=accs[i], energy_wh=0.03,
                                 latency_ms=20.0))
    lam_reg, d = r1.config.lambda_reg, r1.config.context_dim
    eye = lam_reg * np.eye(d)
    n = len(r1.pool)
    pre = []
    for r in (r1, r2):
        sd = r.policy.state_dict()
        pre.append({"xxt": np.asarray(sd["A"][:n], np.float64) - eye,
                    "b": np.asarray(sd["b"][:n], np.float64),
                    "counts": np.asarray(sd["counts"][:n], np.float64)})
    expected_A = eye + pre[0]["xxt"] + pre[1]["xxt"]
    expected_b = pre[0]["b"] + pre[1]["b"]
    expected_counts = pre[0]["counts"] + pre[1]["counts"]

    ar = FeedbackAllReduce(lam_reg, d)
    report = ar.sync({"s0": r1, "s1": r2})
    assert report["arms_updated"] == 2 * n
    for r in (r1, r2):
        sd = r.policy.state_dict()
        np.testing.assert_allclose(np.asarray(sd["A"][:n]), expected_A,
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(sd["b"][:n]), expected_b,
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(sd["counts"][:n]),
                                   expected_counts, rtol=1e-5)
        # maintained inverse rebuilt from the merged design matrix
        np.testing.assert_allclose(
            np.asarray(sd["A_inv"][:n]),
            np.linalg.inv(expected_A), rtol=1e-3, atol=1e-4)
    # idempotence: a second sync with no new feedback is a no-op
    before = np.asarray(r1.policy.state_dict()["A"][:n])
    ar.sync({"s0": r1, "s1": r2})
    np.testing.assert_allclose(np.asarray(r1.policy.state_dict()["A"][:n]),
                               before, rtol=1e-6)
    # behavioral convergence: merged replicas decide identically
    probe = _queries(10, seed=9)
    assert ([x.model_index for x in r1.route_batch(probe)]
            == [x.model_index for x in r2.route_batch(probe)])


def test_allreduce_checkpoint_roundtrip():
    r1, r2 = _replica(), _replica()
    _drive(r1, _queries(10, seed=1))
    _drive(r2, _queries(10, seed=2))
    ar = FeedbackAllReduce(r1.config.lambda_reg, r1.config.context_dim)
    ar.sync({"s0": r1, "s1": r2})
    ar2 = FeedbackAllReduce(r1.config.lambda_reg, r1.config.context_dim)
    ar2.load_state_dict(ar.state_dict())
    assert ar2.syncs == ar.syncs
    for base, stats in ar._global.items():
        for k, v in stats.items():
            np.testing.assert_allclose(ar2._global[base][k], v)


# ---------------------------------------------------------------------------
# fleet planning
# ---------------------------------------------------------------------------

class _FakeDevice:
    def __init__(self, id):  # noqa: A002 - mirrors jax device attr
        self.id = id


def test_plan_fleet_partitions_devices_disjointly():
    devices = [_FakeDevice(i) for i in range(8)]
    plan = plan_fleet(4, ["a", "b"], devices=devices)
    assert plan.n_shards == 4
    seen = [i for s in plan.shards for i in s.device_ids]
    assert sorted(seen) == list(range(8))       # disjoint, full coverage
    assert all(s.n_devices == 2 for s in plan.shards)
    assert all(s.models == ("a", "b") for s in plan.shards)
    # round-robin: shard i owns devices i, i+n_shards, ...
    assert plan.shards[1].device_ids == (1, 5)


def test_plan_fleet_oversubscribed_devices():
    devices = [_FakeDevice(0)]
    plan = plan_fleet(3, ["a"], devices=devices)
    assert [s.device_ids for s in plan.shards] == [(0,), (0,), (0,)]
    with pytest.raises(ValueError):
        plan_fleet(0, ["a"])
    with pytest.raises(ValueError):
        plan_fleet(2, [])


def test_base_model_name():
    assert base_model_name("qwen2.5-3b") == "qwen2.5-3b"
    assert base_model_name("qwen2.5-3b@shard1") == "qwen2.5-3b"


# ---------------------------------------------------------------------------
# controller: fail-over + checkpoint through the closed loop
# ---------------------------------------------------------------------------

def _small_fleet(n_shards, clk, seed=0, heartbeat_timeout_s=0.3):
    from repro.data.profiles import OutcomeSimulator
    from repro.serving.engine import SimEngine
    from repro.configs.pool import build_paper_pool

    keep_small = ["yi-34b", "gemma-3-27b", "qwen2.5-14b", "phi-4-14b",
                  "gemma-3-12b", "llama-3.1-8b", "qwen2.5-7b", "mistral-7b",
                  "qwen2.5-3b", "gemma-3-4b", "llama-3.2-3b",
                  "phi-4-mini-4b"]
    clock = lambda: clk["t"]  # noqa: E731
    sim = OutcomeSimulator(seed=seed)
    outcome = lambda q, m: sim(q, base_model_name(m))  # noqa: E731
    pool_names = [p.name for p in build_paper_pool(exclude=keep_small)]
    plan = plan_fleet(n_shards, pool_names)

    def router_factory(spec):
        cfg = RouterConfig(max_arms=16, seed=seed + spec.index, lam=0.4,
                           energy_scale_wh=0.45)
        return GreenServRouter(
            cfg, ModelPool(build_paper_pool(exclude=keep_small)))

    def engine_factory(profile, spec):
        return SimEngine(profile, outcome, steps_per_query=2,
                         concurrency=4, clock=clock)

    return plan, build_fleet(plan, router_factory, engine_factory,
                             sync_every=4,
                             heartbeat_timeout_s=heartbeat_timeout_s,
                             clock=clock)


def test_failover_zero_lost_requests():
    """Killing a shard mid-stream loses nothing: queries stranded on the
    dead shard (parked, in-flight, and those dispatched into the
    detection window) are redispatched to survivors, whose pools adopt
    the dead shard's engines as fresh arms."""
    from repro.data.scenarios import poisson_arrivals

    clk = {"t": 0.0}
    plan, ctrl = _small_fleet(2, clk)
    qs = _queries(120)
    arrivals = poisson_arrivals(len(qs), 12.0, seed=1)
    t_kill = arrivals[len(arrivals) // 3]
    victim = plan.shards[1].name
    drive_fleet(ctrl, qs, arrivals, clk,
                events=[(t_kill, lambda: ctrl.kill_shard(victim))])
    assert ctrl.stats["completed"] == len(qs)
    assert not ctrl.unanswered
    assert ctrl.stats["failovers"] == 1
    assert ctrl.stats["redispatched"] > 0, (
        "kill recovered no queries — the fail-over path went untested")
    assert ctrl.stats["adopted_engines"] == 4
    ev = [e for e in ctrl.events if e["kind"] == "failover"]
    assert len(ev) == 1 and ev[0]["shard"] == victim
    # adopted arms live on the survivor under suffixed names
    survivor = ctrl.shards[plan.shards[0].name]
    adopted = [n for n in survivor.server.router.pool.names if "@" in n]
    assert len(adopted) == 4
    assert all(base_model_name(n) in plan.shards[0].models
               for n in adopted)


def test_fleet_checkpoint_roundtrip(tmp_path):
    """Fleet-wide save/restore through distributed.checkpoint: a fresh
    fleet restored from the checkpoint routes exactly like the one that
    saved."""
    from repro.data.scenarios import poisson_arrivals

    clk = {"t": 0.0}
    _, ctrl = _small_fleet(2, clk)
    qs = _queries(60)
    drive_fleet(ctrl, qs, poisson_arrivals(len(qs), 10.0, seed=2), clk)
    assert ctrl.stats["syncs"] > 0
    ctrl.save_checkpoint(str(tmp_path), step=1)

    clk2 = {"t": 0.0}
    _, ctrl2 = _small_fleet(2, clk2, seed=0)
    assert ctrl2.load_checkpoint(str(tmp_path)) == 1
    probe = _queries(10, seed=7)
    for name, shard in ctrl.shards.items():
        a = [d.model_index
             for d in shard.server.router.route_batch(probe)]
        b = [d.model_index
             for d in ctrl2.shards[name].server.router.route_batch(probe)]
        assert a == b, f"restored {name} routes differently"
    assert ctrl2.allreduce.syncs == ctrl.allreduce.syncs


def test_heartbeat_virtual_clock_no_sleep():
    """Satellite: fault.Heartbeat takes an injectable clock — staleness
    is driven by modeled time, no wall-clock sleeping."""
    from repro.distributed.fault import HeartbeatMonitor

    t = {"now": 0.0}
    mon = HeartbeatMonitor(timeout_s=5.0, clock=lambda: t["now"])
    mon.register("s0")
    mon.register("s1")
    t["now"] = 4.0
    mon.beat("s0")
    t["now"] = 6.0
    assert mon.stale() == ["s1"]
    mon.deregister("s1")
    assert mon.stale() == []
    t["now"] = 20.0
    assert mon.stale() == ["s0"]
