"""Per-arch reduced-config smoke: one forward/train step on CPU, asserting
output shapes and finiteness (the FULL configs are exercised only via the
dry-run's ShapeDtypeStructs — deliverable (f))."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import ARCH_IDS, get_config
from repro.models import api


def _batch(cfg, b=2, s=32, key=0):
    k = jax.random.PRNGKey(key)
    toks = jax.random.randint(k, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.layout == "encdec" or cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            k, (b, cfg.n_frontend_tokens, cfg.d_model)).astype(cfg.dtype)
    elif cfg.frontend == "vision":
        batch["frontend_embeddings"] = jax.random.normal(
            k, (b, cfg.n_frontend_tokens, cfg.d_model)).astype(cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = api.loss_fn(params, batch, cfg, loss_chunk=16)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0
    assert jnp.isfinite(metrics["aux"])


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_reduces_loss(arch):
    from repro.launch.mesh import make_host_mesh
    from repro.train import OptConfig, make_opt_state, make_train_step
    cfg = get_config(arch, smoke=True)
    mesh = make_host_mesh()
    batch = _batch(cfg, b=2, s=32)
    bundle = make_train_step(cfg, mesh, batch,
                             OptConfig(lr=5e-3, total_steps=8,
                                       warmup_steps=0,
                                       moment_dtype=cfg.moment_dtype),
                             loss_chunk=16)
    fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                 out_shardings=bundle.out_shardings)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_opt_state(cfg, params)
    with compat.set_mesh(mesh):
        losses = []
        for _ in range(4):
            params, opt, m = fn(params, opt, batch)
            losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), arch
    assert min(losses[1:]) < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_serve_step_shapes(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    b, max_len = 2, 64
    cache = api.init_cache(cfg, b, max_len)
    tok = jnp.ones((b, 1), jnp.int32)
    logits, cache = api.serve_step(params, tok, cache, cfg)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    np.testing.assert_array_equal(np.asarray(cache["length"]), [1, 1])


@pytest.mark.parametrize("arch", ["granite-3-8b", "rwkv6-1.6b", "zamba2-7b"])
def test_decode_matches_teacher_forcing(arch):
    """Step-by-step decode logits == teacher-forced forward logits."""
    cfg = get_config(arch, smoke=True)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    logits_tf, _ = api.forward(params, {"tokens": toks}, cfg)
    cache = api.init_cache(cfg, b, 32)
    errs = []
    for t in range(s):
        lg, cache = api.serve_step(params, toks[:, t:t + 1], cache, cfg)
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0].astype(jnp.float32)
            - logits_tf[:, t].astype(jnp.float32)))))
    # bf16 accumulation-order noise; mamba's chunked-vs-stepwise f32 state
    # recurrence drifts most (still ≪ logit scale ~20)
    tol = 0.6 if cfg.layout == "mamba_hybrid" else 0.15
    assert max(errs) < tol, (arch, errs)


def test_windowed_cache_smaller_than_uniform():
    cfg = get_config("gemma3-27b")
    from repro.models import lm
    windowed = lm.cache_shapes(cfg, 128, 32768)
    assert "k_local" in windowed
    uniform_bytes = (cfg.n_layers * 128 * 32768 * cfg.n_kv_heads
                     * cfg.head_dim * 2 * 2)
    windowed_bytes = sum(
        np.prod(v.shape) * 2 for k, v in windowed.items() if k != "length")
    assert windowed_bytes < 0.25 * uniform_bytes


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_shapes_match_init(arch):
    cfg = get_config(arch, smoke=True)
    shapes = api.param_shapes(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    flat_s = jax.tree.leaves(shapes)
    flat_p = jax.tree.leaves(params)
    assert len(flat_s) == len(flat_p)
    for s, p in zip(flat_s, flat_p):
        assert tuple(s.shape) == tuple(p.shape)
        assert s.dtype == p.dtype
