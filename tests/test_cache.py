"""GreenCache correctness: spliced-prefix equivalence, deterministic
eviction, semantic guards, and scheduler integration.

The load-bearing property is splice equivalence: decoding after
``api.splice_prefix`` + suffix chunked prefill must match a cold full
prefill bit-for-bit up to fp tolerance, across dense / MoE / enc-dec
layouts, chunk sizes, prefix lengths, and ragged batch slots — a cache
hit may never change what the model says, only what it costs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import GreenCache, KVBlockPool, SemanticCache, SemanticEntry
from repro.cache.prefix import PrefixCache
from repro.configs import get_config
from repro.core.pool import ModelPool
from repro.core.router import GreenServRouter
from repro.core.types import ModelProfile, Query, RouterConfig
from repro.data import tokenizer as tok
from repro.models import api
from repro.serving import ModelEngine, PoolServer, Request
from repro.telemetry import EnergyBudgetGovernor, Telemetry, to_prometheus

pytestmark = pytest.mark.cache

MAX_LEN = 48
PROMPT = list(range(3, 17))       # 14 tokens
RTOL = ATOL = 3e-4                # fp32, batched-vs-stepped matmuls


def _cfg(arch):
    return get_config(arch, smoke=True, vocab_size=256, dtype="float32",
                      kv_update="where")


def _prefill(params, cfg, prompts, batch, chunk, caches=None, fed0=None):
    """Chunk-prefill per-slot prompts (optionally resuming mid-prompt from
    a spliced cache); returns (per-slot last logits, cache)."""
    cache = caches if caches is not None else api.init_cache(cfg, batch,
                                                             MAX_LEN)
    fed = list(fed0) if fed0 is not None else [0] * batch
    last = [None] * batch
    while any(fed[b] < len(p) for b, p in enumerate(prompts)):
        toks = np.zeros((batch, chunk), np.int32)
        n_active = np.zeros((batch,), np.int32)
        for b, p in enumerate(prompts):
            n = min(chunk, len(p) - fed[b])
            if n > 0:
                toks[b, :n] = p[fed[b]:fed[b] + n]
                n_active[b] = n
        logits, cache = api.prefill_chunk(params, jnp.asarray(toks), cache,
                                          cfg, jnp.asarray(n_active))
        for b, p in enumerate(prompts):
            n = int(n_active[b])
            if n and fed[b] + n == len(p):
                last[b] = np.asarray(logits[b, n - 1])
            fed[b] += n
    return last, cache


# ---------------------------------------------------------------------------
# Splice equivalence (property-style: layouts × chunk sizes × prefix lens)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["granite-3-8b", "qwen2-moe-a2.7b",
                                  "whisper-medium"])
@pytest.mark.parametrize("chunk,prefix_len", [
    (2, 4), (2, 13), (5, 8), (8, 3), (8, 13), (14, 7),
])
def test_spliced_prefix_matches_cold_prefill(arch, chunk, prefix_len):
    if arch == "qwen2-moe-a2.7b" and chunk > 8:
        # expert capacity is computed per dispatch group, so a cold
        # 14-token group and a warm 7-token group can drop different
        # tokens — the standing MoE chunking caveat (docs/SERVING.md),
        # not a cache defect; the suite pins MoE chunks at <= 8
        pytest.skip("MoE capacity caveat: chunk > 8 not exact")
    """Warm decode == cold decode: splice the first ``prefix_len`` tokens'
    KV (captured from a completed cold run) into a fresh slot, prefill
    only the suffix, and require identical first-token logits and an
    identical prompt-region cache."""
    cfg = _cfg(arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    cold_logits, cold_cache = _prefill(params, cfg, [PROMPT], 1, chunk)
    # the "cached blocks": the cold cache's KV for positions [0, prefix)
    k_blk = np.asarray(cold_cache["k"][:, 0, :prefix_len])
    v_blk = np.asarray(cold_cache["v"][:, 0, :prefix_len])
    warm = api.init_cache(cfg, 1, MAX_LEN)
    warm = api.splice_prefix(warm, 0, k_blk, v_blk)
    assert int(warm["length"][0]) == prefix_len
    warm_logits, warm_cache = _prefill(params, cfg, [PROMPT], 1, chunk,
                                       caches=warm, fed0=[prefix_len])
    np.testing.assert_allclose(warm_logits[0], cold_logits[0],
                               rtol=RTOL, atol=ATOL)
    n_p = len(PROMPT)
    for leaf in ("k", "v"):
        np.testing.assert_allclose(
            np.asarray(warm_cache[leaf][:, 0, :n_p]),
            np.asarray(cold_cache[leaf][:, 0, :n_p]), rtol=RTOL, atol=ATOL)
    np.testing.assert_array_equal(np.asarray(warm_cache["length"]),
                                  np.asarray(cold_cache["length"]))


def test_spliced_prefix_ragged_batch_slots():
    """A spliced slot and a cold slot share a batch: the splice must not
    leak into the neighbour, and both must match their solo references."""
    cfg = _cfg("granite-3-8b")
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    short = list(range(40, 45))                       # 5 tokens, cold
    cold_logits, cold_cache = _prefill(params, cfg, [PROMPT, short], 2, 4)
    k_blk = np.asarray(cold_cache["k"][:, 0, :8])
    v_blk = np.asarray(cold_cache["v"][:, 0, :8])
    warm = api.init_cache(cfg, 2, MAX_LEN)
    warm = api.splice_prefix(warm, 0, k_blk, v_blk)   # slot 0 only
    warm_logits, warm_cache = _prefill(params, cfg, [PROMPT, short], 2, 4,
                                       caches=warm, fed0=[8, 0])
    for b in range(2):
        np.testing.assert_allclose(warm_logits[b], cold_logits[b],
                                   rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(warm_cache["k"]),
                               np.asarray(cold_cache["k"]),
                               rtol=RTOL, atol=ATOL)


def test_engine_warm_generation_matches_cold():
    """End-to-end through ModelEngine: the second serving of a prompt
    splices whole blocks, decodes identical tokens, reaches its first
    token in fewer engine steps, and banks avoided joules."""
    cfg = get_config("granite-3-8b", smoke=True, vocab_size=tok.VOCAB_SIZE,
                     dtype="float32")
    eng = ModelEngine("granite", cfg, jax.random.PRNGKey(2), max_batch=2,
                      max_len=64, prefill_chunk=4)
    eng.set_prefix_cache(PrefixCache(max_blocks=32, block_tokens=8))
    prompt = list(range(7, 27))                       # 20 tokens

    def serve(uid):
        req = Request(query=Query(uid=uid, text="x"),
                      prompt_tokens=list(prompt), max_new_tokens=4)
        eng.submit(req)
        steps, out = 0, []
        while not req.generated and steps < 80:
            out += eng.step()
            steps += 1
        ttft_steps = steps
        while not out and steps < 120:
            out += eng.step()
            steps += 1
        return out[0], ttft_steps, req

    cold, cold_ttft, _ = serve(0)
    warm, warm_ttft, warm_req = serve(1)
    assert cold.tokens == warm.tokens
    assert warm_req.prefix_reused == 16               # 2 whole 8-token blocks
    assert warm.prefix_reused == 16
    assert warm_ttft < cold_ttft
    assert eng.prefix_hit_count() == 1
    assert eng.cumulative_joules_avoided() > 0.0
    assert warm.energy_wh < cold.energy_wh            # true-spend accounting


def test_engine_without_full_depth_cache_ignores_prefix_cache():
    """Recurrent layouts can't take a splice: attach is a silent no-op
    (same gate as chunked prefill)."""
    cfg = get_config("rwkv6-1.6b", smoke=True, vocab_size=tok.VOCAB_SIZE)
    eng = ModelEngine("rwkv", cfg, jax.random.PRNGKey(0), max_batch=1,
                      max_len=32)
    eng.set_prefix_cache(PrefixCache(max_blocks=4))
    assert eng.prefix_cache is None


# ---------------------------------------------------------------------------
# Eviction: bounded, leaf-first, deterministic
# ---------------------------------------------------------------------------


def _fake_kv(n_tokens, tag=0.0):
    k = np.full((2, n_tokens, 1, 4), tag, np.float32)
    return k, k.copy()


def test_lru_leaf_eviction_keeps_chains_contiguous():
    pc = PrefixCache(max_blocks=3, block_tokens=4)
    a = list(range(8))                  # 2 blocks
    b = list(range(100, 104))           # 1 block
    pc.insert(a, *_fake_kv(8))
    pc.insert(b, *_fake_kv(4))
    assert pc.stats()["blocks"] == 3
    n, _ = pc.index.lookup(a)           # touch A's chain (B becomes LRU)
    assert n == 8
    c = list(range(200, 204))
    pc.insert(c, *_fake_kv(4))          # full: must evict B's leaf, not A's
    assert pc.peek_len(a) == 8          # A's chain survived intact
    assert pc.peek_len(b) == 0
    assert pc.peek_len(c) == 4
    assert pc.pool.evictions == 1


def test_eviction_is_deterministic_under_seeded_workload():
    def run():
        rng = np.random.default_rng(7)
        pc = PrefixCache(max_blocks=16, block_tokens=4)
        trace = []
        for _ in range(200):
            toks = [int(t) for t in rng.integers(0, 6, size=rng.integers(4, 17))]
            if rng.random() < 0.5:
                n, _ = pc.index.lookup(toks)
                trace.append(("l", n))
            else:
                pc.insert(toks, *_fake_kv((len(toks) // 4) * 4))
                trace.append(("i", len(pc.index)))
        return trace, pc.stats()

    t1, s1 = run()
    t2, s2 = run()
    assert t1 == t2
    assert s1 == s2
    assert s1["evictions"] > 0          # the workload actually churned
    assert s1["blocks"] <= 16


def test_kvpool_refuses_puts_past_capacity():
    pool = KVBlockPool(max_blocks=2, block_tokens=4)
    k, v = _fake_kv(4)
    b0, b1 = pool.put(k, v), pool.put(k, v)
    assert pool.put(k, v) is None and pool.full
    pool.free(b0)
    assert pool.put(k, v) is not None
    assert pool.lru_order()[0] == b1    # oldest surviving block is LRU


# ---------------------------------------------------------------------------
# Semantic cache guards
# ---------------------------------------------------------------------------


def _entry(task, cluster=0, model="m", wh=0.01):
    return SemanticEntry(text="t", task_label=task, cluster=cluster,
                         model_name=model, tokens=[1], text_out="out",
                         energy_wh=wh, accuracy=1.0, input_tokens=4,
                         output_tokens=1)


def test_semantic_cache_never_crosses_task_types():
    sc = SemanticCache(dim=4, threshold=0.5, max_entries=8)
    e = np.array([1.0, 0, 0, 0], np.float32)
    sc.insert(e, _entry(task=0))
    assert sc.lookup(e, task_label=0, cluster=0) is not None
    # identical embedding, different task: the guard must win over cos=1.0
    assert sc.lookup(e, task_label=1, cluster=0) is None
    # identical embedding + task, different cluster: guarded too
    assert sc.lookup(e, task_label=0, cluster=3) is None


def test_semantic_threshold_and_lru_eviction():
    sc = SemanticCache(dim=2, threshold=0.9, max_entries=2)
    e0 = np.array([1.0, 0.0], np.float32)
    e1 = np.array([0.0, 1.0], np.float32)
    sc.insert(e0, _entry(0, model="a"))
    sc.insert(e1, _entry(0, model="b"))
    near = np.array([0.95, 0.312], np.float32)
    near /= np.linalg.norm(near)
    assert sc.lookup(near, 0, 0).model_name == "a"    # cos ≈ 0.95 ≥ 0.9
    far = np.array([0.7, 0.714], np.float32)
    far /= np.linalg.norm(far)
    assert sc.lookup(far, 0, 0) is None               # under threshold
    # e1 is now LRU (e0 was touched by the hit): a third insert evicts it
    sc.insert(e0 * -1.0, _entry(0, model="c"))
    assert sc.lookup(e1, 0, 0) is None
    assert sc.lookup(e0, 0, 0).model_name == "a"
    assert sc.evictions == 1


# ---------------------------------------------------------------------------
# Scheduler integration
# ---------------------------------------------------------------------------


def _small_server(cache_mode="full", **kw):
    cfg = get_config("granite-3-8b", smoke=True, vocab_size=tok.VOCAB_SIZE,
                     dtype="float32")
    eng = ModelEngine("granite-3-8b", cfg, jax.random.PRNGKey(0),
                      max_batch=2, max_len=64)
    router = GreenServRouter(RouterConfig(lam=0.4, energy_scale_wh=0.05),
                             ModelPool([eng.profile]))
    cache = GreenCache(mode=cache_mode, kv_cache_blocks=32,
                       semantic_threshold=0.99)
    server = PoolServer(router, {"granite-3-8b": eng}, tokenizer=tok.encode,
                        prefill_chunk=4, cache=cache, **kw)
    return server, eng, cache


def test_semantic_hit_short_circuits_routing_and_engines():
    server, eng, cache = _small_server(telemetry=Telemetry())
    q = Query(uid=0, text="Answer the question about entropy now",
              max_new_tokens=3)
    server.submit_batch([q])
    server.run_until_drained()
    routed = server.router.n_routed
    dup = Query(uid=1, text="Answer the question about entropy now",
                max_new_tokens=3)
    reqs = server.submit_batch([dup])
    assert reqs[0].done                               # already answered
    assert server.responses[1].tokens == server.responses[0].tokens
    assert server.responses[1].energy_wh == 0.0
    assert server.router.n_routed == routed           # no bandit pull
    assert eng.pending == 0                           # no engine work
    assert server.stats["cache_hits"] == 1
    prom = to_prometheus(server.telemetry.registry)
    assert 'greenserv_cache_hits_total{kind="semantic"} 1.0' in prom


def test_configure_engine_applies_all_knobs_to_late_joiners():
    """add_engine goes through the same _configure_engine as construction:
    a late engine gets the pool-level prefill_chunk AND its prefix-cache
    handle AND telemetry pre-binding — no knob silently missed."""
    server, _, cache = _small_server(cache_mode="prefix",
                                     telemetry=Telemetry())
    cfg = get_config("qwen2-moe-a2.7b", smoke=True,
                     vocab_size=tok.VOCAB_SIZE, dtype="float32")
    late = ModelEngine("qwen2-moe-a2.7b", cfg, jax.random.PRNGKey(1),
                       max_batch=2, max_len=64)       # engine default: 1
    assert late.prefill_chunk == 1 and late.prefix_cache is None
    server.add_engine(late.profile, late)
    assert late.prefill_chunk == 4                    # server-level knob
    assert late.prefix_cache is cache.prefix_for("qwen2-moe-a2.7b")
    prom = to_prometheus(server.telemetry.registry)
    assert 'greenserv_queue_depth{engine="qwen2-moe-a2.7b"}' in prom
    assert server.telemetry.events.counts["engine_added"] == 1


def test_prefix_discount_tilts_routing_toward_warm_arm():
    """Two identical-profile arms; arm 1 holds a cached prefix for the
    query → the energy discount must flip the decision to arm 1."""
    profiles = [ModelProfile(name=f"m{i}", family="dense", params_b=1.0)
                for i in range(2)]
    router = GreenServRouter(RouterConfig(lam=0.5, energy_scale_wh=0.01),
                             ModelPool(profiles))
    q = Query(uid=0, text="discount probe")
    base = router.route_batch([q])[0]
    other = 1 - base.model_index
    disc = np.zeros((1, 2))
    disc[0, other] = 1.0                              # 1 Wh expected saving
    q2 = Query(uid=1, text="discount probe")
    tilted = router.route_batch([q2], energy_discounts_wh=disc)[0]
    assert tilted.model_index == other


def test_governor_avoided_energy_credit_and_inflight_discount():
    gov = EnergyBudgetGovernor(budget_wh=10.0, horizon_queries=100,
                               control_on_completion=False)
    gov.bucket_wh = 0.0
    gov.on_avoided_energy(0.25, "prefix")
    gov.on_avoided_energy(0.1, "semantic")
    s = gov.stats()
    assert s["avoided_prefix_wh"] == pytest.approx(0.25)
    assert s["avoided_semantic_wh"] == pytest.approx(0.1)
    assert gov.bucket_wh == pytest.approx(min(0.35, gov.capacity_wh))
    assert gov.cumulative_wh == 0.0                   # credit, not un-spend
    # in-flight savings shrink the committed projection (moderate load so
    # neither error clips at the ±1 bound)
    gov.on_completion(0.1, 0.0)
    gov.on_admission(4, 0.0, expected_savings_wh=0.0)
    hot = gov._rate_error()
    gov2 = EnergyBudgetGovernor(budget_wh=10.0, horizon_queries=100,
                                control_on_completion=False)
    gov2.on_completion(0.1, 0.0)
    gov2.on_admission(4, 0.0, expected_savings_wh=0.2)
    assert -1.0 < gov2._rate_error() < hot < 1.0


# ---------------------------------------------------------------------------
# TTL / staleness policy
# ---------------------------------------------------------------------------


def test_semantic_ttl_expires_entries_on_virtual_clock():
    clk = {"t": 0.0}
    sc = SemanticCache(dim=2, threshold=0.9, max_entries=4, ttl_s=10.0,
                       clock=lambda: clk["t"])
    e = np.array([1.0, 0.0], np.float32)
    sc.insert(e, _entry(0, model="a"))
    clk["t"] = 9.0
    assert sc.lookup(e, 0, 0).model_name == "a"       # within the TTL
    clk["t"] = 10.5
    assert sc.lookup(e, 0, 0) is None                 # aged out
    assert sc.expirations == 1
    assert len(sc) == 0                               # slot freed
    assert sc.stats()["ttl_s"] == 10.0


def test_semantic_ttl_refreshes_on_reinsert_not_on_hit():
    clk = {"t": 0.0}
    sc = SemanticCache(dim=2, threshold=0.9, max_entries=4, ttl_s=10.0,
                       clock=lambda: clk["t"])
    e = np.array([0.0, 1.0], np.float32)
    sc.insert(e, _entry(0, model="a"))
    clk["t"] = 8.0
    assert sc.lookup(e, 0, 0) is not None             # hit does NOT refresh
    clk["t"] = 11.0
    assert sc.lookup(e, 0, 0) is None                 # age counts from insert
    sc.insert(e, _entry(0, model="b"))                # re-insert restarts age
    clk["t"] = 20.0
    assert sc.lookup(e, 0, 0).model_name == "b"


def test_semantic_ttl_expired_slots_reused_before_lru_eviction():
    clk = {"t": 0.0}
    sc = SemanticCache(dim=2, threshold=0.9, max_entries=2, ttl_s=5.0,
                       clock=lambda: clk["t"])
    sc.insert(np.array([1.0, 0.0], np.float32), _entry(0, model="old"))
    clk["t"] = 4.0
    sc.insert(np.array([0.0, 1.0], np.float32), _entry(0, model="live"))
    clk["t"] = 6.0                                    # "old" aged out
    sc.insert(np.array([-1.0, 0.0], np.float32), _entry(0, model="new"))
    assert sc.evictions == 0                          # reused expired slot
    assert sc.expirations == 1
    assert sc.lookup(np.array([0.0, 1.0], np.float32), 0, 0) is not None


def test_greencache_plumbs_ttl_and_clock():
    clk = {"t": 0.0}
    cache = GreenCache(mode="semantic", semantic_ttl_s=7.0,
                       clock=lambda: clk["t"])
    assert cache.semantic.ttl_s == 7.0
    e = np.zeros(384, np.float32)
    e[0] = 1.0
    cache.semantic.insert(e, _entry(0, model="x"))
    clk["t"] = 8.0
    assert cache.semantic.lookup(e, 0, 0) is None
    assert cache.semantic.stats()["expirations"] == 1


def test_ttl_rejects_nonpositive():
    with pytest.raises(ValueError):
        SemanticCache(ttl_s=0.0)


# ---------------------------------------------------------------------------
# Batched admission probe (one featurization pass per batch)
# ---------------------------------------------------------------------------


def test_features_batch_matches_per_query_features():
    cache = GreenCache(mode="semantic")
    router = GreenServRouter(
        RouterConfig(lam=0.4, energy_scale_wh=0.05),
        ModelPool([ModelProfile(name="m0", family="d", params_b=1.0)]))
    cache.bind_context(router.context)
    texts = ["Answer the question about entropy now",
             "Summarize the committee filing on item alpha",
             "Solve the word problem with held value beta"]
    labels, clusters, embs = cache.features_batch(texts)
    for i, t in enumerate(texts):
        task, cluster, emb = cache.features(t)
        assert int(labels[i]) == task
        assert int(clusters[i]) == cluster
        np.testing.assert_allclose(embs[i], emb, atol=1e-5)


def test_submit_batch_uses_one_probe_pass(monkeypatch):
    server, eng, cache = _small_server(telemetry=Telemetry())
    calls = {"batch": 0, "single": 0}
    real_batch = cache.features_batch
    real_single = cache.features
    monkeypatch.setattr(
        cache, "features_batch",
        lambda texts: calls.__setitem__("batch", calls["batch"] + 1)
        or real_batch(texts))
    monkeypatch.setattr(
        cache, "features",
        lambda text: calls.__setitem__("single", calls["single"] + 1)
        or real_single(text))
    qs = [Query(uid=i, text=f"Probe batching question {i}",
                max_new_tokens=2) for i in range(3)]
    server.submit_batch(qs)
    assert calls["batch"] == 1                 # one pass for the admission
    assert calls["single"] == 0                # no per-query re-encode
    server.run_until_drained()
