"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see 1 CPU device
(only launch/dryrun.py fakes 512).  Multi-device tests run in subprocesses.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

from repro.core.types import RouterConfig


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "cache: GreenCache prefix-KV / semantic caching tests "
        "(run the subset with -m cache)")
    config.addinivalue_line(
        "markers",
        "disagg: disaggregated prefill/decode serving tests — migration "
        "correctness, fault injection, unified equivalence "
        "(run the subset with -m disagg)")
    config.addinivalue_line(
        "markers",
        "costmodel: predictive energy cost model tests — analytic prior, "
        "RLS calibration, governor reconciliation, admission planner "
        "(run the subset with -m costmodel)")
    config.addinivalue_line(
        "markers",
        "scenario: scenario-lab tests — generator determinism, closed-loop "
        "GreenServ-vs-random economics, flash-crowd liveness, pool-churn "
        "durability (run the subset with -m scenario)")
    config.addinivalue_line(
        "markers",
        "fleet: fleet subsystem tests — device-resident router state "
        "(zero-transfer routing), sharded pool all-reduce, heartbeat "
        "fail-over, fleet checkpointing (run the subset with -m fleet)")
    config.addinivalue_line(
        "markers",
        "chaos: reliability-layer tests — deadlines/retries, per-arm "
        "circuit breakers, fault injection, governor charge hygiene "
        "under failure (run the subset with -m chaos)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def router_config():
    return RouterConfig(lam=0.4, max_arms=24, energy_scale_wh=0.3)


@pytest.fixture(scope="session")
def prng():
    return jax.random.PRNGKey(0)
