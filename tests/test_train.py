"""Optimizer, schedules, gradient compression, end-to-end training loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (OptConfig, adamw_update, cosine_lr, ef_compress,
                         ef_init, init_opt_state)
from repro.train.compress import dequantize_leaf, quantize_leaf


def test_cosine_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    lrs = [float(cosine_lr(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(1e-3, rel=1e-4)     # end of warmup
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-2)    # min_lr_frac·lr
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))


def test_adamw_converges_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    cfg = OptConfig(lr=0.2, weight_decay=0.0, total_steps=200,
                    warmup_steps=0, min_lr_frac=1.0)
    opt = init_opt_state(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_moment_dtype_respected():
    params = {"w": jnp.ones((4, 4))}
    cfg = OptConfig(moment_dtype="bfloat16")
    opt = init_opt_state(params, cfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    params2, opt2, _ = adamw_update({"w": jnp.ones((4, 4))}, opt, params, cfg)
    assert opt2["v"]["w"].dtype == jnp.bfloat16


def test_grad_clip_applied():
    params = {"w": jnp.zeros(3)}
    cfg = OptConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0, warmup_steps=0,
                    total_steps=10, min_lr_frac=1.0)
    opt = init_opt_state(params, cfg)
    _, _, m = adamw_update({"w": jnp.array([30.0, 40.0, 0.0])}, opt, params,
                           cfg)
    assert float(m["grad_norm"]) == pytest.approx(50.0, rel=1e-5)


def test_ef_compress_residual_carries():
    """Error feedback: compressed-sum over steps ≈ true sum."""
    rng = np.random.default_rng(0)
    grads = [{"w": jnp.asarray(rng.standard_normal(64), dtype=jnp.float32)}
             for _ in range(30)]
    ef = ef_init({"w": jnp.zeros(64)})
    total_c = np.zeros(64)
    total_t = np.zeros(64)
    for g in grads:
        gq, ef = ef_compress(g, ef)
        total_c += np.asarray(gq["w"])
        total_t += np.asarray(g["w"])
    # residual bound: the final EF buffer is the only divergence
    np.testing.assert_allclose(total_c + np.asarray(ef["w"]), total_t,
                               atol=1e-4)
    assert np.abs(total_c - total_t).max() < 0.05


def test_quantize_roundtrip_exact_for_grid_values():
    x = jnp.asarray(np.linspace(-127, 127, 255), dtype=jnp.float32)
    q, s = quantize_leaf(x)
    np.testing.assert_allclose(np.asarray(dequantize_leaf(q, s)),
                               np.asarray(x), atol=1e-4)


def test_train_loop_with_failure_recovery(tmp_path):
    """launch.train end-to-end: loss drops; injected failure restores from
    checkpoint and continues to the target step."""
    from repro.launch.train import train
    out = train("rwkv6-1.6b", smoke=True, steps=8, batch=2, seq=32,
                ckpt_dir=str(tmp_path), ckpt_every=3, fail_at_step=5,
                lr=5e-3, log_every=100)
    assert out["steps"] == 8                     # recovered AND finished
    assert np.isfinite(out["final_loss"])
    assert len(out["losses"]) >= 8               # re-ran the restored span


def test_compressed_psum_subprocess():
    """int8 compressed all-reduce ≈ fp32 sum across 8 fake devices."""
    import subprocess, sys, os, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.launch.mesh import make_mesh
        from repro.train.compress import compressed_psum
        mesh = make_mesh((8,), ("data",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((8, 32, 16)), jnp.float32)
        with compat.set_mesh(mesh):
            out = compressed_psum({"w": g}, "data", mesh)
        want = np.asarray(g).sum(0)
        got = np.asarray(out["w"])
        rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert rel < 0.02, rel
        print("OK", rel)
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       cwd=os.path.join(os.path.dirname(__file__), ".."),
                       capture_output=True, text=True, timeout=300)
    assert "OK" in r.stdout, r.stderr[-2000:]
