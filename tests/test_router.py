"""Router behaviour: Algorithm 1 loop, feasibility, model addition, regret."""
import numpy as np
import pytest

from repro.core.pool import ModelPool
from repro.core.rewards import RegretTracker, scalarize
from repro.core.router import GreenServRouter
from repro.core.types import (Feedback, ModelProfile, Query, RouterConfig,
                              TaskType)


def _pool(n=4):
    return ModelPool([ModelProfile(name=f"m{i}", family="t",
                                   params_b=float(i + 1),
                                   ms_per_token=float(i + 1),
                                   prefill_ms=10.0)
                      for i in range(n)])


def _router(n=4, **kw):
    cfg = RouterConfig(max_arms=16, **kw)
    return GreenServRouter(cfg, _pool(n))


def test_route_feedback_cycle():
    r = _router()
    q = Query(uid=1, text="Answer the question.\nWhat is entropy?",
              task=TaskType.QA)
    d = r.route(q)
    assert 0 <= d.model_index < 4
    rew = r.feedback(Feedback(query_uid=1, model_index=d.model_index,
                              accuracy=0.8, energy_wh=0.05, latency_ms=30.0))
    assert rew == pytest.approx(scalarize(0.8, 0.05, r.config.lam,
                                          r.config.energy_scale_wh))


def test_feedback_without_route_raises():
    r = _router()
    with pytest.raises(KeyError):
        r.feedback(Feedback(query_uid=99, model_index=0, accuracy=1.0,
                            energy_wh=0.0, latency_ms=0.0))


def test_latency_feasibility_filter():
    r = _router()
    # budget only the fastest model can meet: m0 = 10 + 1*t
    q = Query(uid=2, text="hello world question", max_new_tokens=50,
              latency_budget_ms=70.0)
    d = r.route(q)
    assert d.model_name == "m0"
    assert d.feasible_mask.tolist() == [True, False, False, False]


def test_infeasible_all_degrades_to_fastest():
    r = _router()
    q = Query(uid=3, text="hi there", max_new_tokens=1000,
              latency_budget_ms=1.0)
    d = r.route(q)
    assert d.model_name == "m0"   # fastest fallback, never a dropped query


def test_model_addition_grows_arm(capsys):
    r = _router(3)
    assert r.policy.n_arms == 3
    r.pool.add(ModelProfile(name="new", family="t", params_b=9.0))
    assert r.policy.n_arms == 4
    q = Query(uid=4, text="route me somewhere useful")
    d = r.route(q)
    assert d.feasible_mask.shape[0] == 4


def test_learning_prefers_better_arm():
    """After enough feedback, the router should exploit the best arm."""
    r = _router(3, alpha_ucb=0.05)
    rng = np.random.default_rng(0)
    best = 1
    for uid in range(300):
        q = Query(uid=uid, text=f"Answer the question.\nQ {uid} about topic")
        d = r.route(q)
        acc = 0.9 if d.model_index == best else 0.3
        r.feedback(Feedback(query_uid=uid, model_index=d.model_index,
                            accuracy=acc + rng.normal(0, 0.02),
                            energy_wh=0.05, latency_ms=10.0))
    counts = r.selection_counts()
    assert counts[best] == max(counts)
    assert counts[best] > 150


def test_regret_tracker_math():
    t = RegretTracker()
    assert t.step(0.5, 0.9) == pytest.approx(0.4)
    assert t.step(0.9, 0.9) == 0.0
    assert t.step(1.2, 0.9) == 0.0      # never negative (Eq. 7)
    assert t.cumulative == pytest.approx(0.4)
    assert t.cumulative_curve().tolist() == pytest.approx([0.4, 0.4, 0.4])


def test_router_state_roundtrip():
    r = _router()
    for uid in range(10):
        q = Query(uid=uid, text=f"Summarize the following.\nArticle {uid}")
        d = r.route(q)
        r.feedback(Feedback(query_uid=uid, model_index=d.model_index,
                            accuracy=0.5, energy_wh=0.1, latency_ms=5.0))
    blob = r.state_dict()
    r2 = _router()
    r2.load_state_dict(blob)
    np.testing.assert_allclose(r2.selection_counts(), r.selection_counts())
