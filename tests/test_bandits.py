"""Bandit math vs. numpy oracles (paper §4.3, Eq. 13)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bandits import BanditPolicy, init_state, add_arm
from repro.core.types import RouterConfig


def _oracle_linucb(A, b, x, alpha):
    """Direct per-arm solve: θ = A⁻¹b; score = θᵀx + α√(xᵀA⁻¹x)."""
    scores = []
    for Am, bm in zip(A, b):
        inv = np.linalg.inv(Am)
        theta = inv @ bm
        scores.append(theta @ x + alpha * np.sqrt(max(x @ inv @ x, 0)))
    return np.array(scores)


def _run_updates(policy, rng, n, d, n_arms):
    for _ in range(n):
        x = rng.standard_normal(d).astype(np.float32)
        arm, _ = policy.select(x, np.ones(n_arms, bool))
        policy.update(arm, x, float(rng.standard_normal()))


@pytest.mark.parametrize("algo", ["linucb", "cts", "eps_greedy",
                                  "eps_greedy_ctx"])
def test_select_update_runs(algo, router_config, rng):
    cfg = router_config
    cfg.algorithm = algo
    pol = BanditPolicy(cfg, n_arms=5)
    _run_updates(pol, rng, 30, cfg.context_dim, 5)
    counts = np.asarray(pol.state.counts)[:5]
    assert counts.sum() == 30
    assert np.all(np.isfinite(pol.state_dict()["theta"]))


def test_linucb_scores_match_direct_inverse(router_config, rng):
    cfg = router_config
    pol = BanditPolicy(cfg, n_arms=4)
    d = cfg.context_dim
    _run_updates(pol, rng, 60, d, 4)
    x = rng.standard_normal(d).astype(np.float32)
    _, scores = pol.select(x, np.ones(4, bool))
    st = pol.state_dict()
    want = _oracle_linucb(st["A"][:4], st["b"][:4], x, cfg.alpha_ucb)
    np.testing.assert_allclose(scores[:4], want, rtol=2e-4, atol=2e-4)


def test_sherman_morrison_equals_cholesky_path(router_config, rng):
    cfg = router_config
    pol = BanditPolicy(cfg, n_arms=3)
    _run_updates(pol, rng, 40, cfg.context_dim, 3)
    x = rng.standard_normal(cfg.context_dim).astype(np.float32)
    from repro.core.bandits import linucb_scores
    sm = linucb_scores(pol.state, jnp.asarray(x), cfg.alpha_ucb,
                       "sherman_morrison")
    ch = linucb_scores(pol.state, jnp.asarray(x), cfg.alpha_ucb, "cholesky")
    np.testing.assert_allclose(np.asarray(sm)[:3], np.asarray(ch)[:3],
                               rtol=2e-4, atol=2e-4)


def test_a_inv_consistency(router_config, rng):
    """Maintained A⁻¹ stays the true inverse after many rank-1 updates."""
    cfg = router_config
    pol = BanditPolicy(cfg, n_arms=2)
    _run_updates(pol, rng, 100, cfg.context_dim, 2)
    st = pol.state_dict()
    for m in range(2):
        np.testing.assert_allclose(st["A_inv"][m], np.linalg.inv(st["A"][m]),
                                   rtol=1e-3, atol=1e-4)


def test_add_arm_fresh_prior(router_config):
    cfg = router_config
    state = init_state(cfg, n_arms=3)
    state, idx = add_arm(state, cfg)
    assert idx == 3
    assert bool(state.active[3])
    d = cfg.context_dim
    np.testing.assert_allclose(np.asarray(state.A[3]),
                               np.eye(d) * cfg.lambda_reg)
    np.testing.assert_allclose(np.asarray(state.b[3]), 0.0)


def test_feasibility_mask_respected(router_config, rng):
    cfg = router_config
    pol = BanditPolicy(cfg, n_arms=6)
    feas = np.array([False, True, False, True, False, False])
    for _ in range(25):
        x = rng.standard_normal(cfg.context_dim).astype(np.float32)
        arm, _ = pol.select(x, feas)
        assert feas[arm]
        pol.update(arm, x, 0.1)


def test_eps_decay(router_config, rng):
    cfg = router_config
    cfg.algorithm = "eps_greedy"
    pol = BanditPolicy(cfg, n_arms=3)
    eps0 = float(pol.state.eps)
    _run_updates(pol, rng, 50, cfg.context_dim, 3)
    assert float(pol.state.eps) == pytest.approx(
        max(eps0 * cfg.epsilon_decay ** 50, cfg.epsilon_min), rel=1e-3)


def test_state_dict_roundtrip(router_config, rng):
    cfg = router_config
    pol = BanditPolicy(cfg, n_arms=4)
    _run_updates(pol, rng, 20, cfg.context_dim, 4)
    blob = pol.state_dict()
    pol2 = BanditPolicy(cfg, n_arms=4)
    pol2.load_state_dict(blob)
    x = rng.standard_normal(cfg.context_dim).astype(np.float32)
    a1, s1 = pol.select(x, np.ones(4, bool))
    a2, s2 = pol2.select(x, np.ones(4, bool))
    assert a1 == a2
    np.testing.assert_allclose(s1, s2, rtol=1e-6)
