"""The version-dispatch layer itself: every shim must work on whatever JAX
this environment ships (0.4.x floor and 0.5+/0.6+ alike)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat


def test_jax_version_tuple():
    assert len(compat.JAX_VERSION) >= 2
    assert all(isinstance(p, int) for p in compat.JAX_VERSION)


def test_tree_flatten_with_path_and_path_str():
    tree = {"outer": {"inner": jnp.ones(2)}, "leaf": jnp.zeros(3),
            "seq": [jnp.ones(1), jnp.ones(4)]}
    leaves, treedef = compat.tree_flatten_with_path(tree)
    names = {compat.path_str(path) for path, _ in leaves}
    assert names == {"outer/inner", "leaf", "seq/0", "seq/1"}
    rebuilt = jax.tree_util.tree_unflatten(treedef,
                                           [leaf for _, leaf in leaves])
    assert jax.tree.structure(rebuilt) == jax.tree.structure(tree)


def test_tpu_compiler_params_roundtrip():
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert tuple(params.dimension_semantics) == ("parallel", "arbitrary")


def test_tpu_compiler_params_drops_unknown_fields():
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel",),
        some_flag_from_the_future=True)
    assert tuple(params.dimension_semantics) == ("parallel",)


def test_make_mesh_and_set_mesh():
    n = len(jax.devices())
    mesh = compat.make_mesh((n, 1), ("data", "model"))
    assert mesh.shape["data"] == n
    assert mesh.shape["model"] == 1
    with compat.set_mesh(mesh):
        pass                     # usable as a context manager on every version


def test_auto_axis_types_shape():
    types = compat.auto_axis_types(3)
    assert types is None or len(types) == 3


def test_cost_analysis_returns_dict():
    comp = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    ca = compat.cost_analysis(comp)
    assert isinstance(ca, dict)
    assert ca.get("flops", 0) > 0


def test_shard_map_runs_on_host_mesh():
    from jax.sharding import PartitionSpec as P
    n = len(jax.devices())
    mesh = compat.make_mesh((n,), ("x",))
    fn = compat.shard_map(
        lambda a: jax.lax.psum(a, "x"), mesh=mesh,
        in_specs=(P("x"),), out_specs=P(), check_vma=False)
    out = fn(jnp.arange(n, dtype=jnp.float32))
    np.testing.assert_allclose(np.asarray(out),
                               np.arange(n, dtype=np.float32).sum())
