"""Context generator: Flesch, k-means, task classifier, one-hot layout."""
import numpy as np
import pytest

from repro.core.context import (ContextGenerator, FleschComplexity,
                                OnlineKMeans, TaskClassifier,
                                count_syllables, flesch_reading_ease)
from repro.core.embedding import EmbeddingModel
from repro.core.types import RouterConfig, TaskType
from repro.data.stream import labeled_sample, make_stream


def test_flesch_known_values():
    easy = "The cat sat. The dog ran. We play all day."
    hard = ("Institutional accountability necessitates comprehensive "
            "regulatory harmonisation notwithstanding considerable "
            "implementation uncertainty.")
    assert flesch_reading_ease(easy) > 80
    assert flesch_reading_ease(hard) < 20


def test_flesch_clamped_and_empty():
    assert 0.0 <= flesch_reading_ease("antidisestablishmentarianism " * 30) <= 100.0
    assert flesch_reading_ease("") == 100.0


def test_syllables():
    assert count_syllables("cat") == 1
    assert count_syllables("table") == 2
    assert count_syllables("university") >= 4


def test_binning_edges():
    fc = FleschComplexity(n_bins=3)
    assert fc.bin(0.0) == 0
    assert fc.bin(33.2) == 0
    assert fc.bin(34.0) == 1
    assert fc.bin(99.9) == 2
    assert fc.bin(100.0) == 2     # top edge folds into the last bin


def test_kmeans_seeding_and_update():
    km = OnlineKMeans(k=2, dim=3)
    a = np.array([1.0, 0, 0], np.float32)
    b = np.array([0, 1.0, 0], np.float32)
    assert km.update(a) == 0
    assert km.update(b) == 1      # distinct → seeds second centroid
    c = km.update(np.array([0.9, 0.1, 0], np.float32))
    assert c == 0
    # Eq. 10: mu += (e - mu)/(N+1) with N=1 → midpoint-ish
    np.testing.assert_allclose(km.centroids[0], [0.95, 0.05, 0.0], atol=1e-6)


def test_kmeans_duplicate_seed_not_consumed():
    km = OnlineKMeans(k=3, dim=2)
    v = np.array([1.0, 0], np.float32)
    km.update(v)
    km.update(v)                  # same point: must not seed a new centroid
    assert km._initialized == 1


def test_task_classifier_learns_stream_tasks():
    emb = EmbeddingModel()
    clf = TaskClassifier(emb)
    texts, labels = labeled_sample(n_per_task=30)
    acc = clf.fit(texts, labels, steps=200)
    assert acc > 0.9
    # held-out sample
    texts2, labels2 = labeled_sample(n_per_task=10, seed=99)
    preds = [clf.predict(t) for t in texts2]
    assert np.mean(np.array(preds) == np.array(labels2)) > 0.8


def test_context_vector_layout(router_config):
    gen = ContextGenerator(router_config)
    x = gen.encode(task_label=2, cluster=1, comp_bin=0)
    cfg = router_config
    assert x.shape == (cfg.context_dim,)
    assert x[2] == 1.0                             # task one-hot
    assert x[cfg.n_tasks + 1] == 1.0               # cluster one-hot
    assert x[cfg.n_tasks + cfg.n_clusters + 0] == 1.0
    assert x[-1] == 1.0                            # intercept
    assert x.sum() == 4.0


def test_feature_ablation_toggles(router_config):
    gen = ContextGenerator(router_config)
    gen.set_features(task=False, cluster=False, complexity=False)
    x = gen("Some query text for ablation.")
    assert x.vector.sum() == 1.0                   # intercept only


def test_paper_context_dim_is_12():
    cfg = RouterConfig(n_clusters=3, n_complexity_bins=3)
    assert cfg.context_dim == 12                   # 5 + 3 + 3 + 1 (§6.1.5)


def test_stream_composition():
    qs = make_stream(per_task=10)
    assert len(qs) == 50
    per = {t: 0 for t in TaskType}
    for q in qs:
        per[q.task] += 1
    assert all(v == 10 for v in per.values())
    assert len({q.uid for q in qs}) == 50
